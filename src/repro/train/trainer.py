"""Fault-tolerant training loop.

The loop owns the three production behaviours the dry-run can't show:

  * **checkpoint/restart** — every ``ckpt_every`` steps the full (params,
    opt_state, step) pytree is saved asynchronously (atomic publish, see
    repro.checkpoint); on construction the trainer restores the newest
    complete checkpoint and the deterministic data pipeline (batch_at(step))
    replays exactly the batch the failed run would have seen next.  Node
    failure = process death = restart-and-resume; tests kill a run mid-step
    and assert bit-identical continuation.
  * **straggler mitigation** — per-step wall-time EWMA with a deadline
    multiplier; steps exceeding it are logged and counted (on a real
    multi-host deployment this signal feeds the remesh/elastic path: drop
    the slow host and continue on a smaller mesh via distributed.remesh).
  * **NaN/inf guard** — non-finite loss skips the update (params revert),
    counts toward a fuse that aborts if persistent — the standard large-run
    guard against data poison or transient hardware faults.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class Trainer:
    def __init__(self, train_step, params, opt_state, batch_at,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 straggler_factor: float = 3.0, nan_fuse: int = 5,
                 log_every: int = 10, log_fn=print):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.batch_at = batch_at
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.nan_fuse = nan_fuse
        self.log_every = log_every
        self.log = log_fn
        self.step = 0
        self.metrics: list[dict] = []
        self._ewma = None
        self.straggler_steps = 0
        self._nan_streak = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                state = self.ckpt.restore(
                    latest, like=(self.params, self.opt_state))
                self.params, self.opt_state = state
                self.step = latest + 1
                self.log(f"[trainer] resumed from step {latest}")

    def run(self, n_steps: int):
        end = self.step + n_steps
        while self.step < end:
            batch = self.batch_at(self.step)
            t0 = time.perf_counter()
            out = self.train_step(self.params, self.opt_state, batch)
            new_params, new_opt, loss, gnorm = out
            loss = float(jax.device_get(loss))
            dt = time.perf_counter() - t0
            # straggler watch
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.straggler_factor * self._ewma:
                self.straggler_steps += 1
                self.log(f"[trainer] straggler step {self.step}: "
                         f"{dt:.3f}s vs ewma {self._ewma:.3f}s")
            self._ewma = 0.9 * self._ewma + 0.1 * dt
            # NaN guard
            if not np.isfinite(loss):
                self._nan_streak += 1
                self.log(f"[trainer] non-finite loss at step {self.step}; "
                         f"skipping update ({self._nan_streak}/{self.nan_fuse})")
                if self._nan_streak >= self.nan_fuse:
                    raise FloatingPointError("persistent non-finite loss")
            else:
                self._nan_streak = 0
                self.params, self.opt_state = new_params, new_opt
            self.metrics.append({"step": self.step, "loss": loss,
                                 "gnorm": float(jax.device_get(gnorm)),
                                 "sec": dt})
            if self.log_every and self.step % self.log_every == 0:
                self.log(f"[trainer] step {self.step} loss {loss:.4f} "
                         f"({dt*1e3:.1f} ms)")
            if (self.ckpt is not None and self.step % self.ckpt_every == 0
                    and self.step > 0):
                self.ckpt.save(self.step, (self.params, self.opt_state),
                               blocking=False)
            self.step += 1
        if self.ckpt is not None:
            self.ckpt.save(self.step - 1, (self.params, self.opt_state),
                           blocking=True)
        return self.metrics
