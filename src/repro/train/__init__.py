from .trainer import Trainer  # noqa: F401
