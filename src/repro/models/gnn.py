"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter conv GNN.

Kernel regime: triplet-free edge gather + scatter (segment_sum), the
SpMM-adjacent member of the taxonomy's molecular family.  Message passing is
built on jax.ops.segment_sum (JAX has no sparse MM for this) — see
repro/sparse/ops.py.

One model covers all four assigned graph shapes:

  * molecule         — batched small graphs, sum-pooled energy regression;
  * full_graph_sm /  — single graph, node classification head (features are
    ogb_products       projected into the hidden width; pairwise "distances"
                       are supplied as edge features);
  * minibatch_lg     — fanout-sampled blocks from data/graph.py; the model
                       consumes the flattened union subgraph with edge masks.

Edge-partitioned distribution: edge arrays shard over ("pod","data"), node
states are replicated within a shard group and segment-reduced; the dry-run
meshes reduce partial node aggregates with one psum-like all-reduce inserted
by GSPMD on the segment_sum output constraint.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.sparse.ops import segment_sum


@dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_feat: int = 16          # input feature width (arch-shape dependent)
    n_out: int = 1            # 1 = regression; >1 = node classification
    dtype: Any = jnp.float32
    # edge chunking: the (E, n_rbf) expansion is 74 GB at ogb_products scale;
    # processing edges in checkpointed chunks keeps only one chunk's filter/
    # message tensors live (per device: chunk/shards * (n_rbf+2*dh) * 4 B).
    edge_chunk: int | None = None


def ssp(x):
    """Shifted softplus, SchNet's activation."""
    return jax.nn.softplus(x) - jnp.log(2.0)


def init_params(cfg: SchNetConfig, key) -> dict:
    ks = jax.random.split(key, 4 + 6 * cfg.n_interactions)
    dh, nr = cfg.d_hidden, cfg.n_rbf

    def lin(k, i, o):
        return {"w": jax.random.normal(k, (i, o), cfg.dtype) / jnp.sqrt(i),
                "b": jnp.zeros((o,), cfg.dtype)}

    inter = []
    for i in range(cfg.n_interactions):
        base = 4 + 6 * i
        inter.append({
            "filt1": lin(ks[base], nr, dh),
            "filt2": lin(ks[base + 1], dh, dh),
            "in_lin": lin(ks[base + 2], dh, dh),
            "out1": lin(ks[base + 3], dh, dh),
            "out2": lin(ks[base + 4], dh, dh),
        })
    return {
        "embed_in": lin(ks[0], cfg.d_feat, dh),
        "inter": jax.tree.map(lambda *xs: jnp.stack(xs), *inter)
        if cfg.n_interactions > 1 else jax.tree.map(
            lambda x: x[None], inter[0]),
        "read1": lin(ks[1], dh, dh // 2),
        "read2": lin(ks[2], dh // 2, cfg.n_out),
    }


def _ap(lp, x):
    return x @ lp["w"] + lp["b"]


def rbf_expand(dist, cfg: SchNetConfig):
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = cfg.n_rbf / cfg.cutoff
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def forward(params, batch, cfg: SchNetConfig, mesh):
    """batch: node_feat (N, d_feat), src/dst (E,), dist (E,), edge_mask (E,).

    Returns per-node hidden (N, d_hidden) transformed to (N, n_out).
    """
    x = ssp(_ap(params["embed_in"], batch["node_feat"]))   # (N, dh)
    src = batch["src"]
    dst = batch["dst"]
    emask = batch["edge_mask"].astype(cfg.dtype)
    dist = batch["dist"]
    N = x.shape[0]
    E = src.shape[0]
    ec = cfg.edge_chunk or E
    n_chunks = max(1, E // ec)

    def cfconv_chunk(h, dist_c, src_c, dst_c, emask_c, lp):
        """One edge-chunk of the continuous-filter conv (checkpointed so the
        backward recomputes rbf/filter/messages instead of storing them)."""
        rbf = rbf_expand(dist_c, cfg)                      # (ec, n_rbf)
        rbf = constrain(rbf, mesh, ("pod", "data", "model"), None)
        filt = _ap(lp["filt2"], ssp(_ap(lp["filt1"], rbf)))  # (ec, dh)
        msg = h[src_c] * filt * emask_c[:, None]             # cfconv
        msg = constrain(msg, mesh, ("pod", "data", "model"), None)
        return segment_sum(msg, dst_c, N)

    def interaction(x, lp):
        h = _ap(lp["in_lin"], x)
        if n_chunks == 1:
            agg = cfconv_chunk(h, dist, src, dst, emask, lp)
        else:
            # lax.scan over edge chunks: provably-sequential liveness (one
            # chunk's rbf/filter/message tensors alive at a time); bodies
            # are checkpointed so the backward recomputes instead of saving.
            xs = (dist.reshape(n_chunks, ec), src.reshape(n_chunks, ec),
                  dst.reshape(n_chunks, ec), emask.reshape(n_chunks, ec))

            def body(agg, xc):
                out = jax.checkpoint(cfconv_chunk)(h, *xc, lp)
                return agg + out, None

            agg, _ = jax.lax.scan(
                body, jnp.zeros((N, cfg.d_hidden), cfg.dtype), xs)
        v = _ap(lp["out2"], ssp(_ap(lp["out1"], agg)))
        return x + v

    # unrolled (n_interactions <= 6): exact HLO cost accounting for roofline
    for i in range(cfg.n_interactions):
        lp = jax.tree.map(lambda a: a[i], params["inter"])
        x = interaction(x, lp)
    return _ap(params["read2"], ssp(_ap(params["read1"], x)))


def graph_loss(params, batch, cfg: SchNetConfig, mesh, n_graphs: int = 1):
    """Regression (graph-pooled) or node classification, by config."""
    out = forward(params, batch, cfg, mesh)                # (N, n_out)
    if cfg.n_out == 1:
        # molecule energies: sum-pool per graph via graph_ids
        energy = segment_sum(out[:, 0] * batch["node_mask"],
                             batch["graph_ids"], n_graphs)
        return jnp.mean(jnp.square(energy - batch["target"]))
    logits = out.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
    mask = batch["node_mask"]
    return jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)


def make_train_step(cfg: SchNetConfig, mesh, optimizer_update,
                    n_graphs: int = 1):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: graph_loss(p, batch, cfg, mesh, n_graphs))(params)
        new_p, new_o, gnorm = optimizer_update(params, grads, opt_state)
        return new_p, new_o, loss, gnorm
    return train_step


def input_specs(cfg: SchNetConfig, n_nodes: int, n_edges: int,
                n_graphs: int = 1, classify: bool = False):
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    specs = {
        "node_feat": S((n_nodes, cfg.d_feat), f32),
        "src": S((n_edges,), i32), "dst": S((n_edges,), i32),
        "dist": S((n_edges,), f32), "edge_mask": S((n_edges,), jnp.bool_),
        "node_mask": S((n_nodes,), f32),
    }
    if classify:
        specs["labels"] = S((n_nodes,), i32)
    else:
        specs["graph_ids"] = S((n_nodes,), i32)
        specs["target"] = S((n_graphs,), f32)
    return specs
