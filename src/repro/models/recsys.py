"""RecSys model family: DLRM, SASRec, DIN, two-tower retrieval.

Common substrate: one *fused* embedding table per model — all categorical
tables concatenate row-wise into a single (ΣR, D) array with per-field row
offsets, looked up in ONE gather (the FBGEMM/TBE trick; also what makes
row-sharding over the whole mesh trivial: P(("data","model"), None)).
EmbeddingBag semantics (multi-hot history bags) come from
repro.sparse.embedding_bag.

  * DLRM  (arXiv:1906.00091) — bottom MLP -> dot interaction -> top MLP;
  * SASRec (arXiv:1808.09781) — causal self-attention over the item history;
  * DIN   (arXiv:1706.06978) — target attention (sigmoid-weighted sum);
  * two-tower (Yi et al., RecSys'19) — dual MLP towers, in-batch sampled
    softmax with logQ correction; retrieval_cand scoring is a single
    (1, D)x(D, 10^6) matmul (kernels/retrieval_dot on TPU).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.sparse.ops import embedding_bag


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (i, o), dtype) * jnp.sqrt(2.0 / i),
             "b": jnp.zeros((o,), dtype)}
            for k, i, o in zip(ks, dims[:-1], dims[1:])]


def _mlp_apply(layers, x, final_act=False):
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if i + 1 < len(layers) or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logit, label):
    logit = logit.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * label +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ==========================================================================
# DLRM
# ==========================================================================


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    table_rows: Sequence[int] = ()
    embed_dim: int = 128
    n_dense: int = 13
    bot_mlp: Sequence[int] = (512, 256, 128)
    top_mlp: Sequence[int] = (1024, 1024, 512, 256, 1)
    dtype: Any = jnp.float32

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.table_rows)[:-1]]).astype(
            np.int64)

    @property
    def total_rows(self) -> int:
        """Fused-table rows, padded to 512 for whole-mesh row sharding
        (padding rows sit at the end and are never addressed)."""
        n = int(sum(self.table_rows))
        return (n + 511) // 512 * 512


def dlrm_init(cfg: DLRMConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "table": jax.random.normal(
            k1, (cfg.total_rows, cfg.embed_dim), cfg.dtype) * 0.01,
        "bot": _mlp_init(k2, (cfg.n_dense, *cfg.bot_mlp), cfg.dtype),
        "top": _mlp_init(
            k3, (cfg.embed_dim + (len(cfg.table_rows) + 1) *
                 len(cfg.table_rows) // 2 + 0, *cfg.top_mlp), cfg.dtype),
    }


def dlrm_forward(params, batch, cfg: DLRMConfig, mesh):
    dense = _mlp_apply(params["bot"], batch["dense"], final_act=True)
    ids = batch["sparse"] + jnp.asarray(cfg.offsets, jnp.int32)[None, :]
    emb = params["table"][ids]                    # (B, 26, D) one fused gather
    emb = constrain(emb, mesh, ("pod", "data", "model"), None, None)
    feats = jnp.concatenate([dense[:, None, :], emb], axis=1)  # (B, 27, D)
    inter = jnp.einsum("bnd,bmd->bnm", feats, feats)
    n = feats.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    pairs = inter[:, iu, ju]                                   # (B, 351)
    top_in = jnp.concatenate([dense, pairs], axis=1)
    return _mlp_apply(params["top"], top_in)[:, 0]


def dlrm_loss(params, batch, cfg: DLRMConfig, mesh):
    return bce_loss(dlrm_forward(params, batch, cfg, mesh), batch["label"])


# ==========================================================================
# SASRec
# ==========================================================================


@dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dtype: Any = jnp.float32


def sasrec_init(cfg: SASRecConfig, key) -> dict:
    ks = jax.random.split(key, 3 + 4 * cfg.n_blocks)
    D = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        b = 3 + 4 * i
        blocks.append({
            "wqkv": jax.random.normal(ks[b], (D, 3 * D), cfg.dtype) * 0.05,
            "wo": jax.random.normal(ks[b + 1], (D, D), cfg.dtype) * 0.05,
            "ff1": jax.random.normal(ks[b + 2], (D, D), cfg.dtype) * 0.05,
            "ff2": jax.random.normal(ks[b + 3], (D, D), cfg.dtype) * 0.05,
            "ln1": jnp.ones((D,), cfg.dtype), "ln2": jnp.ones((D,), cfg.dtype),
        })
    return {
        "item_embed": jax.random.normal(
            ks[0], (cfg.n_items, D), cfg.dtype) * 0.01,
        "pos_embed": jax.random.normal(
            ks[1], (cfg.seq_len, D), cfg.dtype) * 0.01,
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        if cfg.n_blocks > 1 else jax.tree.map(lambda x: x[None], blocks[0]),
    }


def _ln(x, g, eps=1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g


def sasrec_hidden(params, seq_ids, cfg: SASRecConfig, mesh):
    B, S = seq_ids.shape
    D = cfg.embed_dim
    x = params["item_embed"][seq_ids] + params["pos_embed"][None, :S]
    x = constrain(x, mesh, ("pod", "data", "model"), None, None)
    mask = jnp.tril(jnp.ones((S, S), bool))

    def block(x, bp):
        h = _ln(x, bp["ln1"])
        qkv = h @ bp["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(D)
        s = jnp.where(mask[None], s, -1e30)
        att = jax.nn.softmax(s, -1) @ v
        x = x + att @ bp["wo"]
        h2 = _ln(x, bp["ln2"])
        return x + jax.nn.relu(h2 @ bp["ff1"]) @ bp["ff2"]

    # unrolled (n_blocks == 2): exact HLO cost accounting for roofline
    for i in range(cfg.n_blocks):
        bp = jax.tree.map(lambda a: a[i], params["blocks"])
        x = block(x, bp)
    return x                                            # (B, S, D)


def sasrec_loss(params, batch, cfg: SASRecConfig, mesh):
    """BCE over (positive, sampled negative) next items, per position."""
    h = sasrec_hidden(params, batch["seq"], cfg, mesh)
    pos_e = params["item_embed"][batch["pos"]]          # (B, S, D)
    neg_e = params["item_embed"][batch["neg"]]
    pos_l = jnp.sum(h * pos_e, -1)
    neg_l = jnp.sum(h * neg_e, -1)
    m = batch["seq_mask"]
    loss = (bce_pointwise(pos_l, 1.0) + bce_pointwise(neg_l, 0.0)) * m
    return loss.sum() / jnp.maximum(m.sum(), 1.0)


def bce_pointwise(logit, label):
    logit = logit.astype(jnp.float32)
    return (jnp.maximum(logit, 0) - logit * label +
            jnp.log1p(jnp.exp(-jnp.abs(logit))))


def sasrec_serve(params, batch, cfg: SASRecConfig, mesh):
    """Score candidate items given a user's history (online inference)."""
    h = sasrec_hidden(params, batch["seq"], cfg, mesh)[:, -1]  # (B, D)
    cand = params["item_embed"][batch["cands"]]                # (B, C, D)
    return jnp.einsum("bd,bcd->bc", h, cand)


# ==========================================================================
# DIN
# ==========================================================================


@dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Sequence[int] = (80, 40)
    mlp: Sequence[int] = (200, 80)
    dtype: Any = jnp.float32


def din_init(cfg: DINConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    D = cfg.embed_dim
    return {
        "item_embed": jax.random.normal(
            k1, (cfg.n_items, D), cfg.dtype) * 0.01,
        "attn": _mlp_init(k2, (4 * D, *cfg.attn_mlp, 1), cfg.dtype),
        "mlp": _mlp_init(k3, (2 * D, *cfg.mlp, 1), cfg.dtype),
    }


def din_forward(params, batch, cfg: DINConfig, mesh):
    hist = params["item_embed"][batch["history"]]       # (B, L, D)
    hist = constrain(hist, mesh, ("pod", "data", "model"), None, None)
    tgt = params["item_embed"][batch["target"]]         # (B, D)
    t = jnp.broadcast_to(tgt[:, None, :], hist.shape)
    a_in = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = _mlp_apply(params["attn"], a_in)[..., 0]        # (B, L) — sigmoid gate
    w = jax.nn.sigmoid(w) * batch["hist_mask"]
    user = jnp.einsum("bl,bld->bd", w, hist)            # weighted sum pool
    x = jnp.concatenate([user, tgt], axis=-1)
    return _mlp_apply(params["mlp"], x)[:, 0]


def din_loss(params, batch, cfg: DINConfig, mesh):
    return bce_loss(din_forward(params, batch, cfg, mesh), batch["label"])


# ==========================================================================
# two-tower retrieval
# ==========================================================================


@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_users_vocab: int = 2_000_000
    n_items: int = 2_000_000
    embed_dim: int = 256
    tower_mlp: Sequence[int] = (1024, 512, 256)
    n_user_feats: int = 8
    dtype: Any = jnp.float32


def twotower_init(cfg: TwoTowerConfig, key) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    D = cfg.embed_dim
    return {
        "user_table": jax.random.normal(
            k1, (cfg.n_users_vocab, D), cfg.dtype) * 0.01,
        "item_table": jax.random.normal(
            k2, (cfg.n_items, D), cfg.dtype) * 0.01,
        "user_tower": _mlp_init(k3, (D, *cfg.tower_mlp), cfg.dtype),
        "item_tower": _mlp_init(k4, (D, *cfg.tower_mlp), cfg.dtype),
    }


def user_embedding(params, batch, cfg: TwoTowerConfig, mesh):
    bag = embedding_bag(params["user_table"], batch["user_feats"],
                        weights=batch["user_mask"], mode="sum")
    u = _mlp_apply(params["user_tower"], bag)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_embedding(params, item_ids, cfg: TwoTowerConfig, mesh):
    it = params["item_table"][item_ids]
    v = _mlp_apply(params["item_tower"], it)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def twotower_loss(params, batch, cfg: TwoTowerConfig, mesh, tau=0.05):
    """In-batch sampled softmax with logQ correction (Yi et al. '19)."""
    u = user_embedding(params, batch, cfg, mesh)         # (B, D')
    v = item_embedding(params, batch["item"], cfg, mesh)  # (B, D')
    logits = (u @ v.T) / tau                             # (B, B)
    logits = logits - batch["logq"][None, :]             # sampling correction
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[:, None], -1)[:, 0]
    return jnp.mean(lse - gold)


def twotower_serve(params, batch, cfg: TwoTowerConfig, mesh):
    """Online inference: score given (user, item) pairs."""
    u = user_embedding(params, batch, cfg, mesh)         # (B, D')
    v = item_embedding(params, batch["item"], cfg, mesh)  # (B, D')
    return jnp.sum(u * v, axis=-1)


def twotower_retrieve(params, batch, cfg: TwoTowerConfig, mesh):
    """retrieval_cand: score one query against n_candidates items."""
    u = user_embedding(params, batch, cfg, mesh)         # (1, D')
    cand = item_embedding(params, batch["cand_ids"], cfg, mesh)  # (C, D')
    cand = constrain(cand, mesh, ("data", "model"), None)
    return (u @ cand.T)                                  # (1, C)


# ==========================================================================
# generic step factories
# ==========================================================================


def make_train_step(loss_fn, optimizer_update):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_o, gnorm = optimizer_update(params, grads, opt_state)
        return new_p, new_o, loss, gnorm
    return train_step
