"""Transformer LM family: dense + MoE, GQA, RoPE, SwiGLU.

One implementation covers all five assigned LM architectures (llama4-scout,
granite-moe, granite-3-2b, llama3.2-3b, mistral-large).  Engineering points
that matter at 512 chips:

  * **scan over layers** — parameters are stacked (L, ...) and the block is a
    single ``lax.scan`` body (+ ``jax.checkpoint`` remat), so HLO size and
    compile time are O(1) in depth (88-layer mistral compiles as fast as a
    2-layer toy);
  * **flash-style attention** — nested q-chunk/kv-chunk scan with running
    (max, denom, acc); no (S, S) score tensor ever materializes, making the
    32k-prefill shapes fit VMEM-sized tiles;
  * **sort-based MoE dispatch** — argsort tokens by expert, capacity-clip,
    scatter/gather rows; no one-hot dispatch einsum, so compiled FLOPs stay
    ≈ useful FLOPs (the dispatch is pure data movement, visible in the
    roofline's memory term instead — where it belongs);
  * **vocab-sharded chunked loss** — logits are built seq-chunk at a time
    with the vocab dim sharded over "model"; the full (B, S, V) tensor never
    exists;
  * **decode path** — serve_step attends one new token against a KV cache
    laid out (L, B, S, kv*dh) so the head dim shards evenly over "model"
    even when kv_heads < mesh width.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    moe: MoEConfig | None = None
    rope_theta: float = 500_000.0
    dtype: Any = jnp.bfloat16
    # execution knobs (hillclimb surface)
    q_chunk: int = 256
    kv_chunk: int = 1024
    loss_chunk: int = 512
    microbatch: int = 1          # grad-accumulation factor
    remat: bool = True
    pad_multiple: int = 512      # mesh-divisibility padding (vocab, experts)
    # layer-boundary activation sharding: "dmodel" won the §Perf H3 sweep
    # (6.5x less weight-gather traffic than "seq" at equal memory; "none"
    # is the no-remat-sharding baseline and OOMs at 88 layers)
    act_shard: str = "dmodel"    # none|seq|dmodel
    opt_dtype: Any = jnp.float32  # AdamW moment dtype (bf16 halves opt mem)
    # roofline probe mode: XLA cost_analysis counts while-loop bodies ONCE,
    # so for §Roofline the dry-run lowers "probe" variants with all loops
    # unrolled at probe_layers ∈ {1, 2} and extrapolates linearly in L.
    probe_layers: int | None = None
    probe_unroll: bool = False

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so (V/model)·(D/data) shardings divide evenly —
        the MaxText-style embedding pad; padded logit columns are masked to
        -inf in the loss."""
        m = self.pad_multiple
        return (self.vocab + m - 1) // m * m

    @property
    def n_experts_padded(self) -> int:
        """Experts rounded up to the tensor-axis width (16); padded experts
        receive zero tokens (router indices stay < n_experts)."""
        if not self.moe:
            return 0
        return (self.moe.n_experts + 15) // 16 * 16

    @property
    def params_count(self) -> int:
        D, H, KV, dh, Fd, V, L = (self.d_model, self.n_heads,
                                  self.n_kv_heads, self.d_head, self.d_ff,
                                  self.vocab, self.n_layers)
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D
        if self.moe:
            ff = self.moe.n_experts * 3 * D * Fd + D * self.moe.n_experts
        else:
            ff = 3 * D * Fd
        return L * (attn + ff + 2 * D) + V * D + D * V + D

    @property
    def active_params_count(self) -> int:
        if not self.moe:
            return self.params_count
        D, Fd, L = self.d_model, self.d_ff, self.n_layers
        full = self.params_count
        ff_all = L * self.moe.n_experts * 3 * D * Fd
        ff_act = L * self.moe.top_k * 3 * D * Fd
        return full - ff_all + ff_act


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------


def init_params(cfg: LMConfig, key) -> dict:
    D, H, KV, dh, Fd, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.d_head, cfg.d_ff, cfg.vocab, cfg.n_layers)
    k = jax.random.split(key, 12)
    s = lambda *sh: (1.0 / math.sqrt(sh[-2])) if len(sh) >= 2 else 0.02
    dt = cfg.dtype

    def rnd(i, *sh):
        return (jax.random.normal(k[i % 12], sh, jnp.float32)
                * 0.02).astype(dt)

    layers = {
        "wq": rnd(0, L, D, H * dh), "wk": rnd(1, L, D, KV * dh),
        "wv": rnd(2, L, D, KV * dh), "wo": rnd(3, L, H * dh, D),
        "ln1": jnp.ones((L, D), dt), "ln2": jnp.ones((L, D), dt),
    }
    if cfg.moe:
        E = cfg.moe.n_experts
        Ep = cfg.n_experts_padded
        layers.update({
            "router": rnd(4, L, D, E),
            "moe_w_gate": rnd(5, L, Ep, D, Fd),
            "moe_w_up": rnd(6, L, Ep, D, Fd),
            "moe_w_down": rnd(7, L, Ep, Fd, D),
        })
    else:
        layers.update({
            "w_gate": rnd(4, L, D, Fd), "w_up": rnd(5, L, D, Fd),
            "w_down": rnd(6, L, Fd, D),
        })
    Vp = cfg.vocab_padded
    return {
        "embed": rnd(8, Vp, D),
        "layers": layers,
        "ln_f": jnp.ones((D,), dt),
        "out_proj": rnd(9, D, Vp),
    }


def params_shape(cfg: LMConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(
        x.dtype) * scale


def rope(x, positions, theta):
    """x: (..., S, H, dh); rotate pairs along dh."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                    unroll: bool = False):
    """Memory-bounded attention: q (B,S,H,dh), k/v (B,S,KV,dh) -> (B,S,H,dh).

    GQA broadcast happens per-tile; running-softmax accumulators keep only
    (B, qc, H, kvc) alive.  ``unroll=True`` materializes the chunk loops as
    straight-line HLO (probe mode: exact FLOP counting; callers pass large
    chunks so the unroll factor stays small).
    """
    q_chunk = min(q_chunk, q.shape[1])
    kv_chunk = min(kv_chunk, k.shape[1])
    if unroll:
        return _flash_unrolled(q, k, v, causal=causal, q_chunk=q_chunk,
                               kv_chunk=kv_chunk)
    B, S, Hq, dh = q.shape
    KV = k.shape[2]
    rep = Hq // KV
    scale = 1.0 / math.sqrt(dh)
    nq = S // q_chunk
    nk = S // kv_chunk

    q = q.reshape(B, nq, q_chunk, Hq, dh)

    # Recursive remat: without it the scan-of-scan backward materializes the
    # (B,H,qc,kvc) probability tile for every (q,kv) pair simultaneously
    # (~nq*nk*p_tile — 12+ GiB/device at 88Lx4k). Checkpointing both loop
    # bodies caps attention-bwd residency at one tile.
    @jax.checkpoint
    def q_chunk_fn(qc, q0):
        def kv_body(carry, ki):
            # GQA without materializing repeated K/V: q is viewed as
            # (B, qc, KV, rep, dh) and contracted against (B, kc, KV, dh)
            # group-wise — a 12x memory saving at mistral's 96:8 ratio.
            m, l, acc = carry
            kc, vc, kpos = ki["k"], ki["v"], ki["pos"]  # (B, kc, KV, dh)
            qg = qc.reshape(B, q_chunk, KV, rep, dh)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = q0 + jnp.arange(q_chunk)
                kpos_v = kpos * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos_v[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            s = s.reshape(B, Hq, q_chunk, kv_chunk)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pg = p.reshape(B, KV, rep, q_chunk, kv_chunk).astype(qc.dtype)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", pg, vc,
                preferred_element_type=jnp.float32).reshape(
                    B, Hq, q_chunk, dh)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hq, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hq, q_chunk, dh), jnp.float32)
        ks = {"k": k.reshape(B, nk, kv_chunk, KV, dh).swapaxes(0, 1),
              "v": v.reshape(B, nk, kv_chunk, KV, dh).swapaxes(0, 1),
              "pos": jnp.arange(nk)}
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_body), (m0, l0, a0),
                                      ks)
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out.swapaxes(1, 2).astype(qc.dtype)  # (B, qc, Hq, dh)

    def q_body(_, qi):
        return None, q_chunk_fn(qi["q"], qi["pos"] * q_chunk)

    qs = {"q": q.swapaxes(0, 1), "pos": jnp.arange(nq)}
    _, out = jax.lax.scan(q_body, None, qs)
    return out.swapaxes(0, 1).reshape(B, S, Hq, dh)


def _flash_unrolled(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int):
    """Straight-line flash attention (probe mode), same math as above."""
    B, S, Hq, dh = q.shape
    KV = k.shape[2]
    rep = Hq // KV
    scale = 1.0 / math.sqrt(dh)
    nq, nk = S // q_chunk, S // kv_chunk
    outs = []
    for qi in range(nq):
        qc = q[:, qi * q_chunk:(qi + 1) * q_chunk]
        m = jnp.full((B, Hq, q_chunk), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        acc = jnp.zeros((B, Hq, q_chunk, dh), jnp.float32)
        qg = qc.reshape(B, q_chunk, KV, rep, dh)
        for ki in range(nk):
            if causal and ki * kv_chunk > (qi + 1) * q_chunk - 1:
                continue  # fully-masked tile: skip (causal block sparsity)
            kc = k[:, ki * kv_chunk:(ki + 1) * kv_chunk]
            vc = v[:, ki * kv_chunk:(ki + 1) * kv_chunk]
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.where(
                    (qpos[:, None] >= kpos[None, :])[None, None, None],
                    s, -1e30)
            s = s.reshape(B, Hq, q_chunk, kv_chunk)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            pg = p.reshape(B, KV, rep, q_chunk, kv_chunk).astype(qc.dtype)
            acc = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", pg, vc,
                preferred_element_type=jnp.float32).reshape(
                    B, Hq, q_chunk, dh)
            m = m_new
        outs.append((acc / jnp.maximum(l, 1e-20)[..., None]
                     ).swapaxes(1, 2).astype(q.dtype))
    return jnp.concatenate(outs, axis=1).reshape(B, S, Hq, dh)


def moe_ffn(x, lp, cfg: LMConfig, mesh):
    """Sort-based top-k MoE (x: (N, D) flat tokens) -> (N, D).

    Expert weights are stored with E padded to the tensor-axis width; router
    indices never reach the padded range, so padded experts process only
    zeros (pure padding waste, visible and noted in the roofline)."""
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k
    Ep = cfg.n_experts_padded
    N, D = x.shape
    C = int(mc.capacity_factor * N * K / E)
    C = max(8, min(C, N))
    x = constrain(x, mesh, ("pod", "data"), None)
    logits = (x @ lp["router"]).astype(jnp.float32)       # (N, E)
    logits = constrain(logits, mesh, ("pod", "data"), None)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                  # (N, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    flat_e = eidx.reshape(-1)                              # (N*K,)
    # stable sort by expert; rank within expert = position - expert start
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(N * K) - starts[sorted_e]
    # gather-only dispatch (no scatter — GSPMD reshards gathers cleanly):
    # tokens sorted by expert are contiguous, so expert e's batch is rows
    # [starts[e], starts[e]+C) of the sorted token matrix, masked at count.
    sorted_tok = constrain(x[order // K], mesh, ("pod", "data"), None)
    starts_p = jnp.concatenate(
        [starts, jnp.full((Ep - E,), N * K, jnp.int32)])   # padded experts
    take = starts_p[:, None] + jnp.arange(C)[None, :]      # (Ep, C)
    valid = (jnp.arange(C)[None, :] < jnp.minimum(
        jnp.concatenate([counts, jnp.zeros(Ep - E, counts.dtype)]), C
    )[:, None])
    h = sorted_tok[jnp.clip(take, 0, N * K - 1)] * valid[..., None]
    h = constrain(h, mesh, "model", None, None)            # (Ep, C, D)
    a = jnp.einsum("ecd,edf->ecf", h, lp["moe_w_gate"])
    b = jnp.einsum("ecd,edf->ecf", h, lp["moe_w_up"])
    hh = jax.nn.silu(a) * b
    out_e = jnp.einsum("ecf,efd->ecd", hh, lp["moe_w_down"])
    out_e = constrain(out_e, mesh, "model", None, None)
    flat_out = out_e.reshape(Ep * C, D)
    # combine: token (n,k) sits at sorted position inv[nk] with expert rank
    # rank[inv[nk]]; capacity-dropped tokens contribute zero.
    inv = jnp.argsort(order, stable=True)                  # (N*K,)
    r_tok = rank[inv]
    e_tok = flat_e
    kept = r_tok < C
    src = jnp.clip(e_tok * C + jnp.minimum(r_tok, C - 1), 0, Ep * C - 1)
    per_k = flat_out[src] * kept[:, None].astype(x.dtype)
    per_k = constrain(per_k.reshape(N, K, D), mesh,
                      ("pod", "data"), None, None)
    return (per_k * gates[..., None].astype(x.dtype)).sum(1)


def dense_ffn(x, lp):
    return (jax.nn.silu(x @ lp["w_gate"]) * (x @ lp["w_up"])) @ lp["w_down"]


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------


def _boundary_constraint(x, cfg: LMConfig, mesh):
    """Layer-boundary activation sharding (what remat saves per layer).

    "seq" = Megatron-style sequence parallelism: (B, S, D) shards S over
    "model" between blocks, so the 88-layer remat footprint divides by the
    tensor-axis width; GSPMD inserts the all-gathers at the attention/FFN
    entry points.  "dmodel" shards D instead; "none" is the naive baseline
    (kept for the §Perf before/after record).
    """
    if cfg.act_shard == "seq":
        return constrain(x, mesh, ("pod", "data"), "model", None)
    if cfg.act_shard == "dmodel":
        return constrain(x, mesh, ("pod", "data"), None, "model")
    return constrain(x, mesh, ("pod", "data"), None, None)


def forward(params, tokens, cfg: LMConfig, mesh, return_kv: bool = False):
    """tokens (B, S) -> final hidden (B, S, D) [+ per-layer KV cache]."""
    B, S = tokens.shape
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = params["embed"][tokens]
    x = _boundary_constraint(x, cfg, mesh)
    positions = jnp.arange(S)[None, :]

    def block(x, lp):
        # Megatron-style sequence parallelism: the block-BOUNDARY tensor
        # (what remat saves, 88x per device) stays seq-sharded over "model";
        # inside the block the activation is all-gathered to full sequence so
        # the tensor-parallel matmuls don't fight over the model axis
        # (otherwise GSPMD reconciles by all-gathering entire FFN weights).
        h = rmsnorm(x, lp["ln1"])
        h = constrain(h, mesh, ("pod", "data"), None, None)
        q = (h @ lp["wq"]).reshape(B, S, H, dh)
        k = (h @ lp["wk"]).reshape(B, S, KV, dh)
        v = (h @ lp["wv"]).reshape(B, S, KV, dh)
        # activations batch-sharded through attention (head counts do not
        # always divide the model axis; GSPMD pads intermediates as needed)
        q = constrain(q, mesh, ("pod", "data"), None, "model", None)
        k = constrain(k, mesh, ("pod", "data"), None, None, None)
        v = constrain(v, mesh, ("pod", "data"), None, None, None)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        att = flash_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk,
                              unroll=cfg.probe_unroll)
        x = x + _boundary_constraint(
            att.reshape(B, S, H * dh) @ lp["wo"], cfg, mesh)
        h2 = rmsnorm(x, lp["ln2"])
        h2 = constrain(h2, mesh, ("pod", "data"), None, None)
        if cfg.moe:
            y = moe_ffn(h2.reshape(B * S, D), lp, cfg, mesh).reshape(B, S, D)
        else:
            y = dense_ffn(h2, lp)
        x = x + _boundary_constraint(y, cfg, mesh)
        x = _boundary_constraint(x, cfg, mesh)
        kv = ((k.reshape(B, S, KV * dh), v.reshape(B, S, KV * dh))
              if return_kv else None)
        return x, kv

    if cfg.probe_layers is not None:
        # probe mode: unrolled layers for exact HLO cost accounting
        kvs = []
        for i in range(cfg.probe_layers):
            lp = jax.tree.map(lambda a: a[i % a.shape[0]], params["layers"])
            x, kv = block(x, lp)
            if return_kv:
                kvs.append(kv)
        out = rmsnorm(x, params["ln_f"])
        if return_kv:
            k_all = jnp.stack([kv[0] for kv in kvs])
            v_all = jnp.stack([kv[1] for kv in kvs])
            return out, {"k": k_all, "v": v_all}
        return out

    body = block
    if cfg.remat and not return_kv:
        body = jax.checkpoint(block, prevent_cse=False)

    x, kvs = jax.lax.scan(body, x, params["layers"])
    out = rmsnorm(x, params["ln_f"])
    if return_kv:
        return out, {"k": kvs[0], "v": kvs[1]}
    return out


def make_prefill_step(cfg: LMConfig, mesh):
    """prefill_step(params, tokens) -> (last-token logits, KV cache)."""

    def prefill_step(params, tokens):
        hidden, cache = forward(params, tokens, cfg, mesh, return_kv=True)
        logits = hidden[:, -1] @ params["out_proj"]
        return logits, cache

    return prefill_step


def lm_loss(params, batch, cfg: LMConfig, mesh):
    """Chunked vocab-sharded cross-entropy."""
    hidden = forward(params, batch["tokens"], cfg, mesh)   # (B, S, D)
    B, S, D = hidden.shape
    ch = min(cfg.loss_chunk, S)
    nch = S // ch

    def chunk_loss(carry, i):
        h = jax.lax.dynamic_slice_in_dim(hidden, i * ch, ch, axis=1)
        y = jax.lax.dynamic_slice_in_dim(batch["labels"], i * ch, ch, axis=1)
        logits = h @ params["out_proj"]                    # (B, ch, Vp)
        logits = constrain(logits, mesh, ("pod", "data"), None, "model")
        if cfg.vocab_padded > cfg.vocab:                   # mask pad columns
            vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab
            logits = jnp.where(vmask, logits, -1e30)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), y[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    if cfg.probe_unroll:
        tot = jnp.zeros((), jnp.float32)
        for i in range(nch):
            tot, _ = chunk_loss(tot, i)
    else:
        tot, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                              jnp.arange(nch))
    return tot / (B * S)


# --------------------------------------------------------------------------
# train / serve steps
# --------------------------------------------------------------------------


def make_train_step(cfg: LMConfig, mesh, optimizer_update,
                    param_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, loss).

    Microbatching: the global batch is split into cfg.microbatch slices and
    gradients accumulate in a scan (activation memory / microbatch).

    ``param_shardings`` pins gradient shardings to the parameter layout —
    without it GSPMD may pick a transposed layout for scan-xs cotangents and
    then *all-gather entire weight matrices* to reconcile at the accumulate/
    optimizer boundary (observed: 21 replicated f32[28672,12288] buffers on
    mistral-123b).
    """

    def pin(g):
        if param_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            param_shardings)

    def loss_fn(p, b):
        return lm_loss(p, b, cfg, mesh)

    def train_step(params, opt_state, batch):
        mb = cfg.microbatch
        if mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = pin(grads)
        else:
            B = batch["tokens"].shape[0]
            sz = B // mb
            def mb_body(acc, i):
                sl = {k: jax.lax.dynamic_slice_in_dim(v, i * sz, sz, 0)
                      for k, v in batch.items()}
                l, g = jax.value_and_grad(loss_fn)(params, sl)
                g = pin(g)
                # accumulate in the param dtype: with donated scan carries
                # this halves accumulator residency vs f32; the optimizer
                # upcasts to f32 before the moment update.
                return (acc[0] + l / mb,
                        jax.tree.map(lambda a, b: a + (b / mb).astype(a.dtype),
                                     acc[1], g)), None
            zero = (jnp.zeros((), jnp.float32),
                    pin(jax.tree.map(jnp.zeros_like, params)))
            (loss, grads), _ = jax.lax.scan(mb_body, zero, jnp.arange(mb))
        new_params, new_opt, gnorm = optimizer_update(params, grads,
                                                      opt_state)
        return new_params, new_opt, loss, gnorm

    return train_step


def make_serve_step(cfg: LMConfig, mesh):
    """Returns serve_step(params, cache, token, pos) -> (logits, cache).

    cache: dict(k=(L, B, S, KV*dh), v=(L, B, S, KV*dh)) — one new token
    attends to `pos` cached positions (decode_* / long_* shapes).
    """
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    rep = H // KV

    def serve_step(params, cache, token, pos):
        B = token.shape[0]
        x = params["embed"][token][:, None, :]             # (B, 1, D)
        positions = jnp.full((B, 1), pos, jnp.int32)

        def block(carry, inp):
            x, li = carry
            lp, kc, vc = inp

            h = rmsnorm(x, lp["ln1"])
            q = (h @ lp["wq"]).reshape(B, 1, H, dh)
            k = (h @ lp["wk"]).reshape(B, 1, KV, dh)
            v = (h @ lp["wv"]).reshape(B, 1, KV, dh)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            # append to cache at position `pos`
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.reshape(B, 1, KV * dh), pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.reshape(B, 1, KV * dh), pos, axis=1)
            S = kc.shape[1]
            kk = kc.reshape(B, S, KV, dh)
            vv = vc.reshape(B, S, KV, dh)
            # GQA decode without repeat: group the query heads (the repeat
            # would materialize rep x the ENTIRE cache — 100+ GB at 32k)
            qg = q.reshape(B, KV, rep, dh)
            s = jnp.einsum("bgrd,bsgd->bgrs", qg, kk,
                           preferred_element_type=jnp.float32)
            s = s / math.sqrt(dh)
            smask = jnp.arange(S)[None, None, None, :] <= pos
            s = jnp.where(smask, s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
            att = jnp.einsum("bgrs,bsgd->bgrd", p, vv)
            x = x + att.reshape(B, 1, H * dh) @ lp["wo"]
            h2 = rmsnorm(x, lp["ln2"])
            if cfg.moe:
                y = moe_ffn(h2.reshape(B, D), lp, cfg, mesh).reshape(B, 1, D)
            else:
                y = dense_ffn(h2, lp)
            return (x + y, li + 1), (kc, vc)

        if cfg.probe_layers is not None:
            nk, nv = [], []
            for i in range(cfg.probe_layers):
                li = i % cfg.n_layers
                lp = jax.tree.map(lambda a: a[li], params["layers"])
                (x, _), (kc, vc) = block(
                    (x, i), (lp, cache["k"][li], cache["v"][li]))
                nk.append(kc)
                nv.append(vc)
            logits = rmsnorm(x, params["ln_f"]) @ params["out_proj"]
            return logits[:, 0], {"k": jnp.stack(nk), "v": jnp.stack(nv)}
        (x, _), (new_k, new_v) = jax.lax.scan(
            block, (x, 0), (params["layers"], cache["k"], cache["v"]))
        logits = rmsnorm(x, params["ln_f"]) @ params["out_proj"]
        return logits[:, 0], {"k": new_k, "v": new_v}

    return serve_step


def make_cache_shape(cfg: LMConfig, batch: int, seq: int):
    KVdh = cfg.n_kv_heads * cfg.d_head
    sh = (cfg.n_layers, batch, seq, KVdh)
    return {"k": jax.ShapeDtypeStruct(sh, cfg.dtype),
            "v": jax.ShapeDtypeStruct(sh, cfg.dtype)}
