from .ops import (  # noqa: F401
    embedding_bag,
    segment_max,
    segment_mean,
    segment_softmax,
    segment_sum,
)
