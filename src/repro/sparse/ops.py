"""Sparse/ragged primitives JAX does not ship natively.

JAX has no EmbeddingBag and no CSR/CSC sparse (BCOO only), so message
passing and recsys lookups are built from gather + segment reductions —
these ARE part of the system, per the assignment brief.  Everything here is
jit/grad-compatible and shard_map-friendly (no data-dependent shapes; all
ragged structure is carried by explicit segment-id / mask arrays).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(data: jnp.ndarray, segment_ids: jnp.ndarray,
                num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data: jnp.ndarray, segment_ids: jnp.ndarray,
                num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_mean(data: jnp.ndarray, segment_ids: jnp.ndarray,
                 num_segments: int) -> jnp.ndarray:
    tot = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones_like(segment_ids, dtype=data.dtype),
                      segment_ids, num_segments)
    return tot / jnp.maximum(cnt, 1)[..., None] if data.ndim > 1 else (
        tot / jnp.maximum(cnt, 1))


def segment_softmax(logits: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Numerically-stable softmax within each segment (GAT edge-softmax)."""
    seg_max = jax.ops.segment_max(logits, segment_ids,
                                  num_segments=num_segments)
    ex = jnp.exp(logits - seg_max[segment_ids])
    den = segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(den[segment_ids], 1e-20)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  offsets: jnp.ndarray | None = None,
                  weights: jnp.ndarray | None = None,
                  mode: str = "sum") -> jnp.ndarray:
    """torch.nn.EmbeddingBag equivalent: gather rows then reduce per bag.

    Two calling conventions:
      * ``ids`` (B, L) fixed-size bags (use ``weights`` (B, L) as mask for
        ragged bags) -> (B, D);
      * ``ids`` (M,) flat with ``offsets`` (B,) bag starts -> (B, D).
    """
    if offsets is None:
        rows = table[ids]                        # (B, L, D)
        if weights is not None:
            rows = rows * weights[..., None]
        if mode == "sum":
            return rows.sum(axis=-2)
        if mode == "mean":
            if weights is None:
                return rows.mean(axis=-2)
            den = jnp.maximum(weights.sum(axis=-1, keepdims=True), 1e-9)
            return rows.sum(axis=-2) / den
        if mode == "max":
            return rows.max(axis=-2)
        raise ValueError(mode)
    # flat + offsets form: bag id per element via searchsorted
    m = ids.shape[0]
    bag = jnp.searchsorted(offsets, jnp.arange(m), side="right") - 1
    rows = table[ids]
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, bag, num_segments=offsets.shape[0])
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones((m,), table.dtype), bag,
                                  num_segments=offsets.shape[0])
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


def coalesce_edges(src: jnp.ndarray, dst: jnp.ndarray, n: int):
    """Sort edges by destination for locality (static shape, jit-safe)."""
    key = dst.astype(jnp.int64) * n + src
    order = jnp.argsort(key)
    return src[order], dst[order], order
