"""Family adapters: turn a model config + shape id into a lowerable cell.

Every architecture exposes ``ARCH.build(mesh, shape_id)`` returning a
``Cell``: the function to jit, its input ShapeDtypeStructs, in/out shardings,
and the analytic MODEL_FLOPS for the roofline's "useful fraction" metric.
The dry-run lowers ``jax.jit(cell.fn, in_shardings=...)`` against the
structs — no arrays are ever allocated for the full-size configs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (batch_axes, lm_param_rules,
                                        tree_shardings)
from repro.models import gnn as gnn_mod
from repro.models import lm as lm_mod
from repro.models import recsys as rec_mod
from repro.optim import adamw_init, adamw_update


@dataclass
class Cell:
    arch_id: str
    shape_id: str
    kind: str                    # train_step | serve_step | prefill | query
    fn: Callable
    args: tuple                  # ShapeDtypeStructs (pytrees allowed)
    in_shardings: Any
    model_flops: float
    notes: str = ""
    donate_argnums: tuple = ()
    # HLO cost_analysis counts while-loop bodies once; cells whose dominant
    # compute sits inside a chunking scan carry the trip count here and the
    # roofline reader scales flops/bytes/collectives by it.
    cost_scale: float = 1.0


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ==========================================================================
# LM family
# ==========================================================================

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


@dataclass
class LMArch:
    arch_id: str
    cfg: lm_mod.LMConfig
    family: str = "lm"
    shapes: tuple = tuple(LM_SHAPES)

    def flops(self, shape_id: str) -> float:
        s = LM_SHAPES[shape_id]
        cfg = self.cfg
        n_act = cfg.active_params_count
        if s["kind"] == "train":
            toks = s["seq"] * s["batch"]
            return 6.0 * n_act * toks
        if s["kind"] == "prefill":
            toks = s["seq"] * s["batch"]
            attn = (4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head
                    * s["seq"] * toks / 2)  # causal half
            return 2.0 * n_act * toks + attn
        # decode: one token per sequence against a seq-long cache
        toks = s["batch"]
        attn = 4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * s["seq"] * toks
        return 2.0 * n_act * toks + attn

    def build(self, mesh, shape_id: str, probe_layers: int | None = None
              ) -> Cell:
        s = LM_SHAPES[shape_id]
        cfg = self.cfg
        if probe_layers is not None:
            # §Roofline probe: unrolled loops, no grad-accum scan; FLOPs and
            # bytes extrapolate linearly in probe_layers (see dryrun.py)
            from dataclasses import replace
            half = max(256, s["seq"] // 2)
            cfg = replace(cfg, probe_layers=probe_layers, probe_unroll=True,
                          microbatch=1, q_chunk=half, kv_chunk=half,
                          loss_chunk=half, remat=False)
        rules = lm_param_rules(mesh)
        pshape = lm_mod.params_shape(cfg)
        pshard = tree_shardings(pshape, mesh, rules)
        dp = batch_axes(mesh)
        rep = NamedSharding(mesh, P())

        if s["kind"] == "train":
            opt_shape = jax.eval_shape(
                lambda p: adamw_init(p, state_dtype=cfg.opt_dtype), pshape)
            opt_shard = tree_shardings(opt_shape, mesh, rules)
            batch = {
                "tokens": jax.ShapeDtypeStruct((s["batch"], s["seq"]),
                                               jnp.int32),
                "labels": jax.ShapeDtypeStruct((s["batch"], s["seq"]),
                                               jnp.int32)}
            bshard = {k: NamedSharding(mesh, P(dp, None)) for k in batch}
            step = lm_mod.make_train_step(
                cfg, mesh, lambda p, g, st: adamw_update(p, g, st, 3e-4),
                param_shardings=pshard)
            return Cell(self.arch_id, shape_id, "train_step", step,
                        (pshape, opt_shape, batch),
                        (pshard, opt_shard, bshard), self.flops(shape_id),
                        donate_argnums=(0, 1) if probe_layers is None
                        else ())
        if s["kind"] == "prefill":
            tokens = jax.ShapeDtypeStruct((s["batch"], s["seq"]), jnp.int32)
            tshard = NamedSharding(mesh, P(dp, None))
            step = lm_mod.make_prefill_step(cfg, mesh)
            return Cell(self.arch_id, shape_id, "serve_step", step,
                        (pshape, tokens), (pshard, tshard),
                        self.flops(shape_id))
        # decode: serve_step(params, cache, token, pos)
        cache = lm_mod.make_cache_shape(cfg, s["batch"], s["seq"])
        if s["batch"] >= mesh.devices.size // mesh.shape["model"]:
            cspec = P(None, dp, None, "model")   # batch-sharded cache
            tokspec = P(dp)
        else:
            cspec = P(None, None, dp, "model")   # sequence-sharded cache
            tokspec = P()
        cshard = {k: NamedSharding(mesh, cspec) for k in cache}
        token = jax.ShapeDtypeStruct((s["batch"],), jnp.int32)
        serve = lm_mod.make_serve_step(cfg, mesh)
        pos = s["seq"] - 1

        def step(params, cache_, token_):
            return serve(params, cache_, token_, pos)

        return Cell(self.arch_id, shape_id, "serve_step", step,
                    (pshape, cache, token),
                    (pshard, cshard, NamedSharding(mesh, tokspec)),
                    self.flops(shape_id),
                    donate_argnums=(1,) if probe_layers is None else ())


# ==========================================================================
# GNN family (SchNet)
# ==========================================================================

def _pad512(n: int) -> int:
    """Round node/edge counts up to 512 (mesh divisibility; masked anyway)."""
    return (n + 511) // 512 * 512


GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                          classify=47, kind="train"),
    "minibatch_lg": dict(n_nodes=184320, n_edges=179200, d_feat=602,
                         classify=41, kind="train"),
    "ogb_products": dict(n_nodes=2449029, n_edges=61859140, d_feat=100,
                         classify=47, kind="train"),
    "molecule": dict(n_nodes=3840, n_edges=8192, d_feat=16, classify=0,
                     n_graphs=128, kind="train"),
}


@dataclass
class GNNArch:
    arch_id: str
    base_cfg: gnn_mod.SchNetConfig
    family: str = "gnn"
    shapes: tuple = tuple(GNN_SHAPES)

    def cfg_for(self, shape_id: str) -> gnn_mod.SchNetConfig:
        s = GNN_SHAPES[shape_id]
        from dataclasses import replace
        e_pad = _pad512(s["n_edges"])
        # chunk the cfconv at >4M edges (ogb_products: 74 GB rbf otherwise)
        chunk = e_pad // 16 if e_pad > (1 << 22) else None
        return replace(self.base_cfg, d_feat=s["d_feat"],
                       n_out=(s["classify"] or 1), edge_chunk=chunk)

    def flops(self, shape_id: str) -> float:
        s = GNN_SHAPES[shape_id]
        c = self.base_cfg
        e, n, dh, nr = s["n_edges"], s["n_nodes"], c.d_hidden, c.n_rbf
        per_layer = 2.0 * e * (nr * dh + dh * dh) + 2.0 * n * 2 * dh * dh
        proj = 2.0 * n * s["d_feat"] * dh
        fb = 3.0  # fwd + bwd
        return fb * (c.n_interactions * per_layer + proj)

    def build(self, mesh, shape_id: str) -> Cell:
        s = GNN_SHAPES[shape_id]
        cfg = self.cfg_for(shape_id)
        # §Perf H2 (same as recsys): edges/nodes shard over the whole mesh —
        # SchNet has no tensor dim for the "model" axis (d_hidden=64).
        dp = tuple(a for a in ("pod", "data", "model")
                   if a in mesh.axis_names)
        specs = gnn_mod.input_specs(cfg, _pad512(s["n_nodes"]),
                                    _pad512(s["n_edges"]),
                                    n_graphs=s.get("n_graphs", 1),
                                    classify=bool(s["classify"]))
        eshard = NamedSharding(mesh, P(dp))
        nshard = NamedSharding(mesh, P(dp))
        shardmap = {
            "node_feat": NamedSharding(mesh, P(dp, None)),
            "src": eshard, "dst": eshard, "dist": eshard,
            "edge_mask": eshard, "node_mask": nshard,
            "labels": nshard, "graph_ids": nshard,
            "target": NamedSharding(mesh, P()),
        }
        bshard = {k: shardmap[k] for k in specs}
        pshape = jax.eval_shape(
            lambda: gnn_mod.init_params(cfg, jax.random.PRNGKey(0)))
        pshard = jax.tree.map(lambda _: NamedSharding(mesh, P()), pshape)
        opt_shape = jax.eval_shape(adamw_init, pshape)
        opt_shard = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 opt_shape)
        step = gnn_mod.make_train_step(
            cfg, mesh, lambda p, g, st: adamw_update(p, g, st, 1e-3),
            n_graphs=s.get("n_graphs", 1))
        e_pad = _pad512(s["n_edges"])
        n_chunks = (e_pad // cfg.edge_chunk) if cfg.edge_chunk else 1
        return Cell(self.arch_id, shape_id, "train_step", step,
                    (pshape, opt_shape, specs),
                    (pshard, opt_shard, bshard), self.flops(shape_id),
                    donate_argnums=(0, 1), cost_scale=float(n_chunks),
                    notes="edge-chunked cfconv" if n_chunks > 1 else "")


# ==========================================================================
# RecSys family
# ==========================================================================

REC_SHAPES = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieve"),
}


@dataclass
class RecsysArch:
    arch_id: str
    cfg: Any
    kind: str                    # dlrm | sasrec | din | twotower
    family: str = "recsys"
    shapes: tuple = tuple(REC_SHAPES)

    # ---- batch spec builders per model ------------------------------------

    def _batch_specs(self, B: int, serve: bool = False):
        S = jax.ShapeDtypeStruct
        f32, i32 = jnp.float32, jnp.int32
        c = self.cfg
        if self.kind == "dlrm":
            sp = {"dense": S((B, c.n_dense), f32),
                  "sparse": S((B, len(c.table_rows)), i32)}
            if not serve:
                sp["label"] = S((B,), f32)
            return sp
        if self.kind == "sasrec":
            sp = {"seq": S((B, c.seq_len), i32)}
            if serve:
                sp["cands"] = S((B, 100), i32)
            else:
                sp.update(pos=S((B, c.seq_len), i32),
                          neg=S((B, c.seq_len), i32),
                          seq_mask=S((B, c.seq_len), f32))
            return sp
        if self.kind == "din":
            sp = {"history": S((B, c.seq_len), i32),
                  "hist_mask": S((B, c.seq_len), f32),
                  "target": S((B,), i32)}
            if not serve:
                sp["label"] = S((B,), f32)
            return sp
        if self.kind == "twotower":
            sp = {"user_feats": S((B, c.n_user_feats), i32),
                  "user_mask": S((B, c.n_user_feats), f32),
                  "item": S((B,), i32)}
            if not serve:
                sp.update(logq=S((B,), f32))
            return sp
        raise ValueError(self.kind)

    def _loss_and_serve(self, mesh):
        c = self.cfg
        if self.kind == "dlrm":
            return (lambda p, b: rec_mod.dlrm_loss(p, b, c, mesh),
                    lambda p, b: rec_mod.dlrm_forward(p, b, c, mesh))
        if self.kind == "sasrec":
            return (lambda p, b: rec_mod.sasrec_loss(p, b, c, mesh),
                    lambda p, b: rec_mod.sasrec_serve(p, b, c, mesh))
        if self.kind == "din":
            return (lambda p, b: rec_mod.din_loss(p, b, c, mesh),
                    lambda p, b: rec_mod.din_forward(p, b, c, mesh))
        if self.kind == "twotower":
            return (lambda p, b: rec_mod.twotower_loss(p, b, c, mesh),
                    lambda p, b: rec_mod.twotower_serve(p, b, c, mesh))
        raise ValueError(self.kind)

    def _init(self, key):
        c = self.cfg
        return {"dlrm": rec_mod.dlrm_init, "sasrec": rec_mod.sasrec_init,
                "din": rec_mod.din_init,
                "twotower": rec_mod.twotower_init}[self.kind](c, key)

    def _pshard(self, pshape, mesh):
        """Embedding tables row-shard over the whole mesh; MLPs replicate."""
        all_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)

        def pick(path, leaf):
            name = "/".join(str(getattr(k, "key", k)) for k in path)
            if ("table" in name or "embed" in name) and leaf.ndim == 2 \
                    and leaf.shape[0] > 100_000:
                return NamedSharding(mesh, P(all_axes, None))
            return NamedSharding(mesh, P())

        flat, tdef = jax.tree_util.tree_flatten_with_path(pshape)
        return jax.tree_util.tree_unflatten(
            tdef, [pick(p, l) for p, l in flat])

    def flops(self, shape_id: str) -> float:
        s = REC_SHAPES[shape_id]
        c = self.cfg
        B = s["batch"]
        if self.kind == "dlrm":
            bot = sum(2 * i * o for i, o in zip(
                (c.n_dense, *c.bot_mlp[:-1]), c.bot_mlp))
            n = len(c.table_rows) + 1
            inter = 2 * n * n * c.embed_dim
            top_in = c.embed_dim + n * (n - 1) // 2
            top = sum(2 * i * o for i, o in zip(
                (top_in, *c.top_mlp[:-1]), c.top_mlp))
            per = bot + inter + top
        elif self.kind == "sasrec":
            D, S = c.embed_dim, c.seq_len
            per = c.n_blocks * (2 * S * 3 * D * D + 4 * S * S * D
                                + 2 * S * 2 * D * D)
        elif self.kind == "din":
            D, L = c.embed_dim, c.seq_len
            attn = sum(2 * i * o for i, o in zip(
                (4 * D, *c.attn_mlp), (*c.attn_mlp, 1)))
            mlp = sum(2 * i * o for i, o in zip(
                (2 * D, *c.mlp), (*c.mlp, 1)))
            per = L * attn + mlp + 2 * L * D
        else:  # twotower
            D = c.embed_dim
            tower = sum(2 * i * o for i, o in zip(
                (D, *c.tower_mlp[:-1]), c.tower_mlp))
            per = 2 * tower
        mult = 3.0 if s["kind"] == "train" else 1.0
        flops = mult * B * per
        if self.kind == "twotower" and s["kind"] == "train":
            # in-batch sampled softmax: the (B, B) logits matmul dominates
            flops += mult * 2.0 * B * B * self.cfg.tower_mlp[-1]
        if s["kind"] == "retrieve":
            C = s["n_candidates"]
            if self.kind == "twotower":
                tower = sum(2 * i * o for i, o in zip(
                    (self.cfg.embed_dim, *self.cfg.tower_mlp[:-1]),
                    self.cfg.tower_mlp))
                flops = C * tower + 2 * C * self.cfg.tower_mlp[-1]
            else:
                flops = per * C
        return float(flops)

    def build(self, mesh, shape_id: str) -> Cell:
        s = REC_SHAPES[shape_id]
        # Perf iteration (EXPERIMENTS.md §Perf H2): recsys models have no
        # tensor dimension worth sharding on "model", so the batch shards
        # over the WHOLE mesh — before this the model axis replicated all
        # MLP compute 16x (useful-compute ratio 0.06 -> ~1).
        dp = tuple(a for a in ("pod", "data", "model")
                   if a in mesh.axis_names)
        loss_fn, serve_fn = self._loss_and_serve(mesh)
        pshape = jax.eval_shape(
            lambda: self._init(jax.random.PRNGKey(0)))
        pshard = self._pshard(pshape, mesh)
        if s["kind"] == "train":
            B = s["batch"]
            specs = self._batch_specs(B)
            bshard = {k: NamedSharding(mesh, P(dp, *(None,) * (v.ndim - 1)))
                      for k, v in specs.items()}
            opt_shape = jax.eval_shape(adamw_init, pshape)
            opt_shard = adamw_like_shardings(pshape, pshard)
            step = rec_mod.make_train_step(
                loss_fn, lambda p, g, st: adamw_update(p, g, st, 1e-3))
            return Cell(self.arch_id, shape_id, "train_step", step,
                        (pshape, opt_shape, specs),
                        (pshard, opt_shard, bshard), self.flops(shape_id),
                        donate_argnums=(0, 1))
        if s["kind"] == "serve":
            B = s["batch"]
            specs = self._batch_specs(B, serve=True)
            bshard = {k: NamedSharding(mesh, P(dp, *(None,) * (v.ndim - 1)))
                      for k, v in specs.items()}
            return Cell(self.arch_id, shape_id, "serve_step", serve_fn,
                        (pshape, specs), (pshard, bshard),
                        self.flops(shape_id))
        # retrieval_cand (candidate count padded for mesh divisibility)
        C = _pad512(s["n_candidates"])
        Sd = jax.ShapeDtypeStruct
        if self.kind == "twotower":
            from repro.models.recsys import twotower_retrieve
            specs = {"user_feats": Sd((1, self.cfg.n_user_feats), jnp.int32),
                     "user_mask": Sd((1, self.cfg.n_user_feats), jnp.float32),
                     "cand_ids": Sd((C,), jnp.int32)}
            bshard = {"user_feats": NamedSharding(mesh, P()),
                      "user_mask": NamedSharding(mesh, P()),
                      "cand_ids": NamedSharding(
                          mesh, P(tuple(a for a in ("pod", "data", "model")
                                        if a in mesh.axis_names)))}
            fn = lambda p, b: twotower_retrieve(p, b, self.cfg, mesh)
        else:
            # score C candidate targets for one user context.  Chunked over
            # candidates (python-unrolled: exact HLO costs): the row-sharded
            # embedding gather otherwise replicates a (C, ...) intermediate
            # on every device (observed 25 GiB on dlrm).
            specs = self._retrieval_specs(C)
            bshard = {k: NamedSharding(
                mesh, P(dp, *(None,) * (v.ndim - 1)) if v.shape[0] == C
                else P()) for k, v in specs.items()}
            cost_scale = 1.0
            if self.kind == "sasrec":
                fn = serve_fn  # candidates ride dim 1; no big gather
            else:
                # lax.scan over candidate chunks: a while loop is the only
                # construct the scheduler provably serializes (an unrolled
                # python loop — even with optimization_barrier chains — left
                # all 16 replicated chunk gathers live at once).
                n_chunks = 16
                cost_scale = float(n_chunks)

                def fn(p, b, _serve=serve_fn, _C=C, _n=n_chunks):
                    sz = _C // _n
                    big = {k: v.reshape(_n, sz, *v.shape[1:])
                           for k, v in b.items() if v.shape[0] == _C}
                    small = {k: v for k, v in b.items() if v.shape[0] != _C}

                    def body(_, sl):
                        return None, _serve(p, {**sl, **small})

                    _, outs = jax.lax.scan(body, None, big)
                    return outs.reshape(-1)

            return Cell(self.arch_id, shape_id, "serve_step", fn,
                        (pshape, specs), (pshard, bshard),
                        self.flops(shape_id), cost_scale=cost_scale,
                        notes="chunked candidate scoring"
                        if cost_scale > 1 else "")
        return Cell(self.arch_id, shape_id, "serve_step", fn,
                    (pshape, specs), (pshard, bshard), self.flops(shape_id))

    def _retrieval_specs(self, C: int):
        S = jax.ShapeDtypeStruct
        f32, i32 = jnp.float32, jnp.int32
        c = self.cfg
        if self.kind == "dlrm":
            return {"dense": S((C, c.n_dense), f32),
                    "sparse": S((C, len(c.table_rows)), i32)}
        if self.kind == "sasrec":
            return {"seq": S((1, c.seq_len), i32), "cands": S((1, C), i32)}
        if self.kind == "din":
            return {"history": S((C, c.seq_len), i32),
                    "hist_mask": S((C, c.seq_len), f32),
                    "target": S((C,), i32)}
        raise ValueError(self.kind)


def adamw_like_shardings(pshape, pshard):
    """AdamW state shardings: mu/nu mirror the param shardings."""
    from repro.optim.adamw import AdamWState
    rep = jax.tree.map(lambda s: s, pshard)
    first = jax.tree.leaves(pshard)[0]
    scalar = type(first)(first.mesh, P()) if hasattr(first, "mesh") else first
    return AdamWState(step=scalar, mu=rep, nu=jax.tree.map(lambda s: s, rep))
