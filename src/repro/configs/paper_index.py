"""paper-index: the paper's own architecture — the distributed
immediate-access dynamic index (document-partitioned shard_map query engine,
DESIGN.md §4).  These cells are EXTRA beyond the 40 assigned ones; the
``query_rank`` cell is the "most representative of the paper's technique"
hillclimb target of EXPERIMENTS.md §Perf.

Production sizing per device shard: 2^20 Const-64 blocks (64 MiB of index,
≈ 30M postings at the paper's ~2.1 B/posting), 2^17 vocabulary terms, 2^20
documents; a batch of 256 conjunctive/ranked queries of up to 8 terms is
sharded over the "model" axis while the index shards over ("pod","data").
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.configs.common import Cell
from repro.core.sharded_index import (make_sharded_query_step,
                                      sharded_input_specs)

INDEX_SHAPES = {
    # (blocks/shard, vocab/shard, docs/shard, qbatch, qterms, max_blocks)
    "query_rank": dict(shard_blocks=1 << 20, vocab=1 << 17, docs=1 << 20,
                       qbatch=256, qterms=8, max_blocks=64),
    "query_rank_hot": dict(shard_blocks=1 << 18, vocab=1 << 15,
                           docs=1 << 18, qbatch=1024, qterms=4,
                           max_blocks=32),
    # conjunctive Boolean (the paper's §4.6 headline mode): hit bitmaps stay
    # sharded; the only collective is the per-query count psum
    "query_conj": dict(shard_blocks=1 << 20, vocab=1 << 17, docs=1 << 20,
                       qbatch=256, qterms=4, max_blocks=64,
                       mode="conjunctive"),
}


@dataclass
class IndexArch:
    arch_id: str = "paper-index"
    family: str = "index"
    shapes: tuple = tuple(INDEX_SHAPES)

    def flops(self, shape_id: str) -> float:
        # The index workload is integer/memory bound: "useful work" is the
        # decoded-postings volume. We count 2 int-ops per payload byte
        # (shift+or) plus the score multiply-accumulate per posting.
        s = INDEX_SHAPES[shape_id]
        blocks_touched = s["qbatch"] * s["qterms"] * s["max_blocks"]
        payload = blocks_touched * 64
        return float(2 * payload + 2 * blocks_touched * 30)

    def build(self, mesh, shape_id: str, decode_fn=None,
              mode: str | None = None) -> Cell:
        """``mode='ranked'`` is the paper-faithful dense-accumulator scorer
        (the §Perf H1 baseline); ``ranked_sparse`` is the optimized sort-
        based aggregation (default after H1); ``conjunctive`` is the
        Boolean mode (shape query_conj)."""
        s = INDEX_SHAPES[shape_id]
        if mode is None:
            mode = s.get("mode", "ranked_sparse")
        fn, ins, outs = make_sharded_query_step(
            mesh, k=10, max_blocks=s["max_blocks"], num_docs=s["docs"],
            decode_fn=decode_fn, mode=mode)
        args = sharded_input_specs(
            mesh, shard_blocks=s["shard_blocks"], B=64, vocab=s["vocab"],
            qbatch=s["qbatch"], qterms=s["qterms"])
        return Cell("paper-index", shape_id, "query_step", fn, args, ins,
                    self.flops(shape_id),
                    notes=f"document-partitioned query fusion [{mode}]")


ARCH = IndexArch()
