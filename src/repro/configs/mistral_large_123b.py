"""mistral-large-123b [dense]: 88L d=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified]

At 123B params x 256 chips the HBM budget forces the full memory toolkit:
microbatch=16 grad accumulation, sequence-parallel boundary activations,
bf16 AdamW moments (PaLM-style), recursive flash-attention remat."""

import jax.numpy as jnp

from repro.configs.common import LMArch
from repro.models.lm import LMConfig

ARCH = LMArch(
    arch_id="mistral-large-123b",
    cfg=LMConfig(
        name="mistral-large-123b",
        n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=28672, vocab=32768, d_head=128,
        # §Perf H3: dmodel boundaries (the default) cut FSDP weight-gather
        # traffic 6.5x/pass, freeing memory to halve the microbatch count
        # (16 -> 8): predicted step collective time 536s -> 233s.
        microbatch=8, q_chunk=256, kv_chunk=1024, loss_chunk=256,
        opt_dtype=jnp.bfloat16,
    ))
