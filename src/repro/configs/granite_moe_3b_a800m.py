"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base;
hf]"""

from repro.configs.common import LMArch
from repro.models.lm import LMConfig, MoEConfig

ARCH = LMArch(
    arch_id="granite-moe-3b-a800m",
    cfg=LMConfig(
        name="granite-moe-3b-a800m",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155, d_head=64,
        moe=MoEConfig(n_experts=40, top_k=8),
        microbatch=2, q_chunk=512, kv_chunk=1024, loss_chunk=512,
    ))
