"""dlrm-mlperf [recsys]: 13 dense + 26 sparse, embed_dim=128,
bot 13-512-256-128, top 1024-1024-512-256-1, dot interaction (MLPerf Criteo
1TB row counts, 40M cap).  [arXiv:1906.00091; paper]"""

from repro.configs.common import RecsysArch
from repro.data.recsys import CRITEO_TABLE_ROWS
from repro.models.recsys import DLRMConfig

ARCH = RecsysArch(
    arch_id="dlrm-mlperf", kind="dlrm",
    cfg=DLRMConfig(
        name="dlrm-mlperf", table_rows=tuple(CRITEO_TABLE_ROWS),
        embed_dim=128, n_dense=13, bot_mlp=(512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1)))
