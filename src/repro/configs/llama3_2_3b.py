"""llama3.2-3b [dense]: 28L d=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.configs.common import LMArch
from repro.models.lm import LMConfig

ARCH = LMArch(
    arch_id="llama3.2-3b",
    cfg=LMConfig(
        name="llama3.2-3b",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, d_head=128,
        microbatch=2, q_chunk=512, kv_chunk=1024, loss_chunk=512,
    ))
