"""din [recsys]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80,
target attention.  [arXiv:1706.06978; paper]"""

from repro.configs.common import RecsysArch
from repro.models.recsys import DINConfig

ARCH = RecsysArch(
    arch_id="din", kind="din",
    # n_items padded 1e6 -> 512-multiple for whole-mesh row sharding
    cfg=DINConfig(name="din", n_items=1_000_448, embed_dim=18, seq_len=100,
                  attn_mlp=(80, 40), mlp=(200, 80)))
