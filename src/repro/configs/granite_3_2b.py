"""granite-3-2b [dense]: 40L d=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.configs.common import LMArch
from repro.models.lm import LMConfig

ARCH = LMArch(
    arch_id="granite-3-2b",
    cfg=LMConfig(
        name="granite-3-2b",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab=49155, d_head=64,
        microbatch=2, q_chunk=512, kv_chunk=1024, loss_chunk=512,
    ))
