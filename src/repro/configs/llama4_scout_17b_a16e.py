"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 (early-fusion backbone; modality frontend
stubbed per brief).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.common import LMArch
from repro.models.lm import LMConfig, MoEConfig

ARCH = LMArch(
    arch_id="llama4-scout-17b-a16e",
    cfg=LMConfig(
        name="llama4-scout-17b-a16e",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, d_head=128,
        moe=MoEConfig(n_experts=16, top_k=1),
        microbatch=4, q_chunk=512, kv_chunk=1024, loss_chunk=512,
    ))
