"""sasrec [recsys]: embed_dim=50 n_blocks=2 n_heads=1 seq_len=50,
self-attentive sequential recommendation.  [arXiv:1808.09781; paper]"""

from repro.configs.common import RecsysArch
from repro.models.recsys import SASRecConfig

ARCH = RecsysArch(
    arch_id="sasrec", kind="sasrec",
    # n_items padded 1e6 -> 512-multiple for whole-mesh row sharding
    cfg=SASRecConfig(name="sasrec", n_items=1_000_448, embed_dim=50,
                     n_blocks=2, n_heads=1, seq_len=50))
