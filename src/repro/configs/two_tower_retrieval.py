"""two-tower-retrieval [recsys]: embed_dim=256 tower 1024-512-256, dot
interaction, sampled-softmax retrieval.  [RecSys'19 (YouTube); unverified]

This is the architecture the paper's technique plugs into directly: the
immediate-access dynamic index is the lexical candidate generator feeding the
dense dot-scoring stage (see examples/hybrid_retrieval.py)."""

from repro.configs.common import RecsysArch
from repro.models.recsys import TwoTowerConfig

ARCH = RecsysArch(
    arch_id="two-tower-retrieval", kind="twotower",
    # vocabularies padded 2e6 -> 512-multiple for whole-mesh row sharding
    cfg=TwoTowerConfig(name="two-tower-retrieval", n_users_vocab=2_000_384,
                       n_items=2_000_384, embed_dim=256,
                       tower_mlp=(1024, 512, 256), n_user_feats=8))
