"""schnet [gnn]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10.
[arXiv:1706.08566; paper]"""

from repro.configs.common import GNNArch
from repro.models.gnn import SchNetConfig

ARCH = GNNArch(
    arch_id="schnet",
    base_cfg=SchNetConfig(
        name="schnet", n_interactions=3, d_hidden=64, n_rbf=300,
        cutoff=10.0))
