"""Architecture registry: ``--arch <id>`` resolves here."""

from importlib import import_module

ARCH_IDS = [
    "llama4_scout_17b_a16e",
    "granite_moe_3b_a800m",
    "granite_3_2b",
    "llama3_2_3b",
    "mistral_large_123b",
    "schnet",
    "dlrm_mlperf",
    "sasrec",
    "din",
    "two_tower_retrieval",
    "paper_index",
]

ALIASES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "granite-3-2b": "granite_3_2b",
    "llama3.2-3b": "llama3_2_3b",
    "mistral-large-123b": "mistral_large_123b",
    "dlrm-mlperf": "dlrm_mlperf",
    "two-tower-retrieval": "two_tower_retrieval",
}


def get_arch(arch_id: str):
    mod_name = ALIASES.get(arch_id, arch_id).replace("-", "_").replace(".", "_")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return import_module(f"repro.configs.{mod_name}").ARCH
