"""Engine query/result types shared by the planner and every backend."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

#: Query modes every backend must agree on (identical results up to float
#: tolerance — enforced by the differential test matrix in tests/test_engine.py).
MODES = ("conjunctive", "ranked_tfidf", "bm25", "phrase", "proximity",
         "bm25_prox")

#: Modes that consume word positions: they require a word-level index and
#: run only on the backends that model positions (host / tiered) — forcing
#: them onto the device or Pallas backends raises.
POSITIONAL_MODES = ("phrase", "proximity", "bm25_prox")

#: Backends a query may force via ``Query.backend``.
BACKENDS = ("host", "device", "pallas", "tiered")


@dataclass(frozen=True)
class Query:
    """One term-based query.

    ``mode`` is one of :data:`MODES`; ``k`` bounds ranked result size
    (ignored for boolean modes); ``window`` is the proximity span in words
    (required for ``mode="proximity"``, disallowed elsewhere — keeping it
    out of non-proximity queries means equal queries stay equal, which the
    serving layer's result-cache key relies on); ``backend`` forces a
    specific backend for this query, overriding the planner (raises if that
    backend cannot run the query, rather than silently falling back).
    """

    terms: tuple[str, ...]
    mode: str = "conjunctive"
    k: int = 10
    window: int | None = None
    backend: str | None = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown query mode {self.mode!r}; "
                             f"expected one of {MODES}")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")
        if self.k < 1:
            # k=0 slices diverge across backends (nz[-0:] keeps everything
            # host-side, top_k keeps nothing) — reject rather than diverge
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.mode == "proximity":
            if self.window is None or self.window < 1:
                raise ValueError("proximity queries need window >= 1, got "
                                 f"{self.window!r}")
        elif self.window is not None:
            raise ValueError(
                f"window only applies to proximity queries, not {self.mode!r}")
        object.__setattr__(self, "terms", tuple(self.terms))


@dataclass
class QueryResult:
    """Backend-independent result: docids ascending for boolean modes,
    descending-score order for ranked modes (``scores`` is None for boolean
    modes).  ``backend``/``reason`` record the planner's routing decision for
    introspection and benchmarks."""

    docids: np.ndarray
    scores: np.ndarray | None = None
    backend: str = "host"
    reason: str = ""

    def __len__(self) -> int:
        return len(self.docids)


from ..core.query import CollectionStats  # noqa: E402  (re-export: the
#   fleet-wide ranking statistics a document-partitioned shard scores with)
from ..core.query import TermStats  # noqa: E402  (re-export for planner)


@dataclass
class EngineStats:
    """Counters surfaced by ``Engine.stats()`` (serving observability)."""

    num_docs: int = 0         # ordinal docid horizon (includes tombstoned)
    deleted_docs: int = 0     # tombstoned docids still masked at serve time
    tombstones_compacted: int = 0  # dead docids dropped from the static
    #                                tier by freeze-time compaction (total
    #                                across all freezes)
    num_postings: int = 0
    num_words: int = 0        # total tokens ingested (= postings, word-level)
    vocab_size: int = 0
    queries: int = 0
    query_batches: int = 0    # execute_many calls (latency denominator)
    query_time_s: float = 0.0  # wall-clock inside execute_many (plan+run)
    ingest_docs: int = 0      # documents ingested (add_document(s))
    ingest_batches: int = 0   # ingest calls (mirror of query_batches: a
    #                           single add_document counts as a batch of 1)
    ingest_time_s: float = 0.0  # wall-clock inside ingest (tokenize+append
    #                             +bookkeeping; excludes queue wait in the
    #                             pipelined path — writer-thread time only)
    collations: int = 0
    delta_refreshes: int = 0
    delta_compactions: int = 0  # refreshes that hit the fragmentation
    #                             threshold and collated instead
    resident_uploads: int = 0   # full device-image uploads (1 per freeze)
    freezes: int = 0          # static-tier freezes completed (lifecycle)
    tier_epoch: int = 0       # epoch of the published static tier (for a
    #                           sharded fleet: the composite epoch — the
    #                           sum over shards, bumping on any tier swap)
    num_shards: int = 0       # 0 = single engine; >0 = sharded composite
    by_backend: dict = field(default_factory=dict)
