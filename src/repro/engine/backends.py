"""Host, Pallas, and tiered execution backends.

Host and Pallas operate directly on the live :class:`~repro.core.index.
DynamicIndex` (immediate access is inherited for free); the device backend,
which needs an image refresh protocol, lives in
:mod:`repro.engine.device_backend`; the tiered backend serves the frozen
docid prefix from the compressed :class:`~repro.core.static_index.
StaticIndex` tier published by the lifecycle (:mod:`repro.core.lifecycle`)
and only reads the dynamic index past the tier horizon.
"""

from __future__ import annotations

import numpy as np

from ..core import query as hostq
from ..core.index import group_occurrences
from ..kernels import registry
from .types import Query, QueryResult


class UnsupportedQueryError(ValueError):
    """Raised when a forced backend cannot execute the query."""


class Backend:
    """Interface: ``execute_many`` over the engine's live state."""

    name = "base"

    def __init__(self, engine):
        self.engine = engine

    def execute_many(self, queries: list[Query]) -> list[QueryResult]:
        return [self.execute(q) for q in queries]

    def execute(self, query: Query) -> QueryResult:
        raise NotImplementedError


class HostBackend(Backend):
    """The paper-faithful numpy path: DAAT cursors with seek_GEQ skipping
    for boolean queries, vectorized TAAT for ranked modes (core/query.py)."""

    name = "host"

    def execute(self, query: Query) -> QueryResult:
        eng = self.engine
        idx = eng.index
        stats = eng.ranking_stats()   # fleet-wide (N, f_t, avgdl) or None
        if query.mode == "conjunctive":
            d = hostq.conjunctive_query(idx, query.terms)
            return QueryResult(d, None, self.name)
        if query.mode == "ranked_tfidf":
            d, s = hostq.ranked_disjunctive_taat(idx, query.terms, k=query.k,
                                                 stats=stats)
            return QueryResult(d, s, self.name)
        if query.mode == "bm25":
            d, s = hostq.ranked_bm25(idx, query.terms, eng.doclens_array(),
                                     k=query.k, stats=stats)
            return QueryResult(d, s, self.name)
        if query.mode == "phrase":
            if not idx.word_level:
                raise UnsupportedQueryError(
                    "phrase queries need a word-level index (§5.1)")
            d = hostq.phrase_query(idx, query.terms)
            return QueryResult(d, None, self.name)
        if query.mode == "proximity":
            if not idx.word_level:
                raise UnsupportedQueryError(
                    "proximity queries need a word-level index (§5.1)")
            d = hostq.proximity_query(idx, query.terms, query.window)
            return QueryResult(d, None, self.name)
        if query.mode == "bm25_prox":
            if not idx.word_level:
                raise UnsupportedQueryError(
                    "bm25_prox queries need a word-level index (§5.1)")
            d, s = hostq.ranked_bm25_prox(idx, query.terms,
                                          eng.doclens_array(), k=query.k,
                                          stats=stats)
            return QueryResult(d, s, self.name)
        raise UnsupportedQueryError(f"unknown mode {query.mode!r}")


class TieredView:
    """Index-like facade over static tier + dynamic suffix (disjoint ranges).

    ``postings(term)`` concatenates the tier's compressed list (all docids
    <= ``horizon``) with the dynamic postings strictly past the horizon —
    read via a ``PostingsCursor`` sought to ``horizon + 1``, so the frozen
    prefix of the live chains is skipped block-at-a-time, never decoded.
    Because docids are ordinal and append-only, the concatenation equals the
    full dynamic list exactly; feeding this view to the host TAAT scorers
    (which take any object with ``num_docs``/``postings``) therefore yields
    results byte-identical to the host backend, while the bulk of each list
    is served from its most compressed form.

    Word-level engines get the same guarantees at occurrence granularity:
    ``postings`` concatenates occurrence streams (docids repeat, payload =
    w-gap) and ``cursor`` chains document-granular POSITIONAL cursors — a
    :class:`~repro.core.static_index.StaticWordCursor` over the tier with a
    :class:`~repro.core.query.WordPostingsCursor` over the suffix — so
    phrase evaluation never materializes either tier.  A document's
    occurrences never straddle the horizon (each document's postings are
    written before the next document starts), which is what makes the
    per-document position lists exact across the chain.
    """

    def __init__(self, engine, tier):
        self.engine = engine
        self.tier = tier                      # StaticTier | None
        self.horizon = 0 if tier is None else tier.num_docs

    @property
    def num_docs(self) -> int:
        return self.engine.index.num_docs

    @property
    def word_level(self) -> bool:
        return self.engine.index.word_level

    @property
    def tombstones(self) -> set:
        """The live tombstone set — deleted docids are masked across BOTH
        tiers (the static tier may still hold docs tombstoned after its
        freeze; the next encode compacts them away)."""
        return self.engine.index.tombstones

    def ft(self, term) -> int:
        """f_t with the dynamic index's semantics, from the engine's O(1)
        global counters (operator-ordering heuristics, e.g. the proximity
        rarest-first lead, read this — never a chain walk)."""
        tid = self.engine.term_id(term)
        return self.engine._fts[tid] if tid is not None else 0

    def suffix_postings(self, term) -> tuple[np.ndarray, np.ndarray]:
        """Dynamic postings with docid > horizon (cursor-skipped prefix)."""
        idx = self.engine.index
        h = idx.lookup(term)
        if h is None:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        c = hostq.PostingsCursor(idx.store, h)
        if not c.seek_geq(self.horizon + 1):
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        ds, fs = [], []
        while True:
            ds.append(c.docid)
            fs.append(c.payload)
            if not c.next():
                break
        return (np.asarray(ds, dtype=np.int64),
                np.asarray(fs, dtype=np.int64))

    def postings(self, term) -> tuple[np.ndarray, np.ndarray]:
        d2, f2 = self.suffix_postings(term)
        if self.tier is None:
            return d2, f2
        d1, f1 = self.tier.index.postings(term)
        if len(d1) == 0:
            return d2, f2
        return np.concatenate([d1, d2]), np.concatenate([f1, f2])

    def doc_postings(self, term) -> tuple[np.ndarray, np.ndarray]:
        """Document-granular postings across both tiers: (unique docids,
        doc-level f_{t,d}) — what the ranked scorers consume.

        The frozen prefix comes from ``StaticIndex.doc_postings`` (docid +
        count streams only; the w-gap stream is never decoded), the suffix
        from grouping the cursor-skipped occurrence stream of
        ``suffix_postings``.  Documents never straddle the horizon, so
        concatenation is exact — identical arrays to grouping the full
        dynamic stream."""
        if not self.engine.index.word_level:
            return self.postings(term)
        docc, _wg = self.suffix_postings(term)
        d2, f2 = group_occurrences(docc)
        if self.tier is None:
            return d2, f2
        d1, f1 = self.tier.index.doc_postings(term)
        if len(d1) == 0:
            return d2, f2
        return np.concatenate([d1, d2]), np.concatenate([f1, f2])

    def cursor(self, term):
        """One chained DAAT cursor across both tiers (None = no postings).

        Word-level indexes chain positional, document-granular cursors
        (payload = f_{t,d}, ``positions()`` live), ready for both the
        conjunctive and the phrase operators."""
        parts = []
        if self.tier is not None:
            parts.append(self.tier.index.postings_iter(term))
        idx = self.engine.index
        h = idx.lookup(term)
        if h is not None:
            c = hostq.PostingsCursor(idx.store, h)
            if self.horizon == 0 or c.seek_geq(self.horizon + 1):
                parts.append(hostq.WordPostingsCursor(c)
                             if idx.word_level else c)
        chained = hostq.ChainedCursor(parts)
        return None if chained.exhausted else chained


class TieredBackend(Backend):
    """Serve each query from the static tier + dynamic suffix, exactly.

    Boolean conjunctive runs DAAT over :class:`~repro.core.query.
    ChainedCursor`s (seek_GEQ skipping inside the compressed tier via its
    bp128 skip tables); ranked modes reuse the host TAAT scorers over the
    :class:`TieredView` (document-granular via ``doc_postings``, so
    word-level f_{t,d}/f_t are doc-level and idf/BM25 statistics are the
    live collection's — the same contract the device backend's frozen+delta
    merge enforces).  Word-level engines additionally get the positional
    modes: ``phrase`` and ``proximity`` run positional DAAT over chained
    static+dynamic word cursors, ``bm25_prox`` scores BM25 + MinDist
    through the same cursors.  Works with no tier published yet (the view
    degenerates to the pure dynamic path), so routing to it is always safe.
    """

    name = "tiered"

    def view(self) -> TieredView:
        return TieredView(self.engine, self.engine.static_tier())

    def execute(self, query: Query) -> QueryResult:
        eng = self.engine
        view = self.view()
        stats = eng.ranking_stats()   # fleet-wide (N, f_t, avgdl) or None
        if query.mode in ("phrase", "proximity", "bm25_prox") \
                and not eng.index.word_level:
            raise UnsupportedQueryError(
                f"{query.mode} queries need a word-level index (§5.1)")
        if query.mode == "phrase":
            # one fresh positional cursor per phrase slot, in phrase order
            d = hostq.phrase_from_cursors(
                [view.cursor(t) for t in query.terms])
            d = hostq._drop_dead(d, hostq._tombstones(view))
            return QueryResult(d, None, self.name)
        if query.mode == "proximity":
            # one positional cursor per UNIQUE term + its multiplicity:
            # repeated query terms must bind distinct positions
            d = hostq.proximity_query(view, query.terms, query.window)
            return QueryResult(d, None, self.name)
        if query.mode == "bm25_prox":
            d, s = hostq.ranked_bm25_prox(view, query.terms,
                                          eng.doclens_array(), k=query.k,
                                          stats=stats)
            return QueryResult(d, s, self.name)
        if query.mode == "conjunctive":
            cursors = []
            for t in query.terms:
                c = view.cursor(t)
                if c is None:
                    return QueryResult(np.zeros(0, np.int64), None, self.name)
                tid = eng.term_id(t)
                cursors.append((eng._fts[tid] if tid is not None else 0, c))
            if not cursors:
                return QueryResult(np.zeros(0, np.int64), None, self.name)
            # rarest-first via the engine's O(1) global f_t counters
            cursors.sort(key=lambda p: p[0])
            d = hostq.conjunctive_from_cursors([c for _, c in cursors])
            d = hostq._drop_dead(d, hostq._tombstones(view))
            return QueryResult(d, None, self.name)
        if query.mode == "ranked_tfidf":
            d, s = hostq.ranked_disjunctive_taat(view, query.terms,
                                                 k=query.k, stats=stats)
            return QueryResult(d, s, self.name)
        if query.mode == "bm25":
            d, s = hostq.ranked_bm25(view, query.terms, eng.doclens_array(),
                                     k=query.k, stats=stats)
            return QueryResult(d, s, self.name)
        raise UnsupportedQueryError(f"unknown mode {query.mode!r}")


class PallasBackend(Backend):
    """Route through the Pallas kernels via ``kernels/registry``.

    On a Const-mode doc-level engine the three term-query modes run the
    FUSED path: one ``fused_query`` launch (decode → score → top-k inside
    the kernel) per (mode, k) group over the engine's resident
    frozen+delta device images — shared with the device backend, so the
    frozen block array uploads once per freeze epoch regardless of which
    backend serves the stream.

    Index layouts without device images (variable-block growth) fall back
    to the legacy per-op path: postings decoded host-side (the live chains
    are host memory), compute-heavy comparisons in individual kernels —
    sorted-list membership for conjunctive AND, score accumulation + top-k
    for ranked modes.  ``interpret`` defaults to interpret-mode off only
    on real TPUs.
    """

    name = "pallas"

    def __init__(self, engine, interpret: bool | None = None,
                 resident=None):
        super().__init__(engine)
        self.interpret = (registry.default_interpret()
                          if interpret is None else interpret)
        self.resident = resident  # shared ResidentImageManager (or None)

    def execute_many(self, queries: list[Query]) -> list[QueryResult]:
        # lazy import: device_backend imports this module for Backend
        from .device_backend import fused_execute
        from ..kernels.fused_query import FUSED_MODES
        eng = self.engine
        fused_ok = self.resident is not None and eng.device_capable
        out: list[QueryResult | None] = [None] * len(queries)
        groups: dict[tuple[str, int], list[int]] = {}
        for i, q in enumerate(queries):
            if fused_ok and q.mode in FUSED_MODES:
                groups.setdefault((q.mode, q.k), []).append(i)
            else:
                out[i] = self.execute(q)
        if groups:
            self.resident.refresh()
            for (mode, k), idxs in groups.items():
                res = fused_execute(eng, self.resident,
                                    [queries[i] for i in idxs], mode, k,
                                    flavor="pallas",
                                    interpret=self.interpret,
                                    name=self.name)
                for i, r in zip(idxs, res):
                    out[i] = r
        return out  # type: ignore[return-value]

    # -- mode implementations -------------------------------------------

    def _conjunctive(self, query: Query) -> QueryResult:
        import jax.numpy as jnp
        idx = self.engine.index
        if not query.terms:
            return QueryResult(np.zeros(0, np.int64), None, self.name)
        lists = []
        for t in query.terms:
            docids, _ = idx.postings(t)
            if len(docids) == 0:
                return QueryResult(np.zeros(0, np.int64), None, self.name)
            lists.append(docids.astype(np.int32))
        lists.sort(key=len)
        a = jnp.asarray(lists[0])
        flags = np.ones(len(lists[0]), bool)
        spec = registry.get("intersect")
        for other in lists[1:]:
            hit = spec.fn(a, jnp.asarray(other), interpret=self.interpret)
            flags &= np.asarray(hit)
        d = hostq._drop_dead(lists[0][flags].astype(np.int64),
                             hostq._tombstones(idx))
        return QueryResult(d, None, self.name)

    def _ranked(self, query: Query) -> QueryResult:
        import jax
        import jax.numpy as jnp
        eng = self.engine
        idx = eng.index
        N = idx.num_docs
        stats = eng.ranking_stats()   # fleet-wide (N, f_t, avgdl) or None
        Ns = N if stats is None else stats.num_docs
        all_d, all_w = [], []
        doclens = eng.doclens_array() if query.mode == "bm25" else None
        if query.mode != "bm25":
            avg = 0.0
        elif stats is not None:
            avg = stats.avg_doclen
        else:
            avg = float(doclens[1:N + 1].mean()) if N else 0.0
        dead = hostq._tombstones(idx)
        for t in query.terms:
            docids, fs = idx.postings(t)
            if dead and len(docids):
                keep = ~np.isin(docids, np.fromiter(dead, np.int64,
                                                    count=len(dead)))
                docids, fs = docids[keep], fs[keep]
            if len(docids) == 0:
                continue
            ft = len(docids) if stats is None else stats.doc_ft(t)
            if query.mode == "bm25":
                w = hostq.bm25_weight(fs.astype(np.float64),
                                      doclens[docids], avg, ft, Ns)
            else:
                w = hostq.tfidf_weight(fs, ft, Ns)
            all_d.append(docids.astype(np.int32))
            all_w.append(w.astype(np.float32))
        if not all_d:
            return QueryResult(np.zeros(0, np.int64),
                               np.zeros(0, np.float64), self.name)
        spec = registry.get("topk_score")
        scores = spec.fn(jnp.concatenate([jnp.asarray(d) for d in all_d]),
                         jnp.concatenate([jnp.asarray(w) for w in all_w]),
                         n_docs=N + 1, interpret=self.interpret)
        k = min(query.k, int(scores.shape[0]))
        top_s, top_d = jax.lax.top_k(scores, k)
        top_s, top_d = np.asarray(top_s), np.asarray(top_d)
        keep = top_s > 0
        return QueryResult(top_d[keep].astype(np.int64),
                           top_s[keep].astype(np.float64), self.name)

    def execute(self, query: Query) -> QueryResult:
        if query.mode == "conjunctive":
            return self._conjunctive(query)
        if query.mode in ("ranked_tfidf", "bm25"):
            return self._ranked(query)
        raise UnsupportedQueryError(
            f"PallasBackend does not implement mode {query.mode!r}")
