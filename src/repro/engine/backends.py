"""Host and Pallas execution backends.

Both operate directly on the live :class:`~repro.core.index.DynamicIndex`
(immediate access is inherited for free); the device backend, which needs an
image refresh protocol, lives in :mod:`repro.engine.device_backend`.
"""

from __future__ import annotations

import numpy as np

from ..core import query as hostq
from ..kernels import registry
from .types import Query, QueryResult


class UnsupportedQueryError(ValueError):
    """Raised when a forced backend cannot execute the query."""


class Backend:
    """Interface: ``execute_many`` over the engine's live state."""

    name = "base"

    def __init__(self, engine):
        self.engine = engine

    def execute_many(self, queries: list[Query]) -> list[QueryResult]:
        return [self.execute(q) for q in queries]

    def execute(self, query: Query) -> QueryResult:
        raise NotImplementedError


class HostBackend(Backend):
    """The paper-faithful numpy path: DAAT cursors with seek_GEQ skipping
    for boolean queries, vectorized TAAT for ranked modes (core/query.py)."""

    name = "host"

    def execute(self, query: Query) -> QueryResult:
        eng = self.engine
        idx = eng.index
        if query.mode == "conjunctive":
            d = hostq.conjunctive_query(idx, query.terms)
            return QueryResult(d, None, self.name)
        if query.mode == "ranked_tfidf":
            d, s = hostq.ranked_disjunctive_taat(idx, query.terms, k=query.k)
            return QueryResult(d, s, self.name)
        if query.mode == "bm25":
            d, s = hostq.ranked_bm25(idx, query.terms, eng.doclens_array(),
                                     k=query.k)
            return QueryResult(d, s, self.name)
        if query.mode == "phrase":
            if not idx.word_level:
                raise UnsupportedQueryError(
                    "phrase queries need a word-level index (§5.1)")
            d = hostq.phrase_query(idx, query.terms)
            return QueryResult(d, None, self.name)
        raise UnsupportedQueryError(f"unknown mode {query.mode!r}")


class PallasBackend(Backend):
    """Route through the Pallas kernels via ``kernels/registry``.

    Postings are decoded host-side (the live chains are host memory); the
    compute-heavy comparisons run in the kernels: sorted-list membership for
    conjunctive AND, masked-matmul score accumulation + top-k for ranked
    modes.  ``interpret`` defaults to interpret-mode off only on real TPUs.
    """

    name = "pallas"

    def __init__(self, engine, interpret: bool | None = None):
        super().__init__(engine)
        self.interpret = (registry.default_interpret()
                          if interpret is None else interpret)

    # -- mode implementations -------------------------------------------

    def _conjunctive(self, query: Query) -> QueryResult:
        import jax.numpy as jnp
        idx = self.engine.index
        if not query.terms:
            return QueryResult(np.zeros(0, np.int64), None, self.name)
        lists = []
        for t in query.terms:
            docids, _ = idx.postings(t)
            if len(docids) == 0:
                return QueryResult(np.zeros(0, np.int64), None, self.name)
            lists.append(docids.astype(np.int32))
        lists.sort(key=len)
        a = jnp.asarray(lists[0])
        flags = np.ones(len(lists[0]), bool)
        spec = registry.get("intersect")
        for other in lists[1:]:
            hit = spec.fn(a, jnp.asarray(other), interpret=self.interpret)
            flags &= np.asarray(hit)
        return QueryResult(lists[0][flags].astype(np.int64), None, self.name)

    def _ranked(self, query: Query) -> QueryResult:
        import jax
        import jax.numpy as jnp
        eng = self.engine
        idx = eng.index
        N = idx.num_docs
        all_d, all_w = [], []
        doclens = eng.doclens_array() if query.mode == "bm25" else None
        avg = (float(doclens[1:N + 1].mean()) if query.mode == "bm25" and N
               else 0.0)
        for t in query.terms:
            docids, fs = idx.postings(t)
            if len(docids) == 0:
                continue
            ft = len(docids)
            if query.mode == "bm25":
                w = hostq.bm25_weight(fs.astype(np.float64),
                                      doclens[docids], avg, ft, N)
            else:
                w = hostq.tfidf_weight(fs, ft, N)
            all_d.append(docids.astype(np.int32))
            all_w.append(w.astype(np.float32))
        if not all_d:
            return QueryResult(np.zeros(0, np.int64),
                               np.zeros(0, np.float64), self.name)
        spec = registry.get("topk_score")
        scores = spec.fn(jnp.concatenate([jnp.asarray(d) for d in all_d]),
                         jnp.concatenate([jnp.asarray(w) for w in all_w]),
                         n_docs=N + 1, interpret=self.interpret)
        k = min(query.k, int(scores.shape[0]))
        top_s, top_d = jax.lax.top_k(scores, k)
        top_s, top_d = np.asarray(top_s), np.asarray(top_d)
        keep = top_s > 0
        return QueryResult(top_d[keep].astype(np.int64),
                           top_s[keep].astype(np.float64), self.name)

    def execute(self, query: Query) -> QueryResult:
        if query.mode == "conjunctive":
            return self._conjunctive(query)
        if query.mode in ("ranked_tfidf", "bm25"):
            return self._ranked(query)
        raise UnsupportedQueryError(
            f"PallasBackend does not implement mode {query.mode!r}")
