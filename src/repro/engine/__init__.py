"""Unified query engine: one planner/executor over every backend.

The paper's triple goal — streamlined ingest, small index, fast term-based
querying — is served here through a single API:

    eng = Engine(B=64, growth="const")
    eng.add_document(["fast", "dynamic", "index"])
    res = eng.execute(Query(mode="conjunctive", terms=("fast", "index")))
    res.docids, res.scores, res.backend

Four pluggable backends execute the same query semantics:

  * :class:`~repro.engine.backends.HostBackend` — the paper-faithful
    cursor/TAAT code in ``core/query.py`` (always available; serves every
    mode including word-level / phrase querying);
  * :class:`~repro.engine.device_backend.DeviceBackend` — the jnp oracle
    ``core/device_index.query_step`` over a frozen collated image plus an
    incrementally refreshed :class:`~repro.core.device_index.DeltaIndex`,
    so device queries see every ingested document without re-running
    ``collate()`` (immediate access on the TPU path);
  * :class:`~repro.engine.backends.PallasBackend` — the Pallas kernels
    (``kernels/intersect``, ``kernels/topk_score``) discovered through
    ``kernels/registry``;
  * :class:`~repro.engine.backends.TieredBackend` — the frozen docid prefix
    served from the compressed :class:`~repro.core.static_index.StaticIndex`
    tier published by :class:`~repro.core.lifecycle.FreezeManager`
    (background freeze, atomic swap), merged exactly with the post-freeze
    dynamic suffix.

A :class:`~repro.engine.planner.Planner` selects the backend per batch from
term statistics (f_t, chain lengths, batch size), with a forced-override
knob (``Engine(force_backend=...)`` or ``Query(backend=...)``).
"""

from ..core.lifecycle import (
    FreezeCoordinator,
    FreezeManager,
    FreezePolicy,
    StaticTier,
)
from .backends import (
    HostBackend,
    PallasBackend,
    TieredBackend,
    UnsupportedQueryError,
)
from .device_backend import DeviceBackend
from .engine import Engine
from .planner import PlanDecision, Planner, PlannerConfig
from .types import (
    MODES,
    POSITIONAL_MODES,
    CollectionStats,
    Query,
    QueryResult,
)

__all__ = [
    "Engine", "Query", "QueryResult", "Planner", "PlannerConfig",
    "PlanDecision", "HostBackend", "DeviceBackend", "PallasBackend",
    "TieredBackend", "UnsupportedQueryError",
    "FreezeManager", "FreezePolicy", "StaticTier", "FreezeCoordinator",
    "CollectionStats", "MODES", "POSITIONAL_MODES",
]
