"""The Engine: one ingest+query front door over all backends.

Owns the live :class:`~repro.core.index.DynamicIndex`, the document-length
array (BM25 state the paper places outside the core index, §3.6), the
term-id vocabulary shared with the device images, and the planner.  See the
package docstring for the API sketch and ``ROADMAP.md`` for how later
scaling PRs (async ingest, caching, multi-backend fusion) plug in here.
"""

from __future__ import annotations

import time

import numpy as np

from ..core.collate import collate
from ..core.index import DynamicIndex, group_occurrences
from ..core.lifecycle import FreezeManager, FreezePolicy
from ..core.prepare import prepare_batch
from ..core.query import CollectionStats, TermStats
from .backends import (
    HostBackend,
    PallasBackend,
    TieredBackend,
    UnsupportedQueryError,
)
from .device_backend import DeviceBackend, ResidentImageManager
from .planner import Planner, PlannerConfig
from .types import POSITIONAL_MODES, EngineStats, Query, QueryResult


class _LiveFtMap:
    """Read-only term-bytes → LIVE document frequency, backed directly by
    the engine's incrementally-maintained counters (no dict materialized).
    Plugged into :class:`CollectionStats` as its ``ft`` mapping when the
    engine synthesizes deletion-aware statistics — ranked scorers then
    weight with exactly the df an index that never saw the dead documents
    would have."""

    __slots__ = ("_tid", "_dfs")

    def __init__(self, tid: dict, dfs: list):
        self._tid = tid
        self._dfs = dfs

    def get(self, tb, default=0):
        t = self._tid.get(tb)
        if t is None:
            return default
        return self._dfs[t]


class Engine:
    """Planner/executor over host, device-oracle, and Pallas backends.

    Parameters
    ----------
    B, growth, F, word_level:
        forwarded to :class:`DynamicIndex` (``index`` may be passed instead
        to adopt an existing one — it must not be shared with other writers).
    planner / force_backend:
        routing configuration; ``force_backend`` pins every query.
    decode_fn:
        optional Pallas decode kernel for the device backend
        (``kernels.dvbyte_decode.ops.as_decode_fn()``).
    interpret:
        Pallas interpret mode for the kernel backend (default: auto —
        interpret everywhere but real TPUs).
    auto_collate_delta_frac:
        if set, a device refresh that finds the delta larger than this
        fraction of the frozen image triggers a full collation first —
        bounding delta size (and device query cost) without ever collating
        on the query path for small deltas.
    delta_compact_frac / delta_compact_min_blocks:
        fragmentation-threshold compaction for the device refresh itself:
        when the PROJECTED delta (new blocks + one copied tail per changed
        term, an O(V) counter compare) exceeds BOTH the fraction of the
        store and the absolute block floor, refresh falls back to a full
        collation — past that point the incremental chain walk costs more
        than rebuilding (BENCH_engine.json, delta section).  The floor
        keeps small indexes on the honest incremental path; None disables.
    tier_policy:
        enable the tiered static lifecycle (``core.lifecycle``): a
        :class:`~repro.core.lifecycle.FreezeManager` converts the frozen
        docid prefix into a compressed :class:`StaticIndex` tier on a
        background thread per this policy, and the tiered backend serves
        the prefix from it.
    """

    def __init__(self, B: int = 64, growth: str = "const",
                 F: int | None = None, word_level: bool = False,
                 index: DynamicIndex | None = None,
                 planner: PlannerConfig | None = None,
                 force_backend: str | None = None,
                 decode_fn=None, interpret: bool | None = None,
                 auto_collate_delta_frac: float | None = None,
                 delta_compact_frac: float | None = 0.25,
                 delta_compact_min_blocks: int = 512,
                 tier_policy: FreezePolicy | None = None):
        self.index = index if index is not None else DynamicIndex(
            B=B, growth=growth, F=F, word_level=word_level)
        self.planner = Planner(planner, force_backend)
        self.auto_collate_delta_frac = auto_collate_delta_frac
        self.delta_compact_frac = delta_compact_frac
        self.delta_compact_min_blocks = delta_compact_min_blocks
        self.version = 0                  # published — bumps per ingested doc
        # when this engine is one shard of a document-partitioned fleet,
        # the fan-out layer installs a callable returning the fleet-wide
        # CollectionStats — every ranked scorer and device-image refresh
        # then rebases (N, f_t, avgdl) to the full collection, making
        # shard results merge-exact.  None = this engine IS the collection.
        self.stats_provider = None
        self.vocab: list[bytes] = []      # tid -> term bytes
        self._tid: dict[bytes, int] = {}
        # tid -> LIVE f_t (doc-level: document frequency; word-level:
        # occurrence count) — incremented at ingest, decremented at delete,
        # so scorers and device images always weight with statistics of an
        # index that never saw the dead documents
        self._fts: list[int] = []
        # tid -> LIVE document frequency on word-level engines (their _fts
        # is an occurrence count; ranked idf needs doc granularity)
        self._doc_dfs: list[int] = []
        self._doclens: list[int] = [0]    # 1-indexed via position-0 pad
        # forward index: docid -> [(tid, occurrences)] per unique term
        # (None once deleted — also the cheap not-deleted check); this is
        # what lets delete_document decrement every per-term df exactly
        # without a decode pass over the inverted chains
        self._doc_tids: list = [None]     # 1-indexed via position-0 pad
        self._deleted_tokens = 0          # Σ doclen over tombstoned docs
        # tid-indexed per-batch grouping scratch for the fused doc-level
        # batch ingest (entries are None between batches)
        self._group_scratch: list = []
        self.stats_counters = EngineStats()
        # ONE resident device-image manager shared by the device and pallas
        # backends: a mixed stream pays for at most one frozen upload and
        # one delta rebuild per engine version
        self.resident = ResidentImageManager(self, decode_fn=decode_fn)
        self.backends = {
            "host": HostBackend(self),
            "device": DeviceBackend(self, resident=self.resident),
            "pallas": PallasBackend(self, interpret=interpret,
                                    resident=self.resident),
            "tiered": TieredBackend(self),
        }
        self.lifecycle: FreezeManager | None = None
        if tier_policy is not None:
            self.enable_tiering(tier_policy)
        if index is not None:
            self._adopt_existing()

    def enable_tiering(self, policy: FreezePolicy | None = None
                       ) -> FreezeManager:
        """Attach (or reconfigure) the static-tier lifecycle (doc-level and
        word-level engines alike — word-level tiers keep positions, so
        phrase queries serve from the compressed tier too)."""
        self.lifecycle = FreezeManager(self, policy)
        return self.lifecycle

    def static_tier(self):
        """The published :class:`~repro.core.lifecycle.StaticTier` (or
        None); swapped atomically by the lifecycle's background freeze."""
        return self.lifecycle.tier if self.lifecycle is not None else None

    def _adopt_existing(self) -> None:
        """Register terms/doclens of a pre-built index (doclens are
        reconstructed as Σ f per doc — exact for doc-level indexes), plus
        the forward index and live per-term statistics (the inverted
        chains still hold tombstoned docs' postings, so live df/avgdl are
        recovered by subtracting the tombstoned contributions)."""
        word = self.index.word_level
        dl = np.zeros(self.index.num_docs + 1, np.int64)
        for term, _h in self.index.terms():
            self._intern(term)
            d, f = self.index.postings(term)
            np.add.at(dl, d, f if not word else 1)
        self._doclens = dl.tolist()
        self._rebuild_forward()
        self._fts = [0] * len(self.vocab)
        for d in range(1, self.index.num_docs + 1):
            entry = self._doc_tids[d]
            if entry is None:
                continue
            for tid, occ in entry:
                self._fts[tid] += occ if word else 1
        self.version += 1

    def _rebuild_forward(self) -> None:
        """Derive the forward index (docid -> [(tid, occurrences)]), live
        word-level document frequencies and the deleted-token total from the
        inverted chains + tombstone set.  Vocabulary and ``_doclens`` must
        already be registered.  Used by ``_adopt_existing`` and snapshot
        restore — the chains and live ``_fts`` are the persisted state of
        record; the forward index is always derived."""
        word = self.index.word_level
        doc_tids: list = [[] for _ in range(self.index.num_docs + 1)]
        for term, _h in self.index.terms():
            tid = self._tid[term]
            d, f = self.index.postings(term)
            ud, cnt = group_occurrences(d) if word else (d, f)
            for dd, cc in zip(ud.tolist(), cnt.tolist()):
                doc_tids[dd].append((tid, cc))
        self._doc_dfs = [0] * len(self.vocab)
        self._deleted_tokens = 0
        dead = self.index.tombstones
        for d in range(1, self.index.num_docs + 1):
            if d in dead:
                self._deleted_tokens += int(self._doclens[d])
                doc_tids[d] = None
                continue
            if word:
                for tid, _occ in doc_tids[d]:
                    self._doc_dfs[tid] += 1
        doc_tids[0] = None
        self._doc_tids = doc_tids

    # ------------------------------------------------------------------
    # vocabulary / statistics
    # ------------------------------------------------------------------

    def _intern(self, tb: bytes) -> int:
        tid = self._tid.get(tb)
        if tid is None:
            tid = len(self.vocab)
            self._tid[tb] = tid
            self.vocab.append(tb)
            self._fts.append(0)
            self._doc_dfs.append(0)
        return tid

    def term_id(self, term) -> int | None:
        tb = term.encode() if isinstance(term, str) else term
        return self._tid.get(tb)

    def ranking_stats(self):
        """The :class:`~repro.core.query.CollectionStats` to score with, or
        None when this engine's own statistics ARE the collection's (the
        single-engine case).  Backends pass this straight into the ranked
        scorers.

        With tombstones outstanding (and no fleet provider), deletion-aware
        statistics are synthesized from the engine's live counters: N minus
        the dead, avgdl over live tokens, per-term LIVE document frequency
        — so ranked scores are byte-identical to an index that never
        ingested the deleted documents."""
        if self.stats_provider is not None:
            return self.stats_provider()
        dead = self.index.tombstones
        if not dead:
            return None
        live_n = self.index.num_docs - len(dead)
        avg = ((self.index.num_words - self._deleted_tokens) / live_n
               if live_n else 0.0)
        dfs = self._doc_dfs if self.index.word_level else self._fts
        return CollectionStats(live_n, avg, _LiveFtMap(self._tid, dfs))

    def global_fts(self) -> np.ndarray:
        """Current f_t per term id (device images rebase stats with this).

        Maintained incrementally at ingest, so an image refresh never walks
        the vocabulary through the store.  Under a fleet stats provider the
        array is the COLLECTION-wide document frequency per local term id —
        the device image must weight its postings exactly as the fleet
        oracle would."""
        stats = self.ranking_stats()
        if stats is not None:
            return stats.fts_for(self.vocab)
        return np.asarray(self._fts, dtype=np.int64)

    def doclens_array(self) -> np.ndarray:
        return np.asarray(self._doclens, dtype=np.float64)

    @property
    def device_capable(self) -> bool:
        return self.index.store.const_mode and not self.index.word_level

    @property
    def pallas_capable(self) -> bool:
        # kernels decode postings host-side, so any growth policy works;
        # word-level lists (w-gap payloads, duplicate docids) do not fit
        return not self.index.word_level

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------

    def add_document(self, terms) -> int:
        """Ingest one document; it is queryable on every backend the moment
        this returns (device backends refresh their delta lazily)."""
        t0 = time.perf_counter()
        d = self.index.add_document(terms)
        tbs = [t.encode() if isinstance(t, str) else t for t in terms]
        entry: list[tuple[int, int]] = []
        if self.index.word_level:
            occ: dict[int, int] = {}
            for tb in tbs:  # §5.1: one posting (and one f_t tick) per occurrence
                tid = self._intern(tb)
                self._fts[tid] += 1
                occ[tid] = occ.get(tid, 0) + 1
            for tid, n in occ.items():  # first-occurrence order
                self._doc_dfs[tid] += 1
                entry.append((tid, n))
        else:
            counts: dict[int, int] = {}
            for tb in tbs:
                tid = self._intern(tb)
                counts[tid] = counts.get(tid, 0) + 1
            for tid, f in counts.items():  # dedupe, first-occurrence order
                self._fts[tid] += 1
                entry.append((tid, f))
        self._doc_tids.append(entry)
        self._doclens.append(len(terms))
        self.version += 1
        sc = self.stats_counters
        sc.ingest_docs += 1
        sc.ingest_batches += 1
        sc.ingest_time_s += time.perf_counter() - t0
        if self.lifecycle is not None:
            self.lifecycle.maybe_freeze()
        return d

    def add_documents(self, docs) -> list[int]:
        """Batched ingest: returns the assigned docids, ascending; every
        document is queryable on every backend the moment this returns.

        Answer-identical to a per-document :meth:`add_document` loop —
        same docids, same term ids (batch interning follows the same
        first-occurrence order), same forward-index entries, same decoded
        chains — but the index append is the grouped per-term run path
        (:meth:`DynamicIndex.add_prepared`) and the forward-index/statistics
        bookkeeping runs batch-wise, so the per-document Python overhead is
        amortized across the batch.  ``docs`` may be raw term sequences or
        :class:`~repro.core.prepare.PreparedDoc` records tokenized off the
        writer thread (``serve.ingest_pipeline``).

        ``version`` advances by the batch size (the same final value as a
        sequential loop — serving cache keys stay aligned); the lifecycle
        freeze check runs once per batch, so a freeze may trigger with the
        whole batch already ingested rather than mid-stream — tier contents
        at any horizon are identical either way.
        """
        t0 = time.perf_counter()
        word = self.index.word_level
        prepared = prepare_batch(docs, word)
        tid_of = self._tid
        vocab = self.vocab
        fts = self._fts
        doc_dfs = self._doc_dfs
        doc_tids = self._doc_tids
        doclens = self._doclens
        getid = tid_of.__getitem__
        if word:
            # word-level: the index groups the occurrence streams itself
            dids = self.index.add_prepared(prepared)
            for p in prepared:
                uniq = p.uniq
                try:
                    tids = [*map(getid, uniq)]      # all-known fast path
                except KeyError:
                    for tb in uniq:                 # first-occurrence order
                        if tb not in tid_of:
                            tid_of[tb] = len(vocab)
                            vocab.append(tb)
                            fts.append(0)
                            doc_dfs.append(0)
                    tids = [*map(getid, uniq)]
                for tid, f in zip(tids, p.counts):
                    fts[tid] += f
                    doc_dfs[tid] += 1
                doc_tids.append([*zip(tids, p.counts)])
                doclens.append(p.doclen)
        else:
            # doc-level FUSED path: the interning/bookkeeping pass also
            # groups the batch's <d, f> postings per term (term-id-indexed
            # lists — no second traversal, no dict probe per posting), and
            # the runs go straight to DynamicIndex.add_runs.  ``touched``
            # keeps first-occurrence order, so head creation matches what
            # sequential ingest would have produced.
            by_tid: list = self._group_scratch
            touched: list[int] = []
            ta = touched.append
            d = self.index.num_docs
            base = d
            nwords = npostings = 0
            for p in prepared:
                uniq = p.uniq
                try:
                    tids = [*map(getid, uniq)]      # all-known fast path
                except KeyError:
                    for tb in uniq:                 # first-occurrence order
                        if tb not in tid_of:
                            tid_of[tb] = len(vocab)
                            vocab.append(tb)
                            fts.append(0)
                            doc_dfs.append(0)
                    tids = [*map(getid, uniq)]
                if len(by_tid) < len(vocab):
                    by_tid.extend([None] * (len(vocab) - len(by_tid)))
                d += 1
                cs = p.counts
                for tid, f in zip(tids, cs):
                    run = by_tid[tid]
                    if run is None:
                        by_tid[tid] = run = []
                        ta(tid)
                    run.append((d, f))
                doc_tids.append([*zip(tids, cs)])
                doclens.append(p.doclen)
                nwords += p.doclen
                npostings += len(tids)
            self.index.add_runs(
                d - base, nwords, npostings,
                ((vocab[tid], by_tid[tid]) for tid in touched))
            for tid in touched:     # df ticks per TERM, then reset scratch
                fts[tid] += len(by_tid[tid])
                by_tid[tid] = None
            dids = list(range(base + 1, d + 1))
        self.version += len(prepared)
        sc = self.stats_counters
        sc.ingest_docs += len(prepared)
        sc.ingest_batches += 1
        sc.ingest_time_s += time.perf_counter() - t0
        if self.lifecycle is not None:
            self.lifecycle.maybe_freeze()
        return dids

    def delete_document(self, docid: int) -> list[tuple[int, int]]:
        """Tombstone one document (takedown/revision primitive).

        Exact statistics maintenance via the forward index: every term the
        document contained has its live f_t (and, word-level, document
        frequency) decremented, and the live token total drops by the
        document's length — so every ranked scorer and device image weights
        as if the document was never ingested.  The docid keeps its ordinal
        meaning (round-robin arithmetic, tier horizons, and device images
        are unaffected); serving paths mask it, and the next freeze drops
        it from the static tier.  Returns the document's ``(tid,
        occurrences)`` pairs so a fan-out layer can mirror the df
        decrements fleet-wide.  Writer thread only, like ``add_document``.
        """
        self.index.delete_document(docid)   # validates range + double delete
        entry = self._doc_tids[docid]
        word = self.index.word_level
        for tid, n in entry:
            self._fts[tid] -= n if word else 1
            if word:
                self._doc_dfs[tid] -= 1
        self._deleted_tokens += self._doclens[docid]
        self._doc_tids[docid] = None
        self.version += 1
        return entry

    def update_document(self, docid: int, terms) -> int:
        """Revise a document: tombstone the old docid, ingest the new
        content under a FRESH ordinal docid (returned).  Docids are
        immutable-once-assigned everywhere (tier horizons, device images,
        round-robin arithmetic), so an update is delete + add by
        construction — exactly the semantics of a rebuild that saw only
        the new content."""
        self.delete_document(docid)
        return self.add_document(terms)

    def collate_now(self) -> None:
        """Full collation (§5.5): stop-the-world chain compaction, then the
        device backend adopts the result as its frozen image and the delta
        rebases to empty.  Queries never require this — the delta keeps the
        device backend current — but a periodic collation keeps the delta
        (and host cache locality) small."""
        self.index = collate(self.index)
        self.stats_counters.collations += 1
        if self.device_capable:
            self.resident.freeze()

    def _maybe_auto_collate(self) -> None:
        frac = self.auto_collate_delta_frac
        if frac is None:
            return
        total = max(1, self.index.store.nblocks)
        if self.resident.delta_blocks > frac * total:
            self.collate_now()

    # ------------------------------------------------------------------
    # query execution
    # ------------------------------------------------------------------

    def execute(self, query: Query) -> QueryResult:
        return self.execute_many([query])[0]

    def execute_many(self, queries: list[Query]) -> list[QueryResult]:
        """Plan and run a batch; results align with ``queries``."""
        if not queries:
            return []
        t0 = time.perf_counter()
        self._maybe_auto_collate()
        plans = []
        for q in queries:
            # planning reads only the engine's O(1) f_t counters — never the
            # store (term_stats' chain walk is for offline introspection)
            stats = [TermStats(self._fts[tid], 0)
                     if (tid := self.term_id(t)) is not None else TermStats()
                     for t in q.terms]
            plans.append(self.planner.plan(
                q, len(queries), stats, device_capable=self.device_capable,
                pallas_capable=self.pallas_capable,
                tiered_available=self.static_tier() is not None,
                # the tiered backend serves every mode; positional modes
                # additionally need word positions (as does the host path)
                tiered_capable=(self.index.word_level
                                if q.mode in POSITIONAL_MODES else True)))
        out: list[QueryResult | None] = [None] * len(queries)
        by_backend: dict[str, list[int]] = {}
        for i, p in enumerate(plans):
            by_backend.setdefault(p.backend, []).append(i)
        for name, idxs in by_backend.items():
            backend = self.backends[name]
            res = backend.execute_many([queries[i] for i in idxs])
            for i, r in zip(idxs, res):
                r.reason = plans[i].reason
                out[i] = r
        self.stats_counters.queries += len(queries)
        self.stats_counters.query_batches += 1
        self.stats_counters.query_time_s += time.perf_counter() - t0
        for p in plans:
            bb = self.stats_counters.by_backend
            bb[p.backend] = bb.get(p.backend, 0) + 1
        return out  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # persistence (core/persist.py)
    # ------------------------------------------------------------------

    def snapshot(self, root: str, *, keep: int = 3,
                 quiesce: bool = False) -> str:
        """Persist this engine under ``root`` (crash-atomic: staged write,
        manifest last, one rename — see ``core.persist``).  Returns the
        published snapshot dir.  Runs on the writer thread; safe while a
        background freeze is encoding (the snapshot captures the currently
        PUBLISHED tier plus the full dynamic image, which restores
        byte-identically at any horizon).  ``quiesce=True`` first joins an
        in-flight encode so the newest tier lands in the snapshot."""
        from ..core import persist
        if quiesce and self.lifecycle is not None:
            self.lifecycle.quiesce()
        return persist.save_engine(self, root, keep=keep)

    @classmethod
    def restore(cls, path_or_root: str, **engine_kwargs) -> "Engine":
        """Rebuild an engine from a snapshot dir (or the newest snapshot
        under a root).  ``engine_kwargs`` forwards runtime knobs (planner,
        force_backend, decode_fn, ...); index shape and freeze policy come
        from the manifest."""
        from ..core import persist
        return persist.restore_engine(path_or_root, **engine_kwargs)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def stats(self) -> EngineStats:
        s = self.stats_counters
        s.num_docs = self.index.num_docs
        s.deleted_docs = len(self.index.tombstones)
        s.num_postings = self.index.num_postings
        s.num_words = self.index.num_words
        s.vocab_size = len(self.vocab)
        if self.lifecycle is not None:
            s.freezes = self.lifecycle.freezes
            s.tier_epoch = self.lifecycle.epoch
            s.tombstones_compacted = self.lifecycle.tombstones_compacted
        return s


__all__ = ["Engine", "Query", "QueryResult", "UnsupportedQueryError"]
