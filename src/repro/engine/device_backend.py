"""Device backend: resident frozen image + incrementally refreshed delta.

The naive TPU path re-runs ``collate()`` + ``build_device_image()`` on every
ingest — stop-the-world, which breaks the paper's immediate-access property
exactly where it matters.  The :class:`ResidentImageManager` instead keeps:

  * a **resident frozen image**: the collated snapshot from the last full
    collation (``Engine.collate_now``), uploaded ONCE per freeze epoch —
    its block array stays on device across queries and refreshes; only the
    per-term statistics are rebased to the live collection at each refresh
    (``with_global_stats``);
  * a **delta image**: a :class:`~repro.core.device_index.DeltaIndex`
    snapshotting only blocks appended since the freeze (cost ∝ delta);

and the backends answer queries by running the fused decode→score→top-k
kernel (``kernels/fused_query``) over BOTH images in one launch.  Because
docids are ordinal and each document's postings are written atomically,
frozen and delta docid spaces are disjoint — merging them inside one
posting pool is exact, verified against the host backend by the
differential tests.

The manager is shared by the ``device`` backend (reference flavour of the
fused op — the oracle) and the ``pallas`` backend (the Pallas kernel
flavour), so a mixed query stream pays for at most one resident image and
one delta rebuild per engine version.

**Delta-compaction policy** (fragmentation threshold): an incremental
refresh whose *projected* delta — new blocks since the freeze plus one
copied tail block per changed term — exceeds both an absolute floor and a
fraction of the store falls back to a full collation first.  Beyond that
threshold the python chain-walk of ``build_delta_image`` costs more than
collating outright (measured in BENCH_engine.json's delta section), so
incremental refresh would otherwise be the slower option exactly when the
delta is largest.  The projection is computed from O(V) counter
comparisons BEFORE paying the walk.

Shapes are bucketed (vocab, block count, chain length, batch, and docid
capacity all round up to powers of two) so steady-state serving reuses
compiled programs; a refresh after ingest re-traces only when a bucket
grows.
"""

from __future__ import annotations

import numpy as np

from ..core.device_index import (
    DeviceIndex,
    build_delta_image,
    build_device_image,
    capture_delta_baseline,
    query_step,
    with_global_stats,
)
from ..kernels import registry
from .backends import Backend, UnsupportedQueryError
from .types import POSITIONAL_MODES, Query, QueryResult


def _pow2(n: int, floor: int = 1) -> int:
    n = max(n, floor)
    return 1 << (n - 1).bit_length()


class ResidentImageManager:
    """Owns the device-resident (frozen, delta) image pair for one engine.

    Lifecycle counters double as the amortization evidence the benchmarks
    record: ``frozen_uploads`` bumps only at freeze (collation) time while
    ``batches_served`` bumps per fused launch — steady-state serving shows
    many batches per upload.
    """

    def __init__(self, engine, decode_fn=None):
        self.engine = engine
        self.decode_fn = decode_fn
        self._frozen_raw: DeviceIndex | None = None   # as built at freeze
        self._baseline = None                          # DeltaBaseline
        self._frozen = None             # writer_only — stats-rebased frozen
        self._delta = None              # writer_only — DeltaIndex
        self._doclens = None                           # (cap+1,) f32 device
        self._alive = None              # packed uint32 liveness bits or None
        self._n_stat = None
        self._avg_stat = None                          # fleet avgdl (sharded)
        self._synced_version = -1                      # writer_only
        self._frozen_mb = 1                            # max_blocks, frozen
        self._delta_mb = 1                             # max_blocks, delta
        self._nblk_np = None            # writer_only — host (frozen, delta)
        #                                                per-term chain sizes
        self._doc_cap = 1024
        self._vocab_cap = 64
        self.epoch = 0                                 # freeze epochs seen
        self.frozen_uploads = 0                        # resident-image uploads
        self.batches_served = 0                        # fused launches

    # ------------------------------------------------------------------
    # image lifecycle
    # ------------------------------------------------------------------

    def freeze(self) -> None:
        """Adopt the engine's (just-collated) index as the frozen image and
        rebase the delta to empty.  Called by ``Engine.collate_now`` — the
        ONLY point at which the full block array is re-uploaded."""
        eng = self.engine
        self._frozen_raw = build_device_image(eng.index, eng.vocab)
        self._baseline = capture_delta_baseline(eng.index, eng.vocab)
        self._frozen_mb = _pow2(int(self._frozen_raw.term_nblk.max())
                                if eng.vocab else 1)
        self._frozen = None        # stale metadata: rebuild from _frozen_raw
        self._synced_version = -1  # force a refresh before the next query
        self.epoch += 1
        self.frozen_uploads += 1
        eng.stats_counters.resident_uploads += 1

    def _projected_delta_blocks(self, local_fts: np.ndarray) -> int:
        """Upper-bound estimate of the delta a refresh would build: blocks
        allocated since the freeze + one copied tail block per changed term.
        O(V) vectorized counter compares — no chain walk."""
        base = self._baseline
        store = self.engine.index.store
        Vf = min(base.vocab_size, len(local_fts))
        changed = int(np.count_nonzero(local_fts[:Vf] != base.ft[:Vf]))
        changed += int(np.count_nonzero(local_fts[Vf:] > 0))
        return (store.nblocks - base.nblocks) + changed

    def _maybe_compact(self, local_fts: np.ndarray) -> bool:
        """Fragmentation-threshold compaction: fall back to a full collation
        when the projected delta exceeds the policy bounds (both the
        absolute block floor AND the store fraction must trip — the floor
        keeps small indexes on the honest incremental path)."""
        eng = self.engine
        frac = eng.delta_compact_frac
        if frac is None or self._baseline is None:
            return False
        projected = self._projected_delta_blocks(local_fts)
        total = max(1, eng.index.store.nblocks)
        if (projected <= eng.delta_compact_min_blocks
                or projected <= frac * total):
            return False
        eng.collate_now()          # re-freezes: baseline + resident image
        eng.stats_counters.delta_compactions += 1
        return True

    def refresh(self) -> bool:
        """Incremental device-image refresh: snapshot only post-freeze blocks.

        Returns True if anything was rebuilt.  ``collate()`` runs here only
        when the compaction policy trips (projected delta past the
        fragmentation threshold); below it, this is the honest
        immediate-access path for the device backends.
        """
        import jax.numpy as jnp
        eng = self.engine
        if self._synced_version == eng.version:
            return False
        if not eng.device_capable:
            raise UnsupportedQueryError(
                "device images need a Const-mode doc-level index")
        if self._baseline is None:
            # never collated: an empty baseline makes the delta cover the
            # whole index, so the device path works before any collation
            self._frozen_raw = _empty_image(eng)
            self._baseline = capture_delta_baseline(eng.index, [])
        # scoring f_t (collection-wide under a fleet stats provider) vs the
        # engine's LOCAL counters: change detection in build_delta_image
        # compares against the freeze baseline's store-level f_t, so it must
        # see the local numbers — the global ones would flag every term of
        # a sharded engine as changed and blow the delta up to O(V)
        local_fts = np.asarray(eng._fts, dtype=np.int64)
        self._maybe_compact(local_fts)
        N = eng.index.num_docs
        doc_cap = max(self._doc_cap, _pow2(N + 1))
        vocab_cap = max(self._vocab_cap, _pow2(len(eng.vocab)))
        # scoring statistics: in a fleet, idf-N and avgdl are the
        # COLLECTION's; with tombstones outstanding they are the engine's
        # synthesized live counters — either way the delta must weight its
        # postings with the SAME f_t as the frozen image (exact merge)
        stats = eng.ranking_stats()
        fts = (stats.fts_for(eng.vocab) if stats is not None
               else np.asarray(eng._fts, dtype=np.int64))
        # the frozen image's chain metadata only changes when a bucket grows
        # or after a freeze; per-refresh work is just the f_t swap + delta
        if (self._frozen is None or doc_cap != self._doc_cap
                or vocab_cap != self._vocab_cap
                or self._frozen.term_slot.shape[0] != vocab_cap):
            self._frozen = with_global_stats(self._frozen_raw, fts, doc_cap,
                                             pad_vocab=vocab_cap)
        else:
            self._frozen = with_global_stats(self._frozen, fts, doc_cap)
        self._doc_cap, self._vocab_cap = doc_cap, vocab_cap
        delta = build_delta_image(eng.index, eng.vocab, self._baseline,
                                  num_docs=self._doc_cap,
                                  pad_vocab=self._vocab_cap,
                                  global_ft=local_fts)
        if stats is not None:
            # fleet or deletion-aware mode: override the delta's baked
            # store-level f_t with the collection-wide / live numbers
            ftp = np.zeros(int(delta.term_ft.shape[0]), np.int32)
            ftp[:min(len(fts), len(ftp))] = fts[:len(ftp)]
            delta.term_ft = jnp.asarray(ftp)
        nd = _pow2(int(delta.blocks.shape[0]))
        if nd > delta.blocks.shape[0]:
            delta.blocks = jnp.pad(
                delta.blocks, ((0, nd - delta.blocks.shape[0]), (0, 0)))
        self._delta = delta
        self._delta_mb = _pow2(int(delta.term_nblk.max())
                               if delta.term_nblk.shape[0] else 1)
        # host copy of both images' per-term chain sizes: fused_execute
        # sizes each launch's packed block pool from the batch's actual
        # chains (one small device→host pull per refresh, not per batch)
        self._nblk_np = (np.asarray(self._frozen.term_nblk),
                         np.asarray(delta.term_nblk))
        dl = np.zeros(self._doc_cap + 1, np.float32)
        dl[1:N + 1] = eng.doclens_array()[1:N + 1]
        self._doclens = jnp.asarray(dl)
        # liveness mask: tombstoned docids score 0 inside the fused kernel's
        # accumulator; None (the common case) skips masking entirely so the
        # no-delete path stays byte-identical to its pre-deletion programs.
        # Packed 1 bit/docid (little-endian uint32 words, unpacked on the
        # fly by the kernel) — 32x smaller resident than a dense f32 mask
        dead = eng.index.tombstones
        if dead:
            al = np.zeros(self._doc_cap + 1, bool)
            al[1:N + 1] = True
            al[np.fromiter(dead, np.int64, count=len(dead))] = False
            bits = np.packbits(al, bitorder="little")
            if bits.nbytes % 4:
                bits = np.pad(bits, (0, 4 - bits.nbytes % 4))
            self._alive = jnp.asarray(bits.view(np.uint32))
        else:
            self._alive = None
        if stats is None:
            self._n_stat = jnp.int32(N)
            self._avg_stat = None
        else:
            self._n_stat = jnp.int32(stats.num_docs)
            self._avg_stat = jnp.float32(stats.avg_doclen)
        self._synced_version = eng.version
        eng.stats_counters.delta_refreshes += 1
        return True

    @property
    def delta_blocks(self) -> int:
        """Live delta size in blocks (the auto-collation signal)."""
        if self._delta is None:
            return 0
        return int(self._delta.term_nblk.sum())

    @property
    def images(self):
        """The resident (frozen, delta) pair the fused kernel merges."""
        return (self._frozen, self._delta)

    @property
    def max_blocks(self) -> tuple:
        """Per-image chain caps, aligned with :attr:`images` — the delta
        suffix keeps its own (small) cap so its decode tile stays tiny."""
        return (self._frozen_mb, self._delta_mb)


def fused_execute(engine, resident: ResidentImageManager,
                  batch: list[Query], mode: str, k: int, *, flavor: str,
                  interpret: bool, name: str) -> list[QueryResult]:
    """Answer one (mode, k) query group with a single fused launch over the
    resident images.  Shared by the device (flavor="ref") and pallas
    (flavor="pallas") backends — identical math, one resident state."""
    import jax.numpy as jnp
    eng = engine
    N = eng.index.num_docs
    # term-id resolution; conjunctive queries with an unknown term are
    # decided (empty) without touching the device
    tids: list[list[int] | None] = []
    for q in batch:
        ids = [eng.term_id(t) for t in q.terms]
        if mode == "conjunctive" and (None in ids or not ids):
            tids.append(None)
        else:
            tids.append([i for i in ids if i is not None])
    live = [i for i, ids in enumerate(tids) if ids]
    results = [QueryResult(np.zeros(0, np.int64),
                           None if mode == "conjunctive"
                           else np.zeros(0, np.float64), name)
               for _ in batch]
    if not live:
        return results
    Qn = _pow2(len(live))
    T = _pow2(max(len(tids[i]) for i in live), floor=4)
    qt = np.zeros((Qn, T), np.int32)
    qm = np.zeros((Qn, T), bool)
    for row, i in enumerate(live):
        ids = tids[i]
        qt[row, :len(ids)] = ids
        qm[row, :len(ids)] = True
    qt, qm = jnp.asarray(qt), jnp.asarray(qm)
    if resident._nblk_np is None:
        resident.refresh()
    # packed pool size per image: the batch's largest per-query total block
    # count (pow2-bucketed so steady-state traffic reuses compiled programs)
    caps = []
    for nblk in resident._nblk_np:
        V = nblk.shape[0]
        tot = max((sum(int(nblk[t]) for t in tids[i] if t < V)
                   for i in live), default=0)
        caps.append(_pow2(max(tot, 1), floor=8))
    spec = registry.get("fused_query")
    out = spec.fn(resident.images, qt, qm, mode=mode, k=k,
                  max_blocks=tuple(caps),
                  doclens=resident._doclens if mode == "bm25" else None,
                  n_stat=resident._n_stat, avg_stat=resident._avg_stat,
                  alive=resident._alive, flavor=flavor, interpret=interpret)
    resident.batches_served += 1
    if mode == "conjunctive":
        matches = np.asarray(out)
        for row, i in enumerate(live):
            d = np.flatnonzero(matches[row, 1:]) + 1
            results[i] = QueryResult(d[d <= N].astype(np.int64), None, name)
        return results
    alld, alls = np.asarray(out[0]), np.asarray(out[1])
    for row, i in enumerate(live):
        d, s = alld[row], alls[row]
        keep = (s > 0) & (d > 0)   # already in canonical order from top_k
        results[i] = QueryResult(d[keep].astype(np.int64),
                                 s[keep].astype(np.float64), name)
    return results


class DeviceBackend(Backend):
    """Oracle flavour of the fused device path (``flavor="ref"``): the same
    single-launch decode→score→top-k math as the Pallas kernel, run as
    plain XLA.  ``use_fused=False`` falls back to the legacy two-launch
    ``query_step`` + host-side merge (kept for differential testing)."""

    name = "device"

    def __init__(self, engine, decode_fn=None,
                 resident: ResidentImageManager | None = None,
                 use_fused: bool = True):
        super().__init__(engine)
        self.resident = resident if resident is not None \
            else ResidentImageManager(engine, decode_fn=decode_fn)
        self.use_fused = use_fused

    # lifecycle delegation (compat: Engine/benchmarks drive these here)
    def freeze(self) -> None:
        self.resident.freeze()

    def refresh(self) -> bool:
        return self.resident.refresh()

    @property
    def delta_blocks(self) -> int:
        return self.resident.delta_blocks

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def execute(self, query: Query) -> QueryResult:
        return self.execute_many([query])[0]

    def execute_many(self, queries: list[Query]) -> list[QueryResult]:
        if any(q.mode in POSITIONAL_MODES for q in queries):
            raise UnsupportedQueryError(
                "DeviceBackend does not implement positional query modes")
        self.resident.refresh()
        out: list[QueryResult | None] = [None] * len(queries)
        groups: dict[tuple[str, int], list[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault((q.mode, q.k), []).append(i)
        for (mode, k), idxs in groups.items():
            batch = [queries[i] for i in idxs]
            if self.use_fused:
                res = fused_execute(self.engine, self.resident, batch, mode,
                                    k, flavor="ref", interpret=True,
                                    name=self.name)
            else:
                res = self._run_group_split(batch, mode, k)
            for i, r in zip(idxs, res):
                out[i] = r
        return out  # type: ignore[return-value]

    def _run_group_split(self, batch: list[Query], mode: str,
                         k: int) -> list[QueryResult]:
        """Legacy path: one ``query_step`` per image, merged host-side."""
        import jax.numpy as jnp
        eng = self.engine
        mgr = self.resident
        if eng.index.tombstones:
            # per-image top-k truncation happens BEFORE any tombstone mask
            # could apply, so a dead doc can evict a live one from an
            # image's k; the fused path masks inside the accumulator —
            # delegate to it whenever deletes are outstanding
            return fused_execute(eng, mgr, batch, mode, k, flavor="ref",
                                 interpret=True, name=self.name)
        N = eng.index.num_docs
        tids: list[list[int] | None] = []
        for q in batch:
            ids = [eng.term_id(t) for t in q.terms]
            if mode == "conjunctive" and (None in ids or not ids):
                tids.append(None)
            else:
                tids.append([i for i in ids if i is not None])
        live = [i for i, ids in enumerate(tids) if ids]
        results = [QueryResult(np.zeros(0, np.int64),
                               None if mode == "conjunctive"
                               else np.zeros(0, np.float64), self.name)
                   for _ in batch]
        if not live:
            return results
        Qn = _pow2(len(live))
        T = _pow2(max(len(tids[i]) for i in live), floor=4)
        qt = np.zeros((Qn, T), np.int32)
        qm = np.zeros((Qn, T), bool)
        for row, i in enumerate(live):
            ids = tids[i]
            qt[row, :len(ids)] = ids
            qm[row, :len(ids)] = True
        qt, qm = jnp.asarray(qt), jnp.asarray(qm)
        kw = dict(max_blocks=mgr._frozen_mb, decode_fn=mgr.decode_fn,
                  n_stat=mgr._n_stat, avg_stat=mgr._avg_stat)
        kwd = dict(kw, max_blocks=mgr._delta_mb)
        if mode == "conjunctive":
            mf, _ = query_step(mgr._frozen, qt, qm, k=1,
                               mode="conjunctive", **kw)
            md, _ = query_step(mgr._delta, qt, qm, k=1,
                               mode="conjunctive", **kwd)
            matches = np.asarray(mf) | np.asarray(md)
            for row, i in enumerate(live):
                d = np.flatnonzero(matches[row]) + 1
                results[i] = QueryResult(d[d <= N].astype(np.int64), None,
                                         self.name)
            return results
        qmode = "bm25" if mode == "bm25" else "ranked"
        dl = mgr._doclens if mode == "bm25" else None
        df, sf = query_step(mgr._frozen, qt, qm, k=k, mode=qmode,
                            doclens=dl, **kw)
        dd, sd = query_step(mgr._delta, qt, qm, k=k, mode=qmode,
                            doclens=dl, **kwd)
        alld = np.concatenate([np.asarray(df), np.asarray(dd)], axis=1)
        alls = np.concatenate([np.asarray(sf), np.asarray(sd)], axis=1)
        for row, i in enumerate(live):
            d, s = alld[row], alls[row]
            keep = (s > 0) & (d > 0)
            d, s = d[keep], s[keep]
            order = np.argsort(-s, kind="stable")[:k]
            results[i] = QueryResult(d[order].astype(np.int64),
                                     s[order].astype(np.float64), self.name)
        return results


def _empty_image(engine) -> DeviceIndex:
    """A zero-term frozen image (pre-first-collation state)."""
    import jax.numpy as jnp
    B = engine.index.store.B
    z = jnp.zeros(0, jnp.int32)
    return DeviceIndex(blocks=jnp.zeros((1, B), jnp.uint8), term_slot=z,
                       term_nblk=z, term_skip=z, term_nx=z, term_ft=z,
                       num_docs=0, F=engine.index.F)
