"""Device backend: frozen collated image + incrementally refreshed delta.

The naive TPU path re-runs ``collate()`` + ``build_device_image()`` on every
ingest — stop-the-world, which breaks the paper's immediate-access property
exactly where it matters.  This backend instead keeps:

  * a **frozen image**: the collated snapshot from the last full collation
    (``Engine.collate_now``), whose per-term statistics are rebased to the
    live collection at each refresh (``with_global_stats``);
  * a **delta image**: a :class:`~repro.core.device_index.DeltaIndex`
    snapshotting only blocks appended since the freeze (cost ∝ delta);

and answers queries by running ``query_step`` on both and merging.  Because
docids are ordinal and each document's postings are written atomically,
frozen and delta docid spaces are disjoint — the merge (top-k concat for
ranked modes, bitmap OR for conjunctive) is exact, verified against the host
backend by the differential tests.

Shapes are bucketed (vocab, block count, chain length, batch, and docid
capacity all round up to powers of two) so steady-state serving reuses
compiled programs; a refresh after ingest re-traces only when a bucket
grows.
"""

from __future__ import annotations

import numpy as np

from ..core.device_index import (
    DeviceIndex,
    build_delta_image,
    build_device_image,
    capture_delta_baseline,
    query_step,
    with_global_stats,
)
from .backends import Backend, UnsupportedQueryError
from .types import POSITIONAL_MODES, Query, QueryResult


def _pow2(n: int, floor: int = 1) -> int:
    n = max(n, floor)
    return 1 << (n - 1).bit_length()


class DeviceBackend(Backend):
    name = "device"

    def __init__(self, engine, decode_fn=None):
        super().__init__(engine)
        self.decode_fn = decode_fn
        self._frozen_raw: DeviceIndex | None = None   # as built at freeze
        self._baseline = None                          # DeltaBaseline
        self._frozen = None                            # stats-rebased frozen
        self._delta = None                             # DeltaIndex
        self._doclens = None                           # (cap+1,) f32 device
        self._n_stat = None
        self._avg_stat = None                          # fleet avgdl (sharded)
        self._synced_version = -1
        self._frozen_mb = 1                            # max_blocks, frozen
        self._delta_mb = 1                             # max_blocks, delta
        self._doc_cap = 1024
        self._vocab_cap = 64

    # ------------------------------------------------------------------
    # image lifecycle
    # ------------------------------------------------------------------

    def freeze(self) -> None:
        """Adopt the engine's (just-collated) index as the frozen image and
        rebase the delta to empty.  Called by ``Engine.collate_now``."""
        eng = self.engine
        self._frozen_raw = build_device_image(eng.index, eng.vocab)
        self._baseline = capture_delta_baseline(eng.index, eng.vocab)
        self._frozen_mb = _pow2(int(self._frozen_raw.term_nblk.max())
                                if eng.vocab else 1)
        self._frozen = None        # stale metadata: rebuild from _frozen_raw
        self._synced_version = -1  # force a refresh before the next query

    def refresh(self) -> bool:
        """Incremental device-image refresh: snapshot only post-freeze blocks.

        Returns True if anything was rebuilt.  No ``collate()`` runs here —
        this is the honest immediate-access path for the device backend.
        """
        import jax.numpy as jnp
        eng = self.engine
        if self._synced_version == eng.version:
            return False
        if not eng.device_capable:
            raise UnsupportedQueryError(
                "device images need a Const-mode doc-level index")
        if self._baseline is None:
            # never collated: an empty baseline makes the delta cover the
            # whole index, so the device path works before any collation
            self._frozen_raw = _empty_image(eng)
            self._baseline = capture_delta_baseline(eng.index, [])
        N = eng.index.num_docs
        doc_cap = max(self._doc_cap, _pow2(N + 1))
        vocab_cap = max(self._vocab_cap, _pow2(len(eng.vocab)))
        # scoring f_t (collection-wide under a fleet stats provider) vs the
        # engine's LOCAL counters: change detection in build_delta_image
        # compares against the freeze baseline's store-level f_t, so it must
        # see the local numbers — the global ones would flag every term of
        # a sharded engine as changed and blow the delta up to O(V)
        fts = eng.global_fts()
        local_fts = np.asarray(eng._fts, dtype=np.int64)
        # the frozen image's chain metadata only changes when a bucket grows
        # or after a freeze; per-refresh work is just the f_t swap + delta
        if (self._frozen is None or doc_cap != self._doc_cap
                or vocab_cap != self._vocab_cap
                or self._frozen.term_slot.shape[0] != vocab_cap):
            self._frozen = with_global_stats(self._frozen_raw, fts, doc_cap,
                                             pad_vocab=vocab_cap)
        else:
            self._frozen = with_global_stats(self._frozen, fts, doc_cap)
        self._doc_cap, self._vocab_cap = doc_cap, vocab_cap
        delta = build_delta_image(eng.index, eng.vocab, self._baseline,
                                  num_docs=self._doc_cap,
                                  pad_vocab=self._vocab_cap,
                                  global_ft=local_fts)
        if eng.stats_provider is not None:
            # fleet mode: the delta weights its postings with the same
            # collection-wide f_t as the frozen image (same idf, exact merge)
            ftp = np.zeros(int(delta.term_ft.shape[0]), np.int32)
            ftp[:min(len(fts), len(ftp))] = fts[:len(ftp)]
            delta.term_ft = jnp.asarray(ftp)
        nd = _pow2(int(delta.blocks.shape[0]))
        if nd > delta.blocks.shape[0]:
            delta.blocks = jnp.pad(
                delta.blocks, ((0, nd - delta.blocks.shape[0]), (0, 0)))
        self._delta = delta
        self._delta_mb = _pow2(int(delta.term_nblk.max())
                               if delta.term_nblk.shape[0] else 1)
        dl = np.zeros(self._doc_cap + 1, np.float32)
        dl[1:N + 1] = eng.doclens_array()[1:N + 1]
        self._doclens = jnp.asarray(dl)
        # scoring statistics: in a fleet, idf-N and avgdl are the
        # COLLECTION's (the fts above already came global via global_fts);
        # doclens stays local — each doc's own length is partition-invariant
        stats = eng.ranking_stats()
        if stats is None:
            self._n_stat = jnp.int32(N)
            self._avg_stat = None
        else:
            self._n_stat = jnp.int32(stats.num_docs)
            self._avg_stat = jnp.float32(stats.avg_doclen)
        self._synced_version = eng.version
        eng.stats_counters.delta_refreshes += 1
        return True

    @property
    def delta_blocks(self) -> int:
        """Live delta size in blocks (the auto-collation signal)."""
        if self._delta is None:
            return 0
        return int(self._delta.term_nblk.sum())

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def execute(self, query: Query) -> QueryResult:
        return self.execute_many([query])[0]

    def execute_many(self, queries: list[Query]) -> list[QueryResult]:
        if any(q.mode in POSITIONAL_MODES for q in queries):
            raise UnsupportedQueryError(
                "DeviceBackend does not implement positional query modes")
        self.refresh()
        out: list[QueryResult | None] = [None] * len(queries)
        groups: dict[tuple[str, int], list[int]] = {}
        for i, q in enumerate(queries):
            groups.setdefault((q.mode, q.k), []).append(i)
        for (mode, k), idxs in groups.items():
            batch = [queries[i] for i in idxs]
            for i, res in zip(idxs, self._run_group(batch, mode, k)):
                out[i] = res
        return out  # type: ignore[return-value]

    def _run_group(self, batch: list[Query], mode: str,
                   k: int) -> list[QueryResult]:
        import jax.numpy as jnp
        eng = self.engine
        N = eng.index.num_docs
        # term-id resolution; conjunctive queries with an unknown term are
        # decided (empty) without touching the device
        tids: list[list[int] | None] = []
        for q in batch:
            ids = [eng.term_id(t) for t in q.terms]
            if mode == "conjunctive" and (None in ids or not ids):
                tids.append(None)
            else:
                tids.append([i for i in ids if i is not None])
        live = [i for i, ids in enumerate(tids) if ids]
        results = [QueryResult(np.zeros(0, np.int64),
                               None if mode == "conjunctive"
                               else np.zeros(0, np.float64), self.name)
                   for _ in batch]
        if not live:
            return results
        Qn = _pow2(len(live))
        T = _pow2(max(len(tids[i]) for i in live), floor=4)
        qt = np.zeros((Qn, T), np.int32)
        qm = np.zeros((Qn, T), bool)
        for row, i in enumerate(live):
            ids = tids[i]
            qt[row, :len(ids)] = ids
            qm[row, :len(ids)] = True
        qt, qm = jnp.asarray(qt), jnp.asarray(qm)
        kw = dict(max_blocks=self._frozen_mb, decode_fn=self.decode_fn,
                  n_stat=self._n_stat, avg_stat=self._avg_stat)
        kwd = dict(kw, max_blocks=self._delta_mb)
        if mode == "conjunctive":
            mf, _ = query_step(self._frozen, qt, qm, k=1,
                               mode="conjunctive", **kw)
            md, _ = query_step(self._delta, qt, qm, k=1,
                               mode="conjunctive", **kwd)
            matches = np.asarray(mf) | np.asarray(md)
            for row, i in enumerate(live):
                d = np.flatnonzero(matches[row]) + 1
                results[i] = QueryResult(d[d <= N].astype(np.int64), None,
                                         self.name)
            return results
        qmode = "bm25" if mode == "bm25" else "ranked"
        dl = self._doclens if mode == "bm25" else None
        df, sf = query_step(self._frozen, qt, qm, k=k, mode=qmode,
                            doclens=dl, **kw)
        dd, sd = query_step(self._delta, qt, qm, k=k, mode=qmode,
                            doclens=dl, **kwd)
        alld = np.concatenate([np.asarray(df), np.asarray(dd)], axis=1)
        alls = np.concatenate([np.asarray(sf), np.asarray(sd)], axis=1)
        for row, i in enumerate(live):
            d, s = alld[row], alls[row]
            keep = (s > 0) & (d > 0)
            d, s = d[keep], s[keep]
            order = np.argsort(-s, kind="stable")[:k]
            results[i] = QueryResult(d[order].astype(np.int64),
                                     s[order].astype(np.float64), self.name)
        return results


def _empty_image(engine) -> DeviceIndex:
    """A zero-term frozen image (pre-first-collation state)."""
    import jax.numpy as jnp
    B = engine.index.store.B
    z = jnp.zeros(0, jnp.int32)
    return DeviceIndex(blocks=jnp.zeros((1, B), jnp.uint8), term_slot=z,
                       term_nblk=z, term_skip=z, term_nx=z, term_ft=z,
                       num_docs=0, F=engine.index.F)
