"""Backend selection: route each query batch by term statistics.

The planner is deliberately a pure function of cheap observables —
per-term f_t and chain length (both O(1) head-block reads), query batch
size, and index shape (growth policy, word level) — so planning cost never
rivals execution cost.  Routing rules, in priority order:

1. a forced override (``Query.backend`` or ``Engine(force_backend=...)``)
   wins unconditionally and raises if the backend can't run the query;
2. word-level indexes run on the host or tiered backends (the two that
   model word positions); positional modes (phrase / proximity /
   bm25_prox) go to the tiered backend when a static tier is published
   (positions served from the compressed ⟨d,w⟩ image) and to the host
   otherwise; non-Const growth additionally rules out the device image
   (device snapshots need B-addressable blocks) but NOT the Pallas
   kernels, which decode postings host-side;
3. batches of ``device_min_batch`` or more queries go to the device image:
   batched fixed-shape execution amortizes the dispatch and the gather
   touches every query's chains in one fused program.  When the config
   carries a measured :class:`CrossoverTable` (engine_bench.py sweep),
   the threshold is the per-mode batch size at which the device — or the
   fused Pallas kernel — actually beat the host, replacing the static
   guess; a mode where neither ever won is never batch-routed off host;
4. single/small queries whose candidate volume (min f_t for conjunctive —
   the driver of DAAT cost — or Σ f_t for ranked) exceeds
   ``pallas_min_postings`` go to the Pallas kernels;
5. when the lifecycle has published a static tier (``tiered_available``),
   remaining queries whose candidate volume stays under
   ``tiered_max_volume`` go to the tiered backend: the frozen docid prefix
   is served from the compressed image (bp128 skip tables for seek_GEQ)
   and only the post-freeze suffix touches the live chains.  This trades a
   modest per-query decode cost (see BENCH_engine.json: tiered runs
   1.4–2.6× the host latency on hot terms) for keeping the working set in
   the ~1.6 B/posting static image instead of the dynamic chains — the
   volume gate bounds the absolute penalty to the small-query regime where
   it is microseconds;
6. everything else stays on the host, whose seek_GEQ skipping beats a
   device round-trip on short chains.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

from .types import POSITIONAL_MODES, Query, TermStats


@dataclass(frozen=True)
class CrossoverTable:
    """Measured device-routing crossovers, derived from benchmark sweeps.

    ``min_batch[mode][backend]`` is the smallest measured batch size at
    which ``backend`` ("device" or "pallas") beat the host's steady-state
    µs/query at EVERY swept collection size (conservative: a backend must
    win across sizes before the planner prefers it), or None when it never
    won.  Built by ``benchmarks/engine_bench.py`` from its workload ×
    collection size × batch size sweep and stored in
    ``BENCH_engine.json["crossover"]`` — :meth:`from_bench` re-derives the
    table from that file, so planner thresholds are measurements, not
    guesses.
    """

    min_batch: dict = field(default_factory=dict)

    @classmethod
    def from_rows(cls, rows) -> "CrossoverTable":
        """Derive the table from sweep rows: dicts with ``workload``,
        ``backend``, ``size``, ``batch``, ``us_per_query`` (steady-state)."""
        cells: dict[tuple, dict[str, float]] = {}
        for r in rows:
            key = (r["workload"], int(r["batch"]), int(r["size"]))
            cells.setdefault(key, {})[r["backend"]] = float(r["us_per_query"])
        modes = sorted({k[0] for k in cells})
        batches = sorted({k[1] for k in cells})
        table: dict[str, dict[str, int | None]] = {}
        for mode in modes:
            table[mode] = {}
            for backend in ("device", "pallas"):
                win = None
                for b in batches:
                    group = [v for k, v in cells.items()
                             if k[0] == mode and k[1] == b]
                    if group and all(backend in v and "host" in v
                                     and v[backend] < v["host"]
                                     for v in group):
                        win = b
                        break
                table[mode][backend] = win
        return cls(min_batch=table)

    @classmethod
    def from_bench(cls, path: str = "BENCH_engine.json") -> "CrossoverTable":
        """Load the sweep rows recorded by ``engine_bench.py`` and re-derive
        the crossover thresholds from them."""
        import json
        with open(path) as fh:
            payload = json.load(fh)
        return cls.from_rows(payload["crossover"]["rows"])

    def min_batch_for(self, mode: str, backend: str) -> int | None:
        """Measured min winning batch for (mode, backend); None = never won
        or mode not swept (caller falls back to static defaults)."""
        per_mode = self.min_batch.get(mode)
        if per_mode is None:
            return None
        return per_mode.get(backend)

    @property
    def swept_modes(self) -> tuple[str, ...]:
        return tuple(self.min_batch)


@dataclass(frozen=True)
class PlannerConfig:
    """Thresholds for the routing rules (see module docstring).

    When ``crossover`` is set (a :class:`CrossoverTable` from
    ``engine_bench.py`` measurements), the batch-size device/pallas rules
    use its per-mode measured thresholds instead of ``device_min_batch``;
    modes the sweep never measured keep the static default, and a mode
    where the accelerated path never beat the host is never batch-routed
    to it.
    """

    device_min_batch: int = 4       # batch size at which the device image wins
    pallas_min_postings: int = 2048  # candidate volume at which kernels win
    tiered_max_volume: int = 2048   # volume ceiling for tiered routing
    allow_device: bool = True
    allow_pallas: bool = True
    allow_tiered: bool = True
    crossover: CrossoverTable | None = None  # measured thresholds (bench)


class PlanDecision(NamedTuple):
    backend: str
    reason: str


class Planner:
    def __init__(self, config: PlannerConfig | None = None,
                 force_backend: str | None = None):
        self.config = config or PlannerConfig()
        self.force_backend = force_backend

    def plan(self, query: Query, batch_size: int, stats: list[TermStats],
             *, device_capable: bool, pallas_capable: bool = True,
             tiered_available: bool = False,
             tiered_capable: bool = True) -> PlanDecision:
        """Pick a backend for ``query`` arriving in a batch of ``batch_size``.

        ``stats`` aligns with ``query.terms``; ``device_capable`` reports
        whether the index layout supports device images (Const-mode,
        doc-level), ``pallas_capable`` whether the kernels apply (doc-level
        — Pallas decodes postings host-side, so variable-block growth is
        fine, but word-level lists carry w-gap payloads and duplicate
        docids the kernels do not model).  ``tiered_capable`` reports
        whether the tiered backend can run THIS query (it serves both doc-
        and word-level images; phrase queries need a word-level one);
        ``tiered_available`` whether a static tier is actually published —
        routing prefers it over the host only then, since with no tier it
        degenerates to the host path with extra indirection.
        """
        cfg = self.config
        forced = query.backend or self.force_backend
        if forced is not None:
            unsupported = (
                (query.mode in POSITIONAL_MODES
                 and forced in ("device", "pallas")) or
                (forced == "device" and not device_capable) or
                (forced == "pallas" and not pallas_capable) or
                (forced == "tiered" and not tiered_capable))
            if forced in ("device", "pallas", "tiered") and unsupported:
                raise ValueError(
                    f"backend {forced!r} forced, but {query.mode!r} queries "
                    "on this index layout do not support it")
            return PlanDecision(forced, "forced override")
        if query.mode in POSITIONAL_MODES:
            if cfg.allow_tiered and tiered_capable and tiered_available:
                return PlanDecision(
                    "tiered",
                    f"{query.mode} served from the compressed ⟨d,w⟩ tier")
            return PlanDecision("host",
                                f"{query.mode} requires word positions")
        if cfg.allow_device and device_capable:
            if cfg.crossover is not None \
                    and query.mode in cfg.crossover.swept_modes:
                mb = cfg.crossover.min_batch_for(query.mode, "device")
                if mb is not None and batch_size >= mb:
                    return PlanDecision(
                        "device", f"measured crossover: device wins "
                                  f"{query.mode} at batch >= {mb}")
            elif batch_size >= cfg.device_min_batch:
                return PlanDecision(
                    "device",
                    f"batch of {batch_size} amortizes device dispatch")
        if (cfg.allow_pallas and pallas_capable and device_capable
                and cfg.crossover is not None
                and query.mode in cfg.crossover.swept_modes):
            mb = cfg.crossover.min_batch_for(query.mode, "pallas")
            if mb is not None and batch_size >= mb:
                return PlanDecision(
                    "pallas", f"measured crossover: fused kernel wins "
                              f"{query.mode} at batch >= {mb}")
        fts = [s.ft for s in stats if s.ft > 0]
        if not fts:
            return PlanDecision("host", "no term statistics (empty terms)")
        volume = min(fts) if query.mode == "conjunctive" else sum(fts)
        if (cfg.allow_pallas and pallas_capable
                and volume >= cfg.pallas_min_postings):
            return PlanDecision(
                "pallas", f"candidate volume {volume} favours kernels")
        if (cfg.allow_tiered and tiered_capable and tiered_available
                and volume <= cfg.tiered_max_volume):
            return PlanDecision(
                "tiered", "static tier serves the frozen prefix compressed")
        return PlanDecision(
            "host", f"candidate volume {volume} favours cursor skipping")
