"""VByte and Double-VByte codecs (paper §2.2, §3.4, Algorithm 2).

The paper uses the Büttcher–Clarke VByte variant with the *null-byte sentinel*
property: the all-zero byte can only be produced by encoding x == 0, so as long
as every encoded value is strictly positive, a 0x00 byte unambiguously marks
"end of sequence" (or "unused trailing space in a block").

The only byte-oriented little-endian base-128 layout with that property is the
standard LEB128 one:

  * non-final bytes carry the continuation flag (top bit SET, value >= 0x80),
  * the final byte carries the top 7-bit group with the top bit CLEAR,
  * groups are emitted least-significant first.

Proof of the sentinel property: a continuation byte is >= 0x80, never null; the
final byte of a multi-byte code holds the most-significant group, which is
non-zero by minimality; a single-byte code is null iff x == 0.  (The paper's
prose describes the flag polarity the other way around, but that polarity would
emit a null byte inside the code of e.g. x == 128, contradicting the paper's own
sentinel claim in §2.2 — so we implement the layout that makes the system
sound, and note the discrepancy here.)

Double-VByte (Algorithm 2) folds a (g, f) pair into one integer when f < F:

    g' = (g - 1) * F + f          # f in 1..F-1  ->  g' mod F == f  != 0
    g' = g * F ; then f - F + 1   # escape       ->  g' mod F == 0

Both branches keep every emitted integer >= 1, preserving the sentinel.

This module provides scalar encoders/decoders (byte-exact, used by the block
store) and vectorized numpy codecs (used by benchmarks and as the host-side
oracle for the Pallas kernel).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "vbyte_len",
    "vbyte_encode_into",
    "vbyte_decode_from",
    "vbyte_encode",
    "vbyte_decode_stream",
    "dvbyte_len",
    "dvbyte_encode_into",
    "dvbyte_decode_from",
    "vbyte_encode_array",
    "vbyte_decode_array",
    "dvbyte_encode_pairs",
    "dvbyte_decode_pairs",
]

# --------------------------------------------------------------------------
# Scalar codec (byte-exact; hot path of the host ingest engine)
# --------------------------------------------------------------------------


def vbyte_len(x: int) -> int:
    """Number of bytes the VByte code of ``x`` occupies (x >= 0)."""
    n = 1
    while x >= 0x80:
        x >>= 7
        n += 1
    return n


def vbyte_encode_into(buf, pos: int, x: int) -> int:
    """Write the VByte code of ``x`` into ``buf`` at ``pos``; return new pos."""
    while x >= 0x80:
        buf[pos] = 0x80 | (x & 0x7F)
        pos += 1
        x >>= 7
    buf[pos] = x  # top bit clear: final byte
    return pos + 1


def vbyte_decode_from(buf, pos: int):
    """Decode one VByte value from ``buf`` at ``pos``; return (value, new pos)."""
    x = 0
    shift = 0
    while True:
        b = int(buf[pos])
        pos += 1
        if b & 0x80:
            x |= (b & 0x7F) << shift
            shift += 7
        else:
            x |= b << shift
            return x, pos


def vbyte_encode(values) -> bytes:
    """Encode an iterable of non-negative ints to a byte string."""
    out = bytearray()
    for x in values:
        x = int(x)
        while x >= 0x80:
            out.append(0x80 | (x & 0x7F))
            x >>= 7
        out.append(x)
    return bytes(out)


def vbyte_decode_stream(buf, pos: int = 0, end: int | None = None,
                        sentinel: bool = True):
    """Decode VByte values until ``end``.  Yields ints.

    With ``sentinel=True`` (the block-store convention) a null byte terminates
    the stream — callers must have guaranteed x > 0 for all encoded values.
    """
    if end is None:
        end = len(buf)
    while pos < end:
        if sentinel and buf[pos] == 0:  # null sentinel: padding / end of block
            return
        x, pos = vbyte_decode_from(buf, pos)
        yield x


# --------------------------------------------------------------------------
# Double-VByte (Algorithm 2)
# --------------------------------------------------------------------------


def dvbyte_len(g: int, f: int, F: int) -> int:
    """Length in bytes of the Double-VByte code for (g, f) with threshold F."""
    if f < F:
        return vbyte_len((g - 1) * F + f)
    return vbyte_len(g * F) + vbyte_len(f - F + 1)


def dvbyte_encode_into(buf, pos: int, g: int, f: int, F: int) -> int:
    """Algorithm 2 ``double_vbyte_encode``: write (g, f) into ``buf``.

    Requires g >= 1 and f >= 1 (guaranteed for doc-level postings; word-level
    callers pre-shift their d-gaps by +1 per paper §5.1).
    """
    if f < F:
        return vbyte_encode_into(buf, pos, (g - 1) * F + f)
    pos = vbyte_encode_into(buf, pos, g * F)
    return vbyte_encode_into(buf, pos, f - F + 1)


def dvbyte_decode_from(buf, pos: int, F: int):
    """Algorithm 2 ``double_vbyte_decode``: return ((g, f), new pos)."""
    gp, pos = vbyte_decode_from(buf, pos)
    r = gp % F
    if r > 0:
        return (1 + gp // F, r), pos
    f2, pos = vbyte_decode_from(buf, pos)
    return (gp // F, F + f2 - 1), pos


# --------------------------------------------------------------------------
# Vectorized numpy codecs (whole-array encode/decode, Table 4 benchmark and
# the oracle for kernels/dvbyte_decode)
# --------------------------------------------------------------------------


def _vbyte_lens_vec(values: np.ndarray) -> np.ndarray:
    """Vectorized vbyte_len for a uint64/int64 array of non-negative values."""
    v = values.astype(np.uint64)
    n = np.ones(v.shape, dtype=np.int64)
    for k in (7, 14, 21, 28, 35):
        n += (v >= (np.uint64(1) << np.uint64(k))).astype(np.int64)
    return n


def vbyte_encode_array(values: np.ndarray) -> np.ndarray:
    """Encode a 1-D array of non-negative ints; returns a uint8 array.

    Fully vectorized: computes per-value code lengths, prefix-sums offsets,
    then scatters all k-th bytes of all codes in one shot per k.
    """
    v = np.asarray(values, dtype=np.uint64).ravel()
    lens = _vbyte_lens_vec(v)
    offs = np.concatenate([[0], np.cumsum(lens)])
    total = int(offs[-1])
    out = np.zeros(total, dtype=np.uint8)
    maxlen = int(lens.max()) if len(lens) else 0
    for k in range(maxlen):
        sel = lens > k
        grp = ((v[sel] >> np.uint64(7 * k)) & np.uint64(0x7F)).astype(np.uint8)
        last = lens[sel] == k + 1
        grp = np.where(last, grp, grp | np.uint8(0x80))
        out[offs[:-1][sel] + k] = grp
    return out


def vbyte_decode_array(buf: np.ndarray, count: int | None = None) -> np.ndarray:
    """Decode a uint8 array of back-to-back VByte codes to a uint64 array.

    Data-parallel structure (this is exactly what the Pallas kernel does on
    TPU): terminator flags -> exclusive scan gives each byte its value index
    and its within-code position, then all payloads are combined with shifts
    via a segmented reduction.
    """
    b = np.asarray(buf, dtype=np.uint8).ravel()
    if count is not None:
        # trim trailing sentinel padding
        pass
    term = (b & 0x80) == 0  # final byte of each code
    # value index of each byte = number of terminators strictly before it
    vidx = np.cumsum(term) - term.astype(np.int64)
    nvals = int(term.sum())
    # position within code: byte_index - start_of_code
    starts = np.zeros(nvals, dtype=np.int64)
    ends = np.flatnonzero(term)
    starts[1:] = ends[:-1] + 1
    pos_in_code = np.arange(len(b), dtype=np.int64) - starts[vidx]
    payload = (b & np.uint8(0x7F)).astype(np.uint64) << (
        np.uint64(7) * pos_in_code.astype(np.uint64)
    )
    vals = np.zeros(nvals, dtype=np.uint64)
    np.add.at(vals, vidx, payload)
    if count is not None:
        vals = vals[:count]
    return vals


def dvbyte_encode_pairs(g: np.ndarray, f: np.ndarray, F: int) -> np.ndarray:
    """Vectorized Double-VByte for arrays of (g, f) pairs -> uint8 stream."""
    g = np.asarray(g, dtype=np.uint64)
    f = np.asarray(f, dtype=np.uint64)
    if np.any(g < 1) or np.any(f < 1):
        raise ValueError("Double-VByte requires g >= 1 and f >= 1")
    small = f < F
    # folded primary values
    prim = np.where(small, (g - 1) * np.uint64(F) + f, g * np.uint64(F))
    # escape values interleave after their primary
    n = len(g)
    n_out = n + int((~small).sum())
    vals = np.empty(n_out, dtype=np.uint64)
    # output slot of each primary = index + (# escapes before it)
    esc_before = np.cumsum(~small) - (~small).astype(np.int64)
    pslot = np.arange(n) + esc_before
    vals[pslot] = prim
    vals[pslot[~small] + 1] = f[~small] - np.uint64(F) + np.uint64(1)
    return vbyte_encode_array(vals)


def dvbyte_decode_pairs(buf: np.ndarray, F: int, count: int | None = None):
    """Decode a Double-VByte uint8 stream back to (g, f) uint64 arrays."""
    vals = vbyte_decode_array(buf)
    # primaries are: the first value, and any value following a completed pair.
    # A value v is an escape iff the *previous primary* had v_prim % F == 0.
    # Scan-free trick: walk with a vectorized two-state automaton is not
    # possible without a scan because escapes consume a slot; do a fast loop
    # over the (rare) escape positions instead.
    mods = vals % np.uint64(F)
    g_out = []
    f_out = []
    i = 0
    n = len(vals)
    # bulk path: find runs with no escapes
    while i < n:
        if mods[i] != 0:
            # run of non-escape primaries
            j = i
            while j < n and mods[j] != 0:
                j += 1
            g_out.append(1 + vals[i:j] // np.uint64(F))
            f_out.append(mods[i:j])
            i = j
        else:
            g_out.append(vals[i : i + 1] // np.uint64(F))
            f_out.append(np.uint64(F) + vals[i + 1 : i + 2] - np.uint64(1))
            i += 2
    g = np.concatenate(g_out) if g_out else np.zeros(0, np.uint64)
    f = np.concatenate(f_out) if f_out else np.zeros(0, np.uint64)
    if count is not None:
        g, f = g[:count], f[:count]
    return g, f
