"""Document pre-processing for the batched write path (ROADMAP: paper-scale
ingest, after Asadi & Lin's pipelined in-memory indexer).

Tokenization, term-byte encoding and within-document aggregation are pure
functions of the document — no index state — so they can run off the writer
thread (``serve.ingest_pipeline`` runs them on the submitting caller; the
per-shard writer threads then consume only :class:`PreparedDoc` records and
spend their time appending postings).

A :class:`PreparedDoc` carries exactly what both halves of an ingest need:

  * ``uniq``/``counts`` — unique term bytes in first-occurrence order with
    their within-document frequencies (doc-level postings, forward-index
    entries, df updates);
  * ``occs`` — the word-level occurrence stream ``(term, w-gap)`` in word
    order (§5.1: the w-payload is the gap to the previous occurrence of the
    SAME term in this document, or the absolute 1-based position for its
    first occurrence), ``None`` for doc-level preparation.

First-occurrence order matters: it is the order a sequential per-document
ingest interns terms in, and batch≡sequential parity (same term ids, same
vocabulary order, same forward-index entries) depends on reproducing it.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass


@dataclass(frozen=True)
class PreparedDoc:
    """One tokenized, aggregated document, ready for the writer thread."""

    doclen: int                                     # token count
    uniq: tuple[bytes, ...]                         # first-occurrence order
    counts: tuple[int, ...]                         # f_{t,d} per uniq entry
    occs: tuple[tuple[bytes, int], ...] | None = None  # word-level stream


def prepare_doc(terms, word_level: bool = False) -> PreparedDoc:
    """Tokenize one document (a sequence of term strings/bytes).

    Pure function — safe on any thread.  The byte encoding and the
    Counter-style aggregation here are exactly what ``add_document``
    performs inline; moving them off the writer thread is what lets the
    writer consume pre-mapped arrays only.
    """
    if word_level:
        counts: dict[bytes, int] = {}
        occs: list[tuple[bytes, int]] = []
        last_w: dict[bytes, int] = {}
        for w, t in enumerate(terms, start=1):
            tb = t.encode() if isinstance(t, str) else t
            prev = last_w.get(tb)
            occs.append((tb, w if prev is None else w - prev))
            last_w[tb] = w
            counts[tb] = counts.get(tb, 0) + 1
        return PreparedDoc(doclen=len(occs), uniq=tuple(counts),
                           counts=tuple(counts.values()), occs=tuple(occs))
    # doc-level: Counter's C-level counting keeps first-occurrence key
    # order (it is a dict), which the intern-order parity relies on.
    # Counting BEFORE encoding means only unique terms pay the encode.
    counts = Counter(terms)
    try:
        uniq = tuple(map(str.encode, counts))
    except TypeError:
        # bytes (or mixed str/bytes) tokens: a str token and its bytes
        # twin must merge, so encode every token first, then count
        tbs = [t.encode() if type(t) is str else t for t in terms]
        counts = Counter(tbs)
        return PreparedDoc(doclen=len(tbs), uniq=tuple(counts),
                           counts=tuple(counts.values()))
    cv = tuple(counts.values())
    return PreparedDoc(doclen=sum(cv), uniq=uniq, counts=cv)


def prepare_batch(docs, word_level: bool = False) -> list[PreparedDoc]:
    """Prepare a batch of documents (each a term sequence or an already
    prepared record, passed through unchanged)."""
    return [d if isinstance(d, PreparedDoc) else prepare_doc(d, word_level)
            for d in docs]


__all__ = ["PreparedDoc", "prepare_doc", "prepare_batch"]
