# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .lifecycle import FreezeManager, FreezePolicy, StaticTier  # noqa: F401
from .static_index import (  # noqa: F401
    StaticIndex,
    StaticPostingsCursor,
    StaticWordCursor,
)
