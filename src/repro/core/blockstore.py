"""The fixed-block index array ``I`` (paper §3.2 Figure 3, §3.3 Algorithm 1).

The whole dynamic index is one flat byte array, logically divided into B-byte
*slots*.  Every term owns a chain of blocks inside that array:

  head block   [n_ptr|d_num 4][t_ptr 4][last_d 4][ft 4][nx][tlen][term ...][postings ... 0 0]
  full block   [n_ptr 4][postings .................................... 0 0]
  tail block   [d_num 4][postings ... <write cursor H.nx> .................]

Notes on the layout (inferred byte-exactly from the paper):

  * H.nx is initialised to 4h + 2 + |t| = 18 + |t|  (§3.3), so the head holds
    four 4-byte fields (n_ptr, t_ptr, last_d, ft) plus one byte of nx and one
    byte of term length before the term string.  Table 7 confirms: head "link
    pointers" = 8 B/term (n_ptr + t_ptr) and "vocabulary" = last_d + ft + nx +
    tlen + term = 10 + |t| bytes/term.
  * Slot 0 of every block is *shared* between d_num (first docid in the block,
    live while the block is the tail — Algorithm 1 line 8/12) and n_ptr (chain
    link, written when the block stops being the tail — line 13).  The head
    block participates too: its slot 0 is d_num until the chain grows.
  * Variable-block mode (§5.4) adds two bytes to the head: nx widens to two
    bytes and a one-byte z (block-sequence position) is added, so postings
    start at 20 + |t|.  Block z's size is recomputed from the deterministic
    growth schedule (see extensible.py).
  * Word-level mode (§5.1) additionally tracks last_w (4 bytes) in the head so
    w-gaps within a document can be formed; postings start 4 bytes later.

All postings are Double-VByte coded (Algorithm 2); F=1 degenerates to two
plain VByte codes per posting.  Unused trailing bytes are null, which the
decoder recognises as end-of-block (the sentinel property, §2.2).

Pointers (n_ptr, t_ptr) are *slot offsets* in units of B bytes — the paper's
"array offsets ... rather than byte-addressed pointers", h = 4 bytes each,
capping the index at 2^32 blocks.
"""

from __future__ import annotations

import numpy as np

from .dvbyte import (
    dvbyte_decode_from,
    dvbyte_encode_into,
    dvbyte_len,
    vbyte_decode_from,
)
from .extensible import Const, GrowthPolicy

H = 4  # link-pointer width in bytes (paper: h = 4)

# head-block field offsets
_OFF_NPTR = 0  # shared with d_num
_OFF_TPTR = 4
_OFF_LASTD = 8
_OFF_FT = 12
_OFF_NX = 16  # 1 byte (const) or 2 bytes (variable)


class BlockStore:
    """The index array ``I`` plus Algorithm 1.

    Parameters
    ----------
    B:        base block size in bytes (paper sweeps 40..80; 64 is typical)
    policy:   growth policy (Const/Expon/Triangle); Const is the paper's §3
    F:        Double-VByte fold threshold (4 doc-level, 3 word-level, 1=VByte)
    word_level: store ⟨d,w⟩ postings (§5.1) instead of ⟨d,f⟩
    """

    def __init__(self, B: int = 64, policy: GrowthPolicy | None = None,
                 F: int = 4, word_level: bool = False,
                 initial_slots: int = 1024):
        if policy is None:
            policy = Const(B=B)
        if policy.B != B:
            raise ValueError("policy base size must equal B")
        if B < 40:
            raise ValueError("block sizes less than 40 cannot be used (§4.4)")
        self.B = B
        self.policy = policy
        self.const_mode = policy.is_const()
        if self.const_mode and B > 255:
            raise ValueError("Const mode needs B <= 255 (1-byte nx)")
        self.F = F
        self.word_level = word_level
        self.I = np.zeros(initial_slots * B, dtype=np.uint8)
        self.nblocks = 0  # global slot counter (Algorithm 1's nblocks)
        # head-layout geometry
        self.nx_width = 1 if self.const_mode else 2
        self.z_width = 0 if self.const_mode else 1
        self.lastw_width = 4 if word_level else 0
        # postings start inside a head block at: 16 + nx + z + lastw + 1 + |t|
        self.head_fixed = 16 + self.nx_width + self.z_width + self.lastw_width + 1

    # ------------------------------------------------------------------
    # low-level field accessors (little-endian ints inside the byte array)
    # ------------------------------------------------------------------

    def _get_u32(self, byte_off: int) -> int:
        return int(self.I[byte_off:byte_off + 4].view(np.uint32)[0])

    def _set_u32(self, byte_off: int, v: int) -> None:
        self.I[byte_off:byte_off + 4].view(np.uint32)[0] = v

    def _slot_base(self, ptr: int) -> int:
        return ptr * self.B

    # head-block field access; ``hb`` = byte offset of the head block
    def get_tptr(self, hb: int) -> int:
        return self._get_u32(hb + _OFF_TPTR)

    def set_tptr(self, hb: int, v: int) -> None:
        self._set_u32(hb + _OFF_TPTR, v)

    def get_lastd(self, hb: int) -> int:
        return self._get_u32(hb + _OFF_LASTD)

    def set_lastd(self, hb: int, v: int) -> None:
        self._set_u32(hb + _OFF_LASTD, v)

    def get_ft(self, hb: int) -> int:
        return self._get_u32(hb + _OFF_FT)

    def set_ft(self, hb: int, v: int) -> None:
        self._set_u32(hb + _OFF_FT, v)

    def get_nx(self, hb: int) -> int:
        if self.nx_width == 1:
            return int(self.I[hb + _OFF_NX])
        return int(self.I[hb + _OFF_NX]) | (int(self.I[hb + _OFF_NX + 1]) << 8)

    def set_nx(self, hb: int, v: int) -> None:
        self.I[hb + _OFF_NX] = v & 0xFF
        if self.nx_width == 2:
            self.I[hb + _OFF_NX + 1] = (v >> 8) & 0xFF

    def get_z(self, hb: int) -> int:
        if self.const_mode:
            return 0  # unused: every block is B bytes
        return int(self.I[hb + _OFF_NX + 2])

    def set_z(self, hb: int, v: int) -> None:
        if not self.const_mode:
            self.I[hb + _OFF_NX + 2] = min(v, 255)

    def get_lastw(self, hb: int) -> int:
        return self._get_u32(hb + 16 + self.nx_width + self.z_width)

    def set_lastw(self, hb: int, v: int) -> None:
        self._set_u32(hb + 16 + self.nx_width + self.z_width, v)

    def term_bytes(self, hb: int) -> bytes:
        tl_off = hb + self.head_fixed - 1
        tlen = int(self.I[tl_off])
        return bytes(self.I[tl_off + 1:tl_off + 1 + tlen])

    # ------------------------------------------------------------------
    # block geometry
    # ------------------------------------------------------------------

    def block_size_at(self, z: int) -> int:
        """Size in bytes of the z-th block (1-based) of any chain."""
        if self.const_mode:
            return self.B
        return self.policy.block_size(z, H)

    def _slots_for(self, nbytes: int) -> int:
        return (nbytes + self.B - 1) // self.B

    def clone(self) -> "BlockStore":
        """Deep snapshot: a private copy of the index array.  The lifecycle's
        background freeze thread reads the clone while ingest keeps writing
        into the original — they share no mutable state (the growth policy is
        stateless and safely shared)."""
        out = BlockStore.__new__(BlockStore)
        out.B = self.B
        out.policy = self.policy
        out.const_mode = self.const_mode
        out.F = self.F
        out.word_level = self.word_level
        out.I = self.I[: self.nblocks * self.B].copy()
        out.nblocks = self.nblocks
        out.nx_width = self.nx_width
        out.z_width = self.z_width
        out.lastw_width = self.lastw_width
        out.head_fixed = self.head_fixed
        return out

    def _ensure_capacity(self, extra_slots: int) -> None:
        need = (self.nblocks + extra_slots) * self.B
        if need > len(self.I):
            new = max(need, 2 * len(self.I))
            grown = np.zeros(new, dtype=np.uint8)
            grown[: len(self.I)] = self.I
            self.I = grown

    # ------------------------------------------------------------------
    # term creation (§3.3: "an empty head block is allocated")
    # ------------------------------------------------------------------

    def new_head(self, term: bytes) -> int:
        """Allocate a head block for a new term; returns its slot pointer."""
        if len(term) > 255:
            raise ValueError("terms are broken at 20 chars upstream; >255 invalid")
        first_size = self.block_size_at(1)
        slots = self._slots_for(first_size)
        self._ensure_capacity(slots)
        h_ptr = self.nblocks
        self.nblocks += slots
        hb = self._slot_base(h_ptr)
        start = self.head_fixed + len(term)
        if start + 2 > first_size:
            raise ValueError(
                f"term of {len(term)} bytes cannot fit a head block of {first_size}")
        # zero-init is already guaranteed; set fields
        self.set_tptr(hb, h_ptr)  # head is its own tail initially
        self.set_lastd(hb, 0)
        self.set_ft(hb, 0)
        self.set_nx(hb, start)
        self.set_z(hb, 1)
        self.I[hb + self.head_fixed - 1] = len(term)
        self.I[hb + self.head_fixed:hb + self.head_fixed + len(term)] = (
            np.frombuffer(term, dtype=np.uint8))
        return h_ptr

    # ------------------------------------------------------------------
    # Algorithm 1: add_posting
    # ------------------------------------------------------------------

    def add_posting(self, h_ptr: int, d: int, second: int) -> None:
        """Append posting ⟨d, second⟩ for the term whose head block is h_ptr.

        ``second`` is f (doc-level) or the w-gap payload (word-level; caller
        computes w-gaps, we compute d-gaps).  Faithful to Algorithm 1 with the
        word-level +1 shift of §5.1 and variable blocks of §5.4.
        """
        B, F = self.B, self.F
        hb = self._slot_base(h_ptr)
        t_ptr = self.get_tptr(hb)
        tb = self._slot_base(t_ptr)
        last_d = self.get_lastd(hb)
        if self.word_level:
            gap = d - last_d + 1  # §5.1: +1 so the coded value is > 0
            major, minor = second, gap  # double_vbyte_encode(w, g) — the twist
        else:
            gap = d - last_d
            major, minor = gap, second
        virgin = self.get_ft(hb) == 0
        nbytes = dvbyte_len(major, minor, F)
        nx = self.get_nx(hb)
        z = self.get_z(hb) if not self.const_mode else None
        tail_cap = B if self.const_mode else self.block_size_at(z)
        if nx + nbytes > tail_cap:  # line 6: posting does not fit
            # line 8: b-gap relative to the first docnum of the (old) tail
            t_dnum = self._get_u32(tb + _OFF_NPTR)
            if self.word_level:
                bgap = d - t_dnum + 1
                major, minor = second, bgap
            else:
                bgap = d - t_dnum
                major, minor = bgap, second
            # line 11: close off the old tail with null bytes
            old_end = tb + tail_cap
            self.I[tb + nx:old_end] = 0
            # allocate the new tail block (lines 10/13/15)
            new_z = (z + 1) if z is not None else 2
            new_size = self.block_size_at(new_z)
            slots = self._slots_for(new_size)
            self._ensure_capacity(slots)
            new_ptr = self.nblocks
            self.nblocks += slots
            nb = self._slot_base(new_ptr)
            self._set_u32(nb + _OFF_NPTR, d)        # line 12: T.d_num <- d
            self._set_u32(tb + _OFF_NPTR, new_ptr)  # line 13: F.n_ptr <- nblocks
            self.set_tptr(hb, new_ptr)              # line 13: H.t_ptr
            self.set_nx(hb, H)                      # line 14
            self.set_z(hb, new_z)
            t_ptr, tb = new_ptr, nb
            nx = H
            nbytes = dvbyte_len(major, minor, F)    # line 16 (b-gap recode)
        elif virgin:
            # first posting lands in the head: slot 0 doubles as d_num while
            # the head is still the tail (it is 0 — "no postings yet" — until
            # now, which is what makes the first b-gap come out as d itself).
            self._set_u32(hb + _OFF_NPTR, d)
        # line 17: code the posting into the tail at T[H.nx]
        pos = dvbyte_encode_into(self.I, tb + nx, major, minor, F)
        self.set_nx(hb, pos - tb)   # line 18
        self.set_lastd(hb, d)       # line 19
        self.set_ft(hb, self.get_ft(hb) + 1)  # line 20

    def append_run(self, h_ptr: int, postings) -> None:
        """Append a run of postings ``[(d, second), ...]`` for one term.

        The batched write path: equivalent to calling :meth:`add_posting`
        once per pair (the decoded chain is identical), but the head fields
        (t_ptr, last_d, ft, nx, the tail's d_num) are hoisted into locals
        for the whole run, and the run is Double-VByte coded CONTIGUOUSLY
        into one staging bytearray that is flushed into the block array
        with a single slice assignment per block segment — the per-posting
        accessor walk that dominates ``add_document`` is paid once per run
        instead.  Only a block-boundary posting is recoded mid-stage (its
        b-gap changes, Algorithm 1 line 8; everything after it is coded
        relative to its predecessor and is unaffected).

        ``postings`` must be in ingest order (ascending d; word-level runs
        repeat d once per occurrence, in word order) — exactly the per-term
        subsequence a sequential ingest would have produced.
        """
        B, F = self.B, self.F
        word = self.word_level
        const = self.const_mode
        I = self.I
        hb = h_ptr * B
        # one slice view reads all four head u32s (vs four accessor calls)
        d_num, t_ptr, last_d, ft = I[hb:hb + 16].view(np.uint32).tolist()
        tb = t_ptr * B
        nx = int(I[hb + 16])
        if not const:
            nx |= int(I[hb + 17]) << 8
        z = 1 if const else int(I[hb + 18])
        tail_cap = B if const else self.block_size_at(z)
        # first docnum of the current tail (slot 0 — d_num while tail)
        t_dnum = d_num if t_ptr == h_ptr else self._get_u32(tb + _OFF_NPTR)
        buf = bytearray()
        ba = buf.append
        flush_at = tb + nx          # byte offset the staged run lands at
        for d, second in postings:
            # Algorithm 2 inlined, size-first: the code's byte length is
            # arithmetic on the folded value, so the fit check (line 6)
            # runs before any byte is staged — no rollback
            if word:
                major, minor = second, d - last_d + 1
            else:
                major, minor = d - last_d, second
            if minor < F:
                x = (major - 1) * F + minor
                y = 0
                nbytes = 1 if x < 0x80 else 2 if x < 0x4000 else \
                    3 if x < 0x200000 else 4 if x < 0x10000000 else 5
            else:
                x = major * F
                y = minor - F + 1
                nbytes = (1 if x < 0x80 else 2 if x < 0x4000 else
                          3 if x < 0x200000 else 4 if x < 0x10000000 else 5) \
                    + (1 if y < 0x80 else 2 if y < 0x4000 else
                       3 if y < 0x200000 else 4 if y < 0x10000000 else 5)
            if nx + nbytes > tail_cap:      # Algorithm 1 line 6
                # recode relative to the old tail's first docnum (line 8)
                if word:
                    minor = d - t_dnum + 1
                else:
                    major = d - t_dnum
                if minor < F:
                    x, y = (major - 1) * F + minor, 0
                else:
                    x, y = major * F, minor - F + 1
                if buf:                     # flush the staged run so far
                    I[flush_at:flush_at + len(buf)] = \
                        np.frombuffer(buf, dtype=np.uint8)
                    buf = bytearray()
                    ba = buf.append
                I[tb + nx:tb + tail_cap] = 0    # line 11: null-close
                new_z = z + 1
                new_size = B if const else self.block_size_at(new_z)
                slots = self._slots_for(new_size)
                self._ensure_capacity(slots)
                I = self.I                  # may have been reallocated
                new_ptr = self.nblocks
                self.nblocks += slots
                nb = new_ptr * B
                self._set_u32(nb + _OFF_NPTR, d)        # line 12
                self._set_u32(tb + _OFF_NPTR, new_ptr)  # line 13
                self.set_z(hb, new_z)
                t_ptr, tb, z = new_ptr, nb, new_z
                tail_cap = new_size
                nx = H
                flush_at = tb + H
                t_dnum = d
                before = len(buf)           # line 16/17: recoded emit
                while x >= 0x80:
                    ba(0x80 | (x & 0x7F))
                    x >>= 7
                ba(x)
                if y:
                    while y >= 0x80:
                        ba(0x80 | (y & 0x7F))
                        y >>= 7
                    ba(y)
                nx += len(buf) - before     # b-gap code length differs
                last_d = d
                ft += 1
                continue
            if ft == 0:
                # first posting ever: head slot 0 doubles as d_num
                self._set_u32(hb + _OFF_NPTR, d)
                t_dnum = d
            while x >= 0x80:                # line 17: stage the code bytes
                ba(0x80 | (x & 0x7F))
                x >>= 7
            ba(x)
            if y:
                while y >= 0x80:
                    ba(0x80 | (y & 0x7F))
                    y >>= 7
                ba(y)
            nx += nbytes                # line 18 (staged)
            last_d = d
            ft += 1                     # line 20
        if buf:
            I[flush_at:flush_at + len(buf)] = \
                np.frombuffer(buf, dtype=np.uint8)
        # one slice view writes t_ptr / last_d / ft back (line 13/19/20)
        I[hb + 4:hb + 16].view(np.uint32)[:] = (t_ptr, last_d, ft)
        I[hb + 16] = nx & 0xFF          # line 18
        if not const:
            I[hb + 17] = (nx >> 8) & 0xFF

    # ------------------------------------------------------------------
    # chain traversal / decoding (§3.6)
    # ------------------------------------------------------------------

    def chain_slots(self, h_ptr: int):
        """Yield (slot_ptr, z, is_tail) for every block in a term's chain."""
        hb = self._slot_base(h_ptr)
        t_ptr = self.get_tptr(hb)
        ptr, z = h_ptr, 1
        while True:
            if ptr == t_ptr:
                yield ptr, z, True
                return
            yield ptr, z, False
            ptr = self._get_u32(self._slot_base(ptr) + _OFF_NPTR)
            z += 1

    def decode_postings(self, h_ptr: int):
        """Decode a term's full postings list.

        Returns (docids, seconds) as int64 arrays; for doc-level ``seconds``
        is f_{t,i}; for word-level it is the w-gap payload (callers rebuild
        absolute word positions per document if needed).
        """
        B, F = self.B, self.F
        hb = self._slot_base(h_ptr)
        nx = self.get_nx(hb)
        docids: list[int] = []
        seconds: list[int] = []
        prev_block_first_d = 0
        cur_d = 0
        for ptr, z, is_tail in self.chain_slots(h_ptr):
            base = self._slot_base(ptr)
            if ptr == h_ptr:
                start = self.head_fixed + int(self.I[base + self.head_fixed - 1])
            else:
                start = H
            cap = self.block_size_at(z) if not self.const_mode else B
            end = (base + nx) if is_tail else (base + cap)
            pos = base + start
            first_in_block = True
            while pos < end:
                if self.I[pos] == 0:  # null sentinel: rest of block unused
                    break
                (major, minor), pos = dvbyte_decode_from(self.I, pos, F)
                if self.word_level:
                    # encode order was (major=w_payload, minor=g_stored)
                    w_payload, g_stored = major, minor
                    if first_in_block and ptr != h_ptr:
                        cur_d = prev_block_first_d + (g_stored - 1)
                    else:
                        cur_d = cur_d + (g_stored - 1)
                    seconds.append(w_payload)
                else:
                    g = major
                    if first_in_block and ptr != h_ptr:
                        cur_d = prev_block_first_d + g  # b-gap
                    else:
                        cur_d = cur_d + g
                    seconds.append(minor)
                docids.append(cur_d)
                if first_in_block:
                    prev_block_first_d = cur_d
                    first_in_block = False
        return (np.asarray(docids, dtype=np.int64),
                np.asarray(seconds, dtype=np.int64))

    # ------------------------------------------------------------------
    # space accounting (Table 7)
    # ------------------------------------------------------------------

    def used_bytes(self) -> int:
        return self.nblocks * self.B

    def component_breakdown(self, head_ptrs) -> dict:
        """Byte-accurate Table 7 component analysis over all chains."""
        B = self.B
        stats = {
            "head_blocks": 0, "head_link": 0, "head_vocab": 0,
            "head_postings": 0, "head_nulls": 0,
            "full_blocks": 0, "full_link": 0, "full_postings": 0,
            "full_nulls": 0,
            "tail_blocks": 0, "tail_docnum": 0, "tail_postings": 0,
            "tail_unused": 0,
        }
        for h_ptr in head_ptrs:
            hb = self._slot_base(h_ptr)
            nx = self.get_nx(hb)
            tlen = int(self.I[hb + self.head_fixed - 1])
            single = self.get_tptr(hb) == h_ptr
            for ptr, z, is_tail in self.chain_slots(h_ptr):
                base = self._slot_base(ptr)
                cap = self.block_size_at(z) if not self.const_mode else B
                if ptr == h_ptr:
                    stats["head_blocks"] += 1
                    stats["head_link"] += 2 * H  # n_ptr + t_ptr
                    stats["head_vocab"] += (self.head_fixed - 2 * H) + tlen
                    start = self.head_fixed + tlen
                    if is_tail:
                        stats["head_postings"] += nx - start
                        stats["head_nulls"] += cap - nx
                    else:
                        data_end = self._data_end(base + start, base + cap)
                        stats["head_postings"] += data_end - (base + start)
                        stats["head_nulls"] += (base + cap) - data_end
                elif is_tail and not single:
                    stats["tail_blocks"] += 1
                    stats["tail_docnum"] += H
                    stats["tail_postings"] += nx - H
                    stats["tail_unused"] += cap - nx
                else:
                    stats["full_blocks"] += 1
                    stats["full_link"] += H
                    data_end = self._data_end(base + H, base + cap)
                    stats["full_postings"] += data_end - (base + H)
                    stats["full_nulls"] += (base + cap) - data_end
        return stats

    def _data_end(self, start: int, end: int) -> int:
        seg = self.I[start:end]
        nz = np.flatnonzero(seg)
        return start + (int(nz[-1]) + 1 if len(nz) else 0)
