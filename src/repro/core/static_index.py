"""Static compressed inverted index (paper §3.1, Table 9 reference systems).

The dynamic shard is periodically frozen into a static, maximally-compressed
form (Figure 2).  We implement two static codecs standing in for the paper's
PISA baselines:

  * ``bp128``  — blocks of 128 d-gaps bit-packed at the per-block maximum
    width plus per-block skip data (the SIMD-BP128 layout of Lemire &
    Boytsov, as used by PISA-BP128);
  * ``interp`` — binary interpolative coding (Moffat & Stuiver), the
    PISA-Interp stand-in: docids coded recursively mid-first with minimal
    binary ranges; frequencies coded interpolatively over their prefix sums.

``freeze`` converts a DynamicIndex (one full decode + re-encode pass — the
paper's "fast conversion of the dynamic index to a 'normal' static compressed
inverted index"), and both codecs are measured in benchmarks/table9.

Beyond the offline Table-9 measurement, the static index is a live SERVING
tier (see ``core/lifecycle.py``): ``postings_iter`` returns a
:class:`StaticPostingsCursor` with the same ``next``/``seek_geq`` protocol as
``core.query.PostingsCursor``, so DAAT conjunctive evaluation runs directly
over the compressed image.  For bp128 the cursor skips block-at-a-time using
a per-list skip table (last docid per 128-gap block, recorded at encode
time; the in-stream bit offsets are recovered from the existing 5-bit width
headers, so the only extra stored state is one docid per block).  Interp has
no block structure — its cursor decodes the list once and seeks by binary
search.

Word-level indexes (§5.1's ⟨d,w⟩ postings — the paper's "only a small amount
more for word-level indexing") freeze too: each term's occurrence stream is
regrouped into three streams — unique-docid d-gaps, per-doc position counts,
and the flat within-doc w-gap stream — each coded under the list's codec.
The docid stream keeps the exact doc-level block structure, so the bp128
skip table still skips BY DOCID and ``seek_geq`` is unchanged; positions are
decoded lazily (per 128-occurrence block) only when a phrase/proximity
operator asks for them via :meth:`StaticWordCursor.positions`.  Under interp
the counts are coded as strictly-increasing prefix sums (the frequency
trick) and the w-gaps as their own prefix-sum sequence, which is strictly
increasing because every w-gap is >= 1.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .index import DynamicIndex

# --------------------------------------------------------------------------
# bit-level IO
# --------------------------------------------------------------------------


class BitWriter:
    def __init__(self):
        self.words: list[int] = []
        self._cur = 0
        self._fill = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        self._cur |= (value & ((1 << nbits) - 1)) << self._fill
        self._fill += nbits
        while self._fill >= 32:
            self.words.append(self._cur & 0xFFFFFFFF)
            self._cur >>= 32
            self._fill -= 32

    def flush(self) -> np.ndarray:
        if self._fill:
            self.words.append(self._cur & 0xFFFFFFFF)
            self._cur = 0
            self._fill = 0
        return np.asarray(self.words, dtype=np.uint32)

    def bit_length(self) -> int:
        return 32 * len(self.words) + self._fill


class BitReader:
    def __init__(self, words: np.ndarray):
        self.words = words
        self.pos = 0

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        out = 0
        got = 0
        while got < nbits:
            w = int(self.words[self.pos >> 5])
            off = self.pos & 31
            take = min(32 - off, nbits - got)
            out |= ((w >> off) & ((1 << take) - 1)) << got
            got += take
            self.pos += take
        return out


def _bits_for(x: int) -> int:
    return max(1, int(x).bit_length())


# --------------------------------------------------------------------------
# binary interpolative coding
# --------------------------------------------------------------------------


def interp_encode(arr: np.ndarray, lo: int, hi: int, w: BitWriter) -> None:
    """Recursively encode a strictly-increasing sequence within [lo, hi]."""
    n = len(arr)
    if n == 0:
        return
    if hi - lo + 1 == n:
        return  # fully dense range: zero bits needed
    mid = n // 2
    x = int(arr[mid])
    a = lo + mid                 # minimum possible value of arr[mid]
    b = hi - (n - 1 - mid)       # maximum possible value
    span = b - a + 1
    if span > 1:
        w.write(x - a, _bits_for(span - 1))
    interp_encode(arr[:mid], lo, x - 1, w)
    interp_encode(arr[mid + 1:], x + 1, hi, w)


def interp_decode(n: int, lo: int, hi: int, r: BitReader, out: list) -> None:
    if n == 0:
        return
    if hi - lo + 1 == n:
        out.extend(range(lo, hi + 1))
        return
    mid = n // 2
    a = lo + mid
    b = hi - (n - 1 - mid)
    span = b - a + 1
    x = a + (r.read(_bits_for(span - 1)) if span > 1 else 0)
    left: list = []
    interp_decode(mid, lo, x - 1, r, left)
    out.extend(left)
    out.append(x)
    right: list = []
    interp_decode(n - 1 - mid, x + 1, hi, r, right)
    out.extend(right)


# --------------------------------------------------------------------------
# BP128-style bitpacking
# --------------------------------------------------------------------------

BP_BLOCK = 128


def bp_encode(values: np.ndarray, w: BitWriter) -> int:
    """Pack ``values`` in blocks of 128 at per-block max width.

    Returns total overhead bits (the 5-bit width headers)."""
    overhead = 0
    for i in range(0, len(values), BP_BLOCK):
        blk = values[i:i + BP_BLOCK]
        width = _bits_for(int(blk.max()))
        w.write(width, 5)
        overhead += 5
        for v in blk:
            w.write(int(v), width)
    return overhead


def bp_decode(n: int, r: BitReader) -> np.ndarray:
    out = np.empty(n, dtype=np.int64)
    i = 0
    while i < n:
        cnt = min(BP_BLOCK, n - i)
        width = r.read(5)
        for j in range(cnt):
            out[i + j] = r.read(width)
        i += cnt
    return out


# --------------------------------------------------------------------------
# the static index
# --------------------------------------------------------------------------


@dataclass
class TermList:
    """One term's compressed postings plus serving metadata.

    ``d_last`` (bp128 only) is the skip table: the docid of the last posting
    in each 128-gap block, ascending — ``seek_geq`` binary-searches it to
    land on the one block that must be decoded.  ``d_bits``/``f_bits`` cache
    the bit offset of each docid/frequency block's 5-bit width header; they
    are *derived* from the headers on first cursor use, not stored, so they
    cost no index bytes.

    Word-level lists reuse the same record: ``n`` counts UNIQUE docids (so
    docid block geometry and the skip table are identical to doc-level),
    ``sum_f`` is the total occurrence count (= length of the w-gap stream),
    and ``sum_w`` bounds the interp prefix-sum coding of the w-gaps.
    ``w_bits`` / ``occ_before`` are the lazily-derived position-stream block
    offsets and the exclusive per-docid-block occurrence prefix counts.
    """

    n: int
    words: np.ndarray
    last_d: int
    sum_f: int
    d_last: np.ndarray | None = None   # (nblk,) skip table (bp128)
    d_bits: np.ndarray | None = None   # (nblk,) derived lazily
    f_bits: np.ndarray | None = None   # (nblk,) derived lazily
    sum_w: int = 0                     # word-level: sum of all w-gaps
    w_bits: np.ndarray | None = None   # word-level (bp128): derived lazily
    occ_before: np.ndarray | None = None  # word-level (bp128): derived
    blk_cache: dict | None = None      # decoded-block cache, lazily created
    #   by the first cursor: {block j: (docids, payloads)}.  Shared across
    #   cursors — serving creates a FRESH cursor per query, so without it
    #   every query re-runs the per-value bp128 unpack loops for the same
    #   hot blocks (the dominant cost of tiered conjunctive latency).  The
    #   arrays are read-only by contract; worst case it holds the decoded
    #   form of every touched block (~4× the compressed bytes, hot terms
    #   only).  Benign under concurrent readers: a lost race merely
    #   decodes a block twice.


class StaticIndex:
    """Frozen, maximally-compressed image of a dynamic index.

    ``word_level`` images store ⟨d,w⟩ occurrence streams (see the module
    docstring); doc-level images store ⟨d,f⟩.  ``epoch`` identifies the
    freeze generation this image belongs to (set by the lifecycle's
    :class:`~repro.core.lifecycle.FreezeManager`; it keys the serving
    layer's query-result cache).
    """

    def __init__(self, codec: str = "bp128", word_level: bool = False):
        assert codec in ("bp128", "interp")
        self.codec = codec
        self.word_level = word_level
        self.terms: dict[bytes, int] = {}
        self.lists: list[TermList] = []
        self.num_docs = 0
        self.num_postings = 0
        self.epoch = 0

    # -- encode ---------------------------------------------------------

    @classmethod
    def freeze(cls, index: DynamicIndex, codec: str = "bp128") -> "StaticIndex":
        """One full decode + re-encode pass over a dynamic index — the
        paper's "fast conversion ... to a 'normal' static compressed
        inverted index".  Word-level indexes freeze too: the decoded
        occurrence stream (docids repeat, seconds = w-gaps) is regrouped
        by ``add_list``.

        Freeze-time compaction: tombstoned docids are dropped from every
        list — the tier is rebuilt anyway, so the dead documents' postings
        (and their share of the encoded bytes) vanish for free.  Dropping a
        word-level document's whole occurrence run is safe because w-gaps
        are INTRA-document (each doc's first occurrence carries its
        absolute position).  ``num_docs`` stays the docid HORIZON — the
        docid space is never renumbered, so the tiered merge arithmetic is
        untouched."""
        out = cls(codec, word_level=index.word_level)
        out.num_docs = index.num_docs
        dead = index.tombstones
        deadarr = (np.asarray(sorted(dead), dtype=np.int64) if dead
                   else None)
        for term, h_ptr in sorted(index.terms()):
            docids, seconds = index.store.decode_postings(h_ptr)
            if deadarr is not None and len(docids):
                keep = ~np.isin(docids, deadarr)
                docids, seconds = docids[keep], seconds[keep]
            out.add_list(term, docids, seconds)
        return out

    def _empty_list(self, tb: bytes) -> None:
        # empty and pathological lists must not crash a lifecycle swap
        self.terms[tb] = len(self.lists)
        self.lists.append(TermList(0, np.zeros(0, np.uint32), 0, 0,
                                   d_last=np.zeros(0, np.int64)))

    def add_list(self, term: bytes, docids: np.ndarray, seconds: np.ndarray):
        """Append one term's full postings list.

        Doc-level: ``docids`` strictly increasing, ``seconds`` = f_{t,d}.
        Word-level: occurrence streams — ``docids`` non-decreasing (one
        entry per occurrence) and ``seconds`` = w-gaps, exactly the shape
        ``BlockStore.decode_postings`` returns.
        """
        docids = np.asarray(docids, dtype=np.int64)
        seconds = np.asarray(seconds, dtype=np.int64)
        tb = bytes(term)
        if self.word_level:
            self._add_list_word(tb, docids, seconds)
            return
        fs = seconds
        n = len(docids)
        if n == 0:
            self._empty_list(tb)
            return
        w = BitWriter()
        d_last = None
        if self.codec == "interp":
            interp_encode(docids, 1, int(docids[-1]), w)
            # frequencies: strictly-increasing prefix sums, coded the same way
            csum = np.cumsum(fs)
            interp_encode(csum + np.arange(n), 1, int(csum[-1]) + n, w)
        else:
            gaps = np.diff(docids, prepend=0)
            bp_encode(gaps, w)
            bp_encode(fs, w)
            # skip table: last docid of each 128-gap block
            d_last = docids[np.minimum(
                np.arange(BP_BLOCK - 1, n + BP_BLOCK - 1, BP_BLOCK), n - 1)]
        self.terms[tb] = len(self.lists)
        self.lists.append(TermList(n, w.flush(), int(docids[-1]),
                                   int(fs.sum()), d_last=d_last))
        self.num_postings += n

    def _add_list_word(self, tb: bytes, docids: np.ndarray,
                       wgaps: np.ndarray) -> None:
        """Word-level encode: regroup the occurrence stream into unique-doc
        d-gaps + per-doc counts + the flat w-gap stream (all >= 1)."""
        n_occ = len(docids)
        if n_occ == 0:
            self._empty_list(tb)
            return
        # occurrence docids are non-decreasing: doc run-lengths = counts
        udocs, counts = np.unique(docids, return_counts=True)
        m = len(udocs)
        w = BitWriter()
        d_last = None
        if self.codec == "interp":
            interp_encode(udocs, 1, int(udocs[-1]), w)
            csum_c = np.cumsum(counts)
            interp_encode(csum_c + np.arange(m), 1, int(csum_c[-1]) + m, w)
            # w-gaps are >= 1, so their prefix sums are strictly increasing
            csum_w = np.cumsum(wgaps)
            interp_encode(csum_w, 1, int(csum_w[-1]), w)
        else:
            bp_encode(np.diff(udocs, prepend=0), w)
            bp_encode(counts, w)
            bp_encode(wgaps, w)
            d_last = udocs[np.minimum(
                np.arange(BP_BLOCK - 1, m + BP_BLOCK - 1, BP_BLOCK), m - 1)]
        self.terms[tb] = len(self.lists)
        self.lists.append(TermList(m, w.flush(), int(udocs[-1]), n_occ,
                                   d_last=d_last, sum_w=int(wgaps.sum())))
        self.num_postings += n_occ

    # -- decode ----------------------------------------------------------

    def _index_of(self, term) -> int | None:
        tb = term.encode() if isinstance(term, str) else bytes(term)
        return self.terms.get(tb)

    def postings(self, term) -> tuple[np.ndarray, np.ndarray]:
        """Full decode, mirroring ``DynamicIndex.postings`` exactly:
        doc-level -> (docids, f); word-level -> the occurrence stream
        (docids repeat per occurrence, seconds = w-gaps)."""
        ti = self._index_of(term)
        if ti is None:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        rec = self.lists[ti]
        if rec.n == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        if self.word_level:
            udocs, counts, wgaps = self._decode_word(rec)
            return np.repeat(udocs, counts), wgaps
        r = BitReader(rec.words)
        n = rec.n
        if self.codec == "interp":
            docids: list = []
            interp_decode(n, 1, rec.last_d, r, docids)
            shifted: list = []
            interp_decode(n, 1, rec.sum_f + n, r, shifted)
            csum = np.asarray(shifted, dtype=np.int64) - np.arange(n)
            fs = np.diff(csum, prepend=0)
            return np.asarray(docids, dtype=np.int64), fs
        gaps = bp_decode(n, r)
        fs = bp_decode(n, r)
        return np.cumsum(gaps), fs

    def word_postings(self, term
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Word-level grouped decode: (unique docids, per-doc counts,
        flat w-gap stream)."""
        if not self.word_level:
            raise ValueError("word_postings needs a word-level image")
        ti = self._index_of(term)
        if ti is None or self.lists[ti].n == 0:
            z = np.zeros(0, np.int64)
            return z, z.copy(), z.copy()
        return self._decode_word(self.lists[ti])

    def _decode_word_docs(self, rec: TermList, r: BitReader
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Decode the docid + count streams of a word-level list — the
        shared layout prefix under both codecs — leaving ``r`` positioned
        at the start of the w-gap stream."""
        m = rec.n
        if self.codec == "interp":
            udocs: list = []
            interp_decode(m, 1, rec.last_d, r, udocs)
            shifted: list = []
            interp_decode(m, 1, rec.sum_f + m, r, shifted)
            csum_c = np.asarray(shifted, dtype=np.int64) - np.arange(m)
            return np.asarray(udocs, dtype=np.int64), np.diff(csum_c,
                                                              prepend=0)
        gaps = bp_decode(m, r)
        counts = bp_decode(m, r)
        return np.cumsum(gaps), counts

    def _decode_word(self, rec: TermList
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        n_occ = rec.sum_f
        r = BitReader(rec.words)
        udocs, counts = self._decode_word_docs(rec, r)
        if self.codec == "interp":
            wsums: list = []
            interp_decode(n_occ, 1, rec.sum_w, r, wsums)
            wgaps = np.diff(np.asarray(wsums, dtype=np.int64), prepend=0)
        else:
            wgaps = bp_decode(n_occ, r)
        return udocs, counts, wgaps

    def doc_postings(self, term) -> tuple[np.ndarray, np.ndarray]:
        """Document-granular postings: (unique docids, doc-level f_{t,d}).

        The ranked serving path: word-level lists decode ONLY the docid and
        count streams (they are laid out ahead of the w-gap stream under
        both codecs), so scoring a term never pays for its positions."""
        ti = self._index_of(term)
        if ti is None:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        rec = self.lists[ti]
        if rec.n == 0:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        if not self.word_level:
            return self.postings(term)
        return self._decode_word_docs(rec, BitReader(rec.words))

    def ft(self, term) -> int:
        """f_t with the dynamic index's semantics: documents containing the
        term (doc-level) / total occurrences (word-level, §5.1)."""
        ti = self._index_of(term)
        if ti is None:
            return 0
        rec = self.lists[ti]
        return rec.sum_f if self.word_level else rec.n

    def postings_iter(self, term) -> "StaticPostingsCursor | None":
        """A DAAT cursor over the compressed list (None if term unknown or
        empty).  Protocol-compatible with ``core.query.PostingsCursor``;
        word-level images return a :class:`StaticWordCursor`, which adds
        ``positions()`` and reports per-doc occurrence counts as payload."""
        ti = self._index_of(term)
        if ti is None or self.lists[ti].n == 0:
            return None
        if self.word_level:
            return StaticWordCursor(self, ti)
        return StaticPostingsCursor(self, ti)

    # -- persistence (core/persist.py) -----------------------------------

    def to_arrays(self) -> tuple[dict, dict]:
        """Decompose the image into (meta, flat numpy arrays) for
        persistence: the compressed word streams and per-list scalars are
        concatenated with exclusive-prefix offsets, the term bytes into one
        blob.  Only STORED state is included — the lazily-derived caches
        (``d_bits``/``w_bits``/``occ_before``/``blk_cache``) are rebuilt on
        first cursor use, so ``from_arrays`` inverts this exactly and a
        restored tier serves byte-identical results."""
        order = sorted(self.terms.items(), key=lambda kv: kv[1])
        term_bytes = [tb for tb, _ in order]
        meta = {"codec": self.codec, "word_level": self.word_level,
                "num_docs": self.num_docs, "num_postings": self.num_postings,
                "epoch": self.epoch, "num_lists": len(self.lists)}

        def offsets(lengths):
            out = np.zeros(len(lengths) + 1, np.int64)
            np.cumsum(np.asarray(lengths, np.int64), out=out[1:])
            return out

        def concat(parts, dtype):
            parts = [np.asarray(p, dtype) for p in parts]
            return (np.concatenate(parts) if parts
                    else np.zeros(0, dtype))

        d_lasts = [r.d_last if r.d_last is not None
                   else np.zeros(0, np.int64) for r in self.lists]
        arrays = {
            "term_blob": np.frombuffer(b"".join(term_bytes), np.uint8).copy(),
            "term_off": offsets([len(t) for t in term_bytes]),
            "n": np.asarray([r.n for r in self.lists], np.int64),
            "last_d": np.asarray([r.last_d for r in self.lists], np.int64),
            "sum_f": np.asarray([r.sum_f for r in self.lists], np.int64),
            "sum_w": np.asarray([r.sum_w for r in self.lists], np.int64),
            "words": concat([r.words for r in self.lists], np.uint32),
            "words_off": offsets([len(r.words) for r in self.lists]),
            "dlast": concat(d_lasts, np.int64),
            "dlast_off": offsets([len(d) for d in d_lasts]),
        }
        return meta, arrays

    @classmethod
    def from_arrays(cls, meta: dict, arrays: dict) -> "StaticIndex":
        """Inverse of :meth:`to_arrays`.  ``d_last`` presence follows the
        codec invariant: interp lists store no skip table (None) while
        empty lists always carry a zero-length one (``_empty_list``)."""
        out = cls(meta["codec"], word_level=meta["word_level"])
        out.num_docs = int(meta["num_docs"])
        out.num_postings = int(meta["num_postings"])
        out.epoch = int(meta["epoch"])
        blob = arrays["term_blob"].tobytes()
        toff, woff, doff = (arrays["term_off"], arrays["words_off"],
                            arrays["dlast_off"])
        for i in range(int(meta["num_lists"])):
            n = int(arrays["n"][i])
            if n == 0:
                d_last = np.zeros(0, np.int64)
            elif out.codec == "interp":
                d_last = None
            else:
                d_last = arrays["dlast"][doff[i]:doff[i + 1]].copy()
            rec = TermList(
                n=n,
                words=arrays["words"][woff[i]:woff[i + 1]].copy(),
                last_d=int(arrays["last_d"][i]),
                sum_f=int(arrays["sum_f"][i]),
                d_last=d_last,
                sum_w=int(arrays["sum_w"][i]))
            out.terms[blob[int(toff[i]):int(toff[i + 1])]] = len(out.lists)
            out.lists.append(rec)
        return out

    # -- accounting (Table 9: "including vocabulary and other files") ----

    def total_bytes(self) -> int:
        postings = sum(4 * len(rec.words) for rec in self.lists)
        # vocabulary: term bytes + (offset, n, last_d, sum_f) per term;
        # word-level lists additionally store sum_w (interp bound)
        per_term = 20 if self.word_level else 16
        vocab = sum(len(t) + 1 for t in self.terms) + per_term * len(self.lists)
        # bp128 skip table: one stored docid per block (offsets are derived)
        skip = sum(4 * len(rec.d_last) for rec in self.lists
                   if rec.d_last is not None)
        return postings + vocab + skip

    def bytes_per_posting(self) -> float:
        return self.total_bytes() / max(1, self.num_postings)

    # -- skip-table completion (derived from the 5-bit width headers) ----

    def _block_offsets(self, rec: TermList):
        """Bit offsets of every docid/frequency block header, recovered by
        walking the in-stream width headers (no decode of the packed
        values)."""
        if rec.d_bits is not None:
            return rec.d_bits, rec.f_bits
        nblk = (rec.n + BP_BLOCK - 1) // BP_BLOCK
        d_bits = np.zeros(nblk, np.int64)
        f_bits = np.zeros(nblk, np.int64)
        r = BitReader(rec.words)
        off = 0
        for arr in (d_bits, f_bits):
            for j in range(nblk):
                arr[j] = off
                cnt = min(BP_BLOCK, rec.n - j * BP_BLOCK)
                r.pos = off
                width = r.read(5)
                off += 5 + width * cnt
        rec.d_bits, rec.f_bits = d_bits, f_bits
        return d_bits, f_bits

    def _word_offsets(self, rec: TermList):
        """bp128 word-level stream geometry: bit offsets of every docid /
        count / w-gap block header, plus the exclusive occurrence-count
        prefix per docid block (``occ_before``) so ``positions()`` can map a
        (block, in-block doc) pair to its w-gap slice.  The offsets come
        from the width headers alone; ``occ_before`` needs one decode of the
        count blocks — done once per list, cached on the record."""
        if rec.d_bits is not None:
            return rec.d_bits, rec.f_bits, rec.w_bits, rec.occ_before
        nblkd = (rec.n + BP_BLOCK - 1) // BP_BLOCK
        nblkw = (rec.sum_f + BP_BLOCK - 1) // BP_BLOCK
        d_bits = np.zeros(nblkd, np.int64)
        c_bits = np.zeros(nblkd, np.int64)
        w_bits = np.zeros(nblkw, np.int64)
        r = BitReader(rec.words)
        off = 0
        for arr, total in ((d_bits, rec.n), (c_bits, rec.n),
                           (w_bits, rec.sum_f)):
            for j in range(len(arr)):
                arr[j] = off
                cnt = min(BP_BLOCK, total - j * BP_BLOCK)
                r.pos = off
                width = r.read(5)
                off += 5 + width * cnt
        occ_before = np.zeros(nblkd + 1, np.int64)
        for j in range(nblkd):
            cnt = min(BP_BLOCK, rec.n - j * BP_BLOCK)
            r.pos = int(c_bits[j])
            occ_before[j + 1] = occ_before[j] + int(bp_decode(cnt, r).sum())
        rec.d_bits, rec.f_bits = d_bits, c_bits
        rec.w_bits, rec.occ_before = w_bits, occ_before
        return d_bits, c_bits, w_bits, occ_before


class StaticPostingsCursor:
    """DAAT cursor over one compressed static list: ``next``/``seek_geq``
    with (docid, payload) state, the protocol of
    ``core.query.PostingsCursor``.

    bp128: decodes one 128-posting block at a time; ``seek_geq`` first
    binary-searches the skip table (``d_last``) so only the single candidate
    block is ever decoded.  interp: the recursion has no sub-list entry
    points, so the list is decoded once up front and sought by binary
    search.
    """

    __slots__ = ("static", "rec", "_blk", "_d", "_f", "_k",
                 "docid", "payload", "_exhausted")

    def __init__(self, static: StaticIndex, ti: int):
        self.static = static
        self.rec = static.lists[ti]
        self._blk = -1
        self._d: np.ndarray | None = None
        self._f: np.ndarray | None = None
        self._k = -1
        self.docid = 0
        self.payload = 0
        self._exhausted = self.rec.n == 0
        if not self._exhausted:
            self._load_block(0)
            self._advance_to(0, 0)

    # -- block machinery -------------------------------------------------

    def _nblocks(self) -> int:
        if self.static.codec == "interp":
            return 1
        return (self.rec.n + BP_BLOCK - 1) // BP_BLOCK

    def _load_block(self, j: int) -> None:
        rec = self.rec
        if rec.blk_cache is None:
            rec.blk_cache = {}
        hit = rec.blk_cache.get(j)
        if hit is not None:
            self._d, self._f = hit
            self._blk = j
            return
        if self.static.codec == "interp":
            # one "block" = the whole list
            r = BitReader(rec.words)
            docids: list = []
            interp_decode(rec.n, 1, rec.last_d, r, docids)
            shifted: list = []
            interp_decode(rec.n, 1, rec.sum_f + rec.n, r, shifted)
            csum = np.asarray(shifted, dtype=np.int64) - np.arange(rec.n)
            self._d = np.asarray(docids, dtype=np.int64)
            self._f = np.diff(csum, prepend=0)
            self._blk = 0
            rec.blk_cache[0] = (self._d, self._f)
            return
        d_bits, f_bits = self.static._block_offsets(rec)
        cnt = min(BP_BLOCK, rec.n - j * BP_BLOCK)
        r = BitReader(rec.words)
        r.pos = int(d_bits[j])
        gaps = bp_decode(cnt, r)
        r.pos = int(f_bits[j])
        fs = bp_decode(cnt, r)
        base = int(self.rec.d_last[j - 1]) if j > 0 else 0
        self._d = base + np.cumsum(gaps)
        self._f = fs
        self._blk = j
        rec.blk_cache[j] = (self._d, self._f)

    def _advance_to(self, j: int, k: int) -> None:
        self._k = k
        self.docid = int(self._d[k])
        self.payload = int(self._f[k])

    # -- protocol ---------------------------------------------------------

    def next(self) -> bool:
        if self._exhausted:
            return False
        if self._k + 1 < len(self._d):
            self._advance_to(self._blk, self._k + 1)
            return True
        if self._blk + 1 < self._nblocks():
            self._load_block(self._blk + 1)
            self._advance_to(self._blk, 0)
            return True
        self._exhausted = True
        return False

    def seek_geq(self, target: int) -> bool:
        """Position on the first posting with docid >= target."""
        if self._exhausted:
            return False
        if self.docid >= target:
            return True
        if target > self.rec.last_d:
            self._exhausted = True
            return False
        if self.static.codec == "bp128":
            # skip: first block whose last docid >= target
            j = int(np.searchsorted(self.rec.d_last, target, side="left"))
            if j > self._blk:
                self._load_block(j)
                self._advance_to(j, 0)
                if self.docid >= target:
                    return True
        k = int(np.searchsorted(self._d, target, side="left"))
        if k >= len(self._d):  # only when already in the final block
            self._exhausted = True
            return False
        self._advance_to(self._blk, k)
        return True

    @property
    def exhausted(self) -> bool:
        return self._exhausted


class StaticWordCursor(StaticPostingsCursor):
    """DAAT cursor over one compressed word-level list.

    Iterates UNIQUE docids (the shape every conjunctive/ranked consumer
    expects), with ``payload`` = the doc's occurrence count f_{t,d}; the
    within-doc word positions of the current document come from
    ``positions()`` — the protocol ``core.query.WordPostingsCursor`` speaks
    for the dynamic chains, so phrase evaluation is uniform across tiers.

    ``next``/``seek_geq`` (including the skip-table block jump) are
    inherited unchanged: the docid stream has the same 128-gap block
    geometry as a doc-level list.  Positions are decoded lazily: one
    128-occurrence w-gap block at a time, only when ``positions()`` is
    called (bp128); interp decodes the whole list once, like its doc-level
    cursor.
    """

    __slots__ = ("_c", "_ccum", "_occ0", "_wg", "_wg_blocks")

    def __init__(self, static: StaticIndex, ti: int):
        self._wg = None
        self._wg_blocks: dict[int, np.ndarray] = {}
        super().__init__(static, ti)

    # -- block machinery (docid + count streams) -------------------------

    def _load_block(self, j: int) -> None:
        rec = self.rec
        if self.static.codec == "interp":
            udocs, counts, wgaps = self.static._decode_word(rec)
            self._d = udocs
            self._c = counts
            self._ccum = np.cumsum(counts) - counts  # exclusive prefix
            self._occ0 = 0
            self._wg = wgaps
            self._blk = 0
            return
        d_bits, c_bits, _w_bits, occ_before = self.static._word_offsets(rec)
        cnt = min(BP_BLOCK, rec.n - j * BP_BLOCK)
        r = BitReader(rec.words)
        r.pos = int(d_bits[j])
        gaps = bp_decode(cnt, r)
        r.pos = int(c_bits[j])
        counts = bp_decode(cnt, r)
        base = int(rec.d_last[j - 1]) if j > 0 else 0
        self._d = base + np.cumsum(gaps)
        self._c = counts
        self._ccum = np.cumsum(counts) - counts
        self._occ0 = int(occ_before[j])
        self._blk = j

    def _advance_to(self, j: int, k: int) -> None:
        self._k = k
        self.docid = int(self._d[k])
        self.payload = int(self._c[k])

    # -- position access --------------------------------------------------

    def _wgap_range(self, lo: int, hi: int) -> np.ndarray:
        """w-gaps [lo, hi) of the flat occurrence stream (bp128: decode and
        cache only the 128-occurrence blocks that overlap the range)."""
        if self._wg is not None:          # interp: fully decoded
            return self._wg[lo:hi]
        rec = self.rec
        _d, _c, w_bits, _o = self.static._word_offsets(rec)
        parts = []
        for j in range(lo // BP_BLOCK, (hi - 1) // BP_BLOCK + 1):
            blk = self._wg_blocks.get(j)
            if blk is None:
                cnt = min(BP_BLOCK, rec.sum_f - j * BP_BLOCK)
                r = BitReader(rec.words)
                r.pos = int(w_bits[j])
                blk = bp_decode(cnt, r)
                self._wg_blocks[j] = blk
            s = j * BP_BLOCK
            parts.append(blk[max(lo - s, 0):hi - s])
        return np.concatenate(parts)

    def positions(self) -> np.ndarray:
        """Absolute word positions of the current document, ascending
        (cumulative sum of its w-gap slice)."""
        lo = self._occ0 + int(self._ccum[self._k])
        return np.cumsum(self._wgap_range(lo, lo + self.payload))
