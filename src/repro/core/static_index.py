"""Static compressed inverted index (paper §3.1, Table 9 reference systems).

The dynamic shard is periodically frozen into a static, maximally-compressed
form (Figure 2).  We implement two static codecs standing in for the paper's
PISA baselines:

  * ``bp128``  — blocks of 128 d-gaps bit-packed at the per-block maximum
    width plus per-block skip data (the SIMD-BP128 layout of Lemire &
    Boytsov, as used by PISA-BP128);
  * ``interp`` — binary interpolative coding (Moffat & Stuiver), the
    PISA-Interp stand-in: docids coded recursively mid-first with minimal
    binary ranges; frequencies coded interpolatively over their prefix sums.

``freeze`` converts a DynamicIndex (one full decode + re-encode pass — the
paper's "fast conversion of the dynamic index to a 'normal' static compressed
inverted index"), and both codecs are measured in benchmarks/table9.
"""

from __future__ import annotations

import numpy as np

from .index import DynamicIndex

# --------------------------------------------------------------------------
# bit-level IO
# --------------------------------------------------------------------------


class BitWriter:
    def __init__(self):
        self.words: list[int] = []
        self._cur = 0
        self._fill = 0

    def write(self, value: int, nbits: int) -> None:
        if nbits == 0:
            return
        self._cur |= (value & ((1 << nbits) - 1)) << self._fill
        self._fill += nbits
        while self._fill >= 32:
            self.words.append(self._cur & 0xFFFFFFFF)
            self._cur >>= 32
            self._fill -= 32

    def flush(self) -> np.ndarray:
        if self._fill:
            self.words.append(self._cur & 0xFFFFFFFF)
            self._cur = 0
            self._fill = 0
        return np.asarray(self.words, dtype=np.uint32)

    def bit_length(self) -> int:
        return 32 * len(self.words) + self._fill


class BitReader:
    def __init__(self, words: np.ndarray):
        self.words = words
        self.pos = 0

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        out = 0
        got = 0
        while got < nbits:
            w = int(self.words[self.pos >> 5])
            off = self.pos & 31
            take = min(32 - off, nbits - got)
            out |= ((w >> off) & ((1 << take) - 1)) << got
            got += take
            self.pos += take
        return out


def _bits_for(x: int) -> int:
    return max(1, int(x).bit_length())


# --------------------------------------------------------------------------
# binary interpolative coding
# --------------------------------------------------------------------------


def interp_encode(arr: np.ndarray, lo: int, hi: int, w: BitWriter) -> None:
    """Recursively encode a strictly-increasing sequence within [lo, hi]."""
    n = len(arr)
    if n == 0:
        return
    if hi - lo + 1 == n:
        return  # fully dense range: zero bits needed
    mid = n // 2
    x = int(arr[mid])
    a = lo + mid                 # minimum possible value of arr[mid]
    b = hi - (n - 1 - mid)       # maximum possible value
    span = b - a + 1
    if span > 1:
        w.write(x - a, _bits_for(span - 1))
    interp_encode(arr[:mid], lo, x - 1, w)
    interp_encode(arr[mid + 1:], x + 1, hi, w)


def interp_decode(n: int, lo: int, hi: int, r: BitReader, out: list) -> None:
    if n == 0:
        return
    if hi - lo + 1 == n:
        out.extend(range(lo, hi + 1))
        return
    mid = n // 2
    a = lo + mid
    b = hi - (n - 1 - mid)
    span = b - a + 1
    x = a + (r.read(_bits_for(span - 1)) if span > 1 else 0)
    left: list = []
    interp_decode(mid, lo, x - 1, r, left)
    out.extend(left)
    out.append(x)
    right: list = []
    interp_decode(n - 1 - mid, x + 1, hi, r, right)
    out.extend(right)


# --------------------------------------------------------------------------
# BP128-style bitpacking
# --------------------------------------------------------------------------

BP_BLOCK = 128


def bp_encode(values: np.ndarray, w: BitWriter) -> int:
    """Pack ``values`` in blocks of 128 at per-block max width.

    Returns total overhead bits (the 5-bit width headers)."""
    overhead = 0
    for i in range(0, len(values), BP_BLOCK):
        blk = values[i:i + BP_BLOCK]
        width = _bits_for(int(blk.max()))
        w.write(width, 5)
        overhead += 5
        for v in blk:
            w.write(int(v), width)
    return overhead


def bp_decode(n: int, r: BitReader) -> np.ndarray:
    out = np.empty(n, dtype=np.int64)
    i = 0
    while i < n:
        cnt = min(BP_BLOCK, n - i)
        width = r.read(5)
        for j in range(cnt):
            out[i + j] = r.read(width)
        i += cnt
    return out


# --------------------------------------------------------------------------
# the static index
# --------------------------------------------------------------------------


class StaticIndex:
    """Frozen, maximally-compressed image of a dynamic doc-level index."""

    def __init__(self, codec: str = "bp128"):
        assert codec in ("bp128", "interp")
        self.codec = codec
        self.terms: dict[bytes, int] = {}
        self.lists: list[tuple] = []  # (n, words, last_docid) per term
        self.num_docs = 0
        self.num_postings = 0

    # -- encode ---------------------------------------------------------

    @classmethod
    def freeze(cls, index: DynamicIndex, codec: str = "bp128") -> "StaticIndex":
        if index.word_level:
            raise ValueError("static conversion implemented for doc-level")
        out = cls(codec)
        out.num_docs = index.num_docs
        for term, h_ptr in sorted(index.terms()):
            docids, fs = index.store.decode_postings(h_ptr)
            out.add_list(term, docids, fs)
        return out

    def add_list(self, term: bytes, docids: np.ndarray, fs: np.ndarray):
        w = BitWriter()
        n = len(docids)
        if self.codec == "interp":
            interp_encode(docids, 1, int(docids[-1]), w)
            # frequencies: strictly-increasing prefix sums, coded the same way
            csum = np.cumsum(fs)
            interp_encode(csum + np.arange(n), 1, int(csum[-1]) + n, w)
        else:
            gaps = np.diff(docids, prepend=0)
            bp_encode(gaps, w)
            bp_encode(fs, w)
        self.terms[bytes(term)] = len(self.lists)
        self.lists.append((n, w.flush(), int(docids[-1]), int(fs.sum())))
        self.num_postings += n

    # -- decode ----------------------------------------------------------

    def postings(self, term) -> tuple[np.ndarray, np.ndarray]:
        tb = term.encode() if isinstance(term, str) else bytes(term)
        ti = self.terms.get(tb)
        if ti is None:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        n, words, last_d, sum_f = self.lists[ti]
        r = BitReader(words)
        if self.codec == "interp":
            docids: list = []
            interp_decode(n, 1, last_d, r, docids)
            shifted: list = []
            interp_decode(n, 1, sum_f + n, r, shifted)
            csum = np.asarray(shifted, dtype=np.int64) - np.arange(n)
            fs = np.diff(csum, prepend=0)
            return np.asarray(docids, dtype=np.int64), fs
        gaps = bp_decode(n, r)
        fs = bp_decode(n, r)
        return np.cumsum(gaps), fs

    # -- accounting (Table 9: "including vocabulary and other files") ----

    def total_bytes(self) -> int:
        postings = sum(4 * len(wds) for _, wds, _, _ in self.lists)
        # vocabulary: term bytes + (offset, n, last_d, sum_f) per term
        vocab = sum(len(t) + 1 for t in self.terms) + 16 * len(self.lists)
        return postings + vocab

    def bytes_per_posting(self) -> float:
        return self.total_bytes() / max(1, self.num_postings)
