"""Periodic collation (paper §5.5).

Rewrites the index array ``I`` so that every term's chain of blocks is stored
contiguously, in chain order.  Nothing inside any block changes except the
``n_ptr``/``t_ptr`` link fields; the hash array is updated to the new head
offsets.  On the paper's hardware this restored spatial locality (66% fewer
cache misses, conjunctive latency halved — Table 14); on TPU the same
permutation turns per-block gathers into a single contiguous DMA per term
(see device_index.py, which requires a collated image).

The paper performs the permutation through a disk file with ingest stalled;
we perform it in memory with the same observable result (a brief
stop-the-world copy), and expose ``collate()`` both as an in-place operation
and as a pure function returning a new index.

Collation is the FREEZE point of the engine's device-image lifecycle
(``repro.engine``): ``Engine.collate_now`` collates, snapshots the result as
the frozen device image, and captures a ``DeltaBaseline`` so every later
refresh ships only post-freeze blocks to the device.  ``collation_stats``
quantifies how fragmented the chains currently are — the signal for deciding
when a full re-collation pays for itself.
"""

from __future__ import annotations

import numpy as np

from .blockstore import _OFF_NPTR, _OFF_TPTR, BlockStore
from .index import DynamicIndex


def collate(index: DynamicIndex) -> DynamicIndex:
    """Return a new DynamicIndex whose chains are contiguous (§5.5)."""
    store = index.store
    B = store.B
    new_store = BlockStore(B=B, policy=store.policy, F=store.F,
                           word_level=store.word_level,
                           initial_slots=max(1, store.nblocks))
    new_hash = np.zeros_like(index.hash)
    write_ptr = 0
    # §5.5: visit every non-empty element of (a copy of) the hash array; for
    # each term copy head block then the rest of the chain, rewriting links.
    for slot in np.flatnonzero(index.hash):
        h_ptr = int(index.hash[slot]) - 1
        chain = list(store.chain_slots(h_ptr))
        new_ptrs = []
        p = write_ptr
        for ptr, z, _ in chain:
            size = B if store.const_mode else store.block_size_at(z)
            slots = (size + B - 1) // B
            new_ptrs.append(p)
            p += slots
        # copy block bytes
        for (ptr, z, _), np_ in zip(chain, new_ptrs):
            size = B if store.const_mode else store.block_size_at(z)
            src = ptr * B
            dst = np_ * B
            new_store.I[dst:dst + size] = store.I[src:src + size]
        # rewrite links: n_ptr of every non-tail block, and head t_ptr
        hb = new_ptrs[0] * B
        new_store._set_u32(hb + _OFF_TPTR, new_ptrs[-1])
        for i in range(len(new_ptrs) - 1):
            base = new_ptrs[i] * B
            new_store._set_u32(base + _OFF_NPTR, new_ptrs[i + 1])
        new_hash[slot] = new_ptrs[0] + 1
        write_ptr = p
    new_store.nblocks = write_ptr
    out = DynamicIndex.__new__(DynamicIndex)
    out.store = new_store
    out.word_level = index.word_level
    out.F = index.F
    out.hash = new_hash
    out.vocab_size = index.vocab_size
    out.num_docs = index.num_docs
    out.num_postings = index.num_postings
    out.num_words = index.num_words
    out.tombstones = set(index.tombstones)
    out._cache = {}
    return out


def collation_stats(index: DynamicIndex) -> dict:
    """Fragmentation report: how far the store is from collated order.

    Returns chain/block counts plus ``fragmented_blocks`` — blocks that do
    not sit at their chain-contiguous position (each is one non-sequential
    cache line / DMA descriptor at query time).  ``frag_ratio`` near 0 means
    a fresh collation would buy little (Table 14's locality win is already
    in hand)."""
    store = index.store
    B = store.B
    chains = blocks = fragmented = 0
    for h_ptr in index.head_ptrs():
        chains += 1
        expect = h_ptr
        for ptr, z, _ in store.chain_slots(h_ptr):
            blocks += 1
            if ptr != expect:
                fragmented += 1
            size = B if store.const_mode else store.block_size_at(z)
            expect = ptr + (size + B - 1) // B
    return {"chains": chains, "blocks": blocks,
            "fragmented_blocks": fragmented,
            "frag_ratio": fragmented / max(1, blocks)}


def is_collated(index: DynamicIndex) -> bool:
    """True if every chain occupies consecutive slots in chain order."""
    store = index.store
    B = store.B
    for h_ptr in index.head_ptrs():
        expect = h_ptr
        for ptr, z, _ in store.chain_slots(h_ptr):
            if ptr != expect:
                return False
            size = B if store.const_mode else store.block_size_at(z)
            expect = ptr + (size + B - 1) // B
    return True
