"""DynamicIndex: the immediate-access index of paper §3 (ingest side).

Combines the BlockStore (Figure 3 / Algorithm 1) with the vocabulary hash
array of §3.2: "a hash array of 32-bit integers that stores block offsets ...
twice the size of the collection vocabulary (using an extensible hashing
technique) ... a simple linear advance collision resolution technique",
giving O(|t|+1) expected lookup.  The hash array stores h_ptr+1 (0 = empty
slot) and is costed at ``4 * len(hash)`` bytes, which equals the paper's
``8v`` when the load factor is 1/2.

Documents are ordinal, 1-based (d-gaps must be >= 1).  ``add_document``
implements §3.3: parse, sort-count term occurrences, then one ``add_posting``
per unique term (doc-level) or per occurrence (word-level §5.1).

Ingest and query may interleave freely: the structure is always consistent
after each ``add_document`` returns (the paper's immediate-access property).
"""

from __future__ import annotations

from collections import Counter

import numpy as np

from .blockstore import BlockStore, H
from .extensible import GrowthPolicy, make_policy
from .prepare import PreparedDoc, prepare_batch

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


def fnv1a(term: bytes) -> int:
    """FNV-1a 64-bit hash, folded to 32 bits (cheap, good avalanche)."""
    h = _FNV_OFFSET
    for b in term:
        h = np.uint64((int(h) ^ b) * int(_FNV_PRIME) & 0xFFFFFFFFFFFFFFFF)
    return (int(h) ^ (int(h) >> 32)) & 0xFFFFFFFF


def group_occurrences(docids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(unique docids, run-length counts) of a non-decreasing occurrence
    stream — the one implementation of the doc-level grouping invariant
    (word-level postings repeat a docid once per occurrence, so the
    run-lengths ARE the per-document f_{t,d}), shared by the dynamic index,
    the query helpers, and the tiered view."""
    if len(docids) == 0:
        return docids, docids.copy()
    udocs, counts = np.unique(docids, return_counts=True)
    return udocs, counts.astype(np.int64)


class DynamicIndex:
    """An immediate-access dynamic inverted index (document- or word-level)."""

    def __init__(self, B: int = 64, growth: str | GrowthPolicy = "const",
                 F: int | None = None, word_level: bool = False,
                 expon_k: float = 1.1, initial_hash_bits: int = 10):
        policy = (growth if isinstance(growth, GrowthPolicy)
                  else make_policy(growth, B, expon_k))
        if F is None:
            F = 3 if word_level else 4  # paper defaults (§3.5, §5.1)
        self.store = BlockStore(B=B, policy=policy, F=F, word_level=word_level)
        self.word_level = word_level
        self.F = F
        self.hash = np.zeros(1 << initial_hash_bits, dtype=np.uint32)
        self.vocab_size = 0
        self.num_docs = 0
        self.num_postings = 0
        self.num_words = 0
        # deleted docids (docid SPACE is never renumbered — postings stay in
        # the BlockStore and every serving path masks members of this set;
        # the next static freeze drops them from the encoded tier instead)
        self.tombstones: set[int] = set()
        # host-side acceleration cache (pure cache of hash-array content; the
        # probe path below is the structure of record and tested against it)
        self._cache: dict[bytes, int] = {}

    # ------------------------------------------------------------------
    # vocabulary hash (§3.2)
    # ------------------------------------------------------------------

    def _probe(self, term: bytes):
        """Return (h_ptr or None, slot_index) via linear probing."""
        mask = len(self.hash) - 1
        i = fnv1a(term) & mask
        while True:
            v = int(self.hash[i])
            if v == 0:
                return None, i
            h_ptr = v - 1
            if self.store.term_bytes(h_ptr * self.store.B) == term:
                return h_ptr, i
            i = (i + 1) & mask

    def _grow_hash(self) -> None:
        old = self.hash
        self.hash = np.zeros(len(old) * 2, dtype=np.uint32)
        mask = len(self.hash) - 1
        for v in old[old != 0]:
            h_ptr = int(v) - 1
            term = self.store.term_bytes(h_ptr * self.store.B)
            i = fnv1a(term) & mask
            while self.hash[i] != 0:
                i = (i + 1) & mask
            self.hash[i] = v

    def lookup(self, term) -> int | None:
        """Term -> head-block slot pointer, or None."""
        tb = term.encode() if isinstance(term, str) else term
        hit = self._cache.get(tb)
        if hit is not None:
            return hit
        h_ptr, _ = self._probe(tb)
        return h_ptr

    def _lookup_or_create(self, tb: bytes) -> int:
        hit = self._cache.get(tb)
        if hit is not None:
            return hit
        h_ptr, slot = self._probe(tb)
        if h_ptr is None:
            if 2 * (self.vocab_size + 1) > len(self.hash):
                self._grow_hash()
                _, slot = self._probe(tb)
            h_ptr = self.store.new_head(tb)
            self.hash[slot] = h_ptr + 1
            self.vocab_size += 1
        self._cache[tb] = h_ptr
        return h_ptr

    # ------------------------------------------------------------------
    # ingest (§3.3)
    # ------------------------------------------------------------------

    def add_document(self, terms) -> int:
        """Ingest one document (a sequence of term strings/bytes).

        Returns the assigned ordinal document identifier (1-based).  The
        document is findable by queries the moment this method returns.
        """
        self.num_docs += 1
        d = self.num_docs
        self.num_words += len(terms)
        if self.word_level:
            # §5.1: one posting per occurrence, in word order (w is 1-based);
            # w-payload = w-gap since the previous same-doc occurrence.
            last_w: dict[bytes, int] = {}
            for w, t in enumerate(terms, start=1):
                tb = t.encode() if isinstance(t, str) else t
                h_ptr = self._lookup_or_create(tb)
                prev = last_w.get(tb)
                wgap = w if prev is None else w - prev
                last_w[tb] = w
                self.store.add_posting(h_ptr, d, wgap)
                self.num_postings += 1
        else:
            # sort-count within the document, then one posting per term
            counts = Counter(t.encode() if isinstance(t, str) else t
                             for t in terms)
            for tb, f in counts.items():
                h_ptr = self._lookup_or_create(tb)
                self.store.add_posting(h_ptr, d, f)
                self.num_postings += 1
        return d

    def add_documents(self, docs) -> list[int]:
        """Batched §3.3 ingest: returns the assigned docids, ascending.

        Answer-identical to a per-document :meth:`add_document` loop (same
        docids, same decoded chains, same vocabulary order), but the
        batch's postings are grouped per term first, so each term pays ONE
        chain-tail lookup and one staged Double-VByte append run
        (:meth:`BlockStore.append_run`) for the whole batch instead of the
        per-posting accessor walk.  Block ALLOCATION order differs from
        sequential ingest (all new heads first, overflow blocks per run),
        so the raw block array is not byte-comparable — every decoded
        answer is.

        ``docs`` may be raw term sequences or pre-tokenized
        :class:`~repro.core.prepare.PreparedDoc` records (the pipelined
        write path prepares off the writer thread).
        """
        return self.add_prepared(prepare_batch(docs, self.word_level))

    def add_prepared(self, prepared: list[PreparedDoc]) -> list[int]:
        """Ingest pre-tokenized documents (see :meth:`add_documents`)."""
        word = self.word_level
        runs: dict[bytes, list] = {}
        dids: list[int] = []
        d = self.num_docs
        nw = np_ = 0
        for p in prepared:
            d += 1
            dids.append(d)
            nw += p.doclen
            if word:
                np_ += len(p.occs)
                for tb, wgap in p.occs:
                    try:
                        runs[tb].append((d, wgap))
                    except KeyError:
                        runs[tb] = [(d, wgap)]
            else:
                np_ += len(p.uniq)
                for tb, f in zip(p.uniq, p.counts):
                    try:
                        runs[tb].append((d, f))
                    except KeyError:
                        runs[tb] = [(d, f)]
        self.num_words += nw
        self.num_postings += np_
        self.num_docs = d
        # runs iterate in first-occurrence order across the batch — the
        # same head-creation (and engine intern) order sequential ingest
        # would have produced
        append_run = self.store.append_run
        lookup = self._lookup_or_create
        for tb, run in runs.items():
            append_run(lookup(tb), run)
        return dids

    def add_runs(self, ndocs: int, nwords: int, npostings: int,
                 groups) -> None:
        """Append pre-grouped per-term posting runs.

        The fused batch path: ``Engine.add_documents`` groups the batch's
        postings per term during its own interning/bookkeeping pass and
        hands the runs straight down — one traversal of the batch instead
        of the second one :meth:`add_prepared` would cost on top.

        ``groups`` is an iterable of ``(term_bytes, [(d, f), ...])`` with
        each run in ingest order and terms in first-occurrence order (the
        head-creation order a sequential ingest would have produced);
        counters advance by the caller-computed totals.
        """
        append_run = self.store.append_run
        lookup = self._lookup_or_create
        for tb, run in groups:
            append_run(lookup(tb), run)
        self.num_docs += ndocs
        self.num_words += nwords
        self.num_postings += npostings

    def delete_document(self, docid: int) -> None:
        """Tombstone one document (the takedown primitive).

        The docid keeps its ordinal meaning — postings stay in the
        BlockStore and ``num_docs`` is NOT decremented, so round-robin
        arithmetic, tier horizons, and device images are all unaffected.
        Serving paths mask tombstoned docids; the next static freeze drops
        them from the encoded tier (see ``StaticIndex.freeze``)."""
        if not 1 <= docid <= self.num_docs:
            raise ValueError(f"docid {docid} out of range "
                             f"[1, {self.num_docs}]")
        if docid in self.tombstones:
            raise ValueError(f"docid {docid} already deleted")
        self.tombstones.add(docid)

    def clone(self) -> "DynamicIndex":
        """Deep snapshot sharing no mutable state with the original.

        One memcpy of the block array plus the hash array — cheap relative
        to any decode pass.  The lifecycle freeze hands the clone to a
        background thread for static conversion while ingest continues into
        the original (single-writer model preserved: the clone has no
        writer at all)."""
        out = DynamicIndex.__new__(DynamicIndex)
        out.store = self.store.clone()
        out.word_level = self.word_level
        out.F = self.F
        out.hash = self.hash.copy()
        out.vocab_size = self.vocab_size
        out.num_docs = self.num_docs
        out.num_postings = self.num_postings
        out.num_words = self.num_words
        out.tombstones = set(self.tombstones)
        out._cache = {}
        return out

    # ------------------------------------------------------------------
    # read access
    # ------------------------------------------------------------------

    def postings(self, term):
        """Decode a term's postings: (docids, f) doc-level or (docids, wgaps)."""
        h_ptr = self.lookup(term)
        if h_ptr is None:
            return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        return self.store.decode_postings(h_ptr)

    def doc_postings(self, term):
        """Document-granular postings: (unique docids, doc-level f_{t,d}).

        Identical to :meth:`postings` on doc-level indexes; word-level
        occurrence streams are grouped (docids are non-decreasing, so the
        run-lengths ARE the per-doc counts).  This is the shape every
        ranked scorer consumes — w-gaps must never be mistaken for term
        frequencies."""
        docids, seconds = self.postings(term)
        if not self.word_level:
            return docids, seconds
        return group_occurrences(docids)

    def ft(self, term) -> int:
        h_ptr = self.lookup(term)
        if h_ptr is None:
            return 0
        return self.store.get_ft(h_ptr * self.store.B)

    def head_ptrs(self):
        """All head-block slot pointers (via the hash array)."""
        return [int(v) - 1 for v in self.hash[self.hash != 0]]

    def terms(self):
        for h_ptr in self.head_ptrs():
            yield self.store.term_bytes(h_ptr * self.store.B), h_ptr

    # ------------------------------------------------------------------
    # space accounting (Tables 7/8/11/13: "all index costs")
    # ------------------------------------------------------------------

    def hash_bytes(self) -> int:
        return len(self.hash) * 4

    def total_bytes(self) -> int:
        return self.store.used_bytes() + self.hash_bytes()

    def bytes_per_posting(self) -> float:
        return self.total_bytes() / max(1, self.num_postings)

    def breakdown(self) -> dict:
        stats = self.store.component_breakdown(self.head_ptrs())
        stats["hash_bytes"] = self.hash_bytes()
        stats["total_bytes"] = self.total_bytes()
        stats["num_postings"] = self.num_postings
        stats["bytes_per_posting"] = self.bytes_per_posting()
        return stats

    def stats(self) -> dict:
        """Cheap O(1) summary counters (no chain walk, unlike
        ``breakdown``).  ``num_words`` counts every ingested token, so for
        word-level indexes ``bytes_per_posting`` IS the paper's §5.1
        bytes-per-word figure (one posting per occurrence) and for
        doc-level indexes ``bytes_per_word`` amortizes the index over the
        collection's token count (Table 11's denominator)."""
        return {
            "num_docs": self.num_docs,
            "deleted_docs": len(self.tombstones),
            "num_postings": self.num_postings,
            "num_words": self.num_words,
            "vocab_size": self.vocab_size,
            "word_level": self.word_level,
            "total_bytes": self.total_bytes(),
            "bytes_per_posting": self.bytes_per_posting(),
            "bytes_per_word": self.total_bytes() / max(1, self.num_words),
        }
