"""Engine snapshot/restore: crash-atomic persistence of the serving engine.

The trainer has had durable state since the checkpoint PR
(``repro.checkpoint.manager``); this module gives the SERVING side the same
guarantee — an :class:`~repro.engine.Engine` (or a whole
:class:`~repro.core.sharded_index.ShardedEngine` fleet) can be snapshotted
to disk and restored in a fresh process answering every query mode
byte-identically (docids, score doubles, tie order) to the never-restarted
original.  What is persisted is exactly the state of record:

  * the blockstore extents (``I[:nblocks*B]``) + the vocabulary hash array —
    the paper's whole dynamic index is these two flat arrays;
  * the term-id map, per-term ``f_t`` counters, and document lengths — the
    BM25 ``CollectionStats`` state the paper keeps outside the core index;
  * the published static tier, if any: the encoded :class:`StaticIndex`
    streams (via ``StaticIndex.to_arrays``) plus its docid horizon and
    epoch, so a restored engine resumes the tiered lifecycle mid-epoch;
  * engine configuration (B, growth policy, F, word_level, freeze policy)
    so restore rebuilds an identically-shaped engine without caller input.

Durability follows the same write-temp-then-atomic-rename discipline as the
checkpoint manager: every artifact is staged into a ``.tmp-<seq>`` directory,
``manifest.json`` (with a CRC per artifact) is written LAST, and the staging
directory is published with one ``os.rename`` — atomic on POSIX — so readers
can never observe a torn snapshot: either the rename happened and the
manifest (hence every artifact it checksums) is complete, or the directory
is still ``.tmp-`` and is ignored (and swept at the next snapshot).
Retention keeps the newest ``keep`` snapshots.

Concurrency: snapshots run on the engine's single writer thread, so all
dynamic state is stable for the duration; the only concurrently-mutated
field is the lifecycle's published ``tier``, which is read exactly ONCE
(one reference load of an immutable :class:`StaticTier`).  A snapshot taken
mid-background-freeze therefore captures the previous tier plus the full
dynamic image — still byte-identical to serve from, because the tiered
backend merges to the same results at ANY horizon.  Callers who want the
newest tier in the snapshot use ``FreezeManager.quiesce()`` first.

Fault injection (tests): set ``_CRASH_AT`` to one of :data:`CRASH_POINTS`
and the persist path raises :class:`SnapshotCrash` at that point,
simulating a process kill between artifact writes.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from dataclasses import asdict

import numpy as np

from .extensible import make_policy
from .index import DynamicIndex
from .lifecycle import FreezePolicy, StaticTier
from .static_index import StaticIndex

FORMAT_VERSION = 1
SNAP_PREFIX = "snap-"
TMP_PREFIX = ".tmp-"
MANIFEST = "manifest.json"

#: Injection points, in write order: "staged" fires right after the staging
#: dir is created; "blockstore" / "term_map" / "tier" after those artifact
#: groups are flushed; "manifest" after manifest.json is written but BEFORE
#: the atomic rename — the worst case, a byte-complete yet unpublished
#: snapshot.
CRASH_POINTS = ("staged", "blockstore", "term_map", "tier", "manifest")

_CRASH_AT: str | None = None  # tests monkeypatch this


class SnapshotCrash(RuntimeError):
    """Raised by the fault-injection hook to simulate a mid-persist kill."""


class SnapshotCorrupt(RuntimeError):
    """A published snapshot failed CRC or structural validation."""


def _crash(label: str) -> None:
    if _CRASH_AT == label:
        raise SnapshotCrash(f"injected crash at {label!r}")


# --------------------------------------------------------------------------
# checksummed artifact IO
# --------------------------------------------------------------------------


def _save_array(d: str, name: str, arr: np.ndarray, crcs: dict) -> None:
    path = os.path.join(d, name + ".npy")
    np.save(path, arr, allow_pickle=False)
    with open(path, "rb") as f:
        crcs[name] = zlib.crc32(f.read())


def _load_array(d: str, name: str, crcs: dict) -> np.ndarray:
    path = os.path.join(d, name + ".npy")
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except FileNotFoundError as e:
        raise SnapshotCorrupt(f"missing artifact {name!r} in {d}") from e
    if zlib.crc32(raw) != crcs.get(name):
        raise SnapshotCorrupt(f"CRC mismatch for artifact {name!r} in {d}")
    return np.load(path, allow_pickle=False)


def _blob(items: list[bytes]) -> tuple[np.ndarray, np.ndarray]:
    """(byte blob, exclusive-prefix offsets) of a list of byte strings."""
    off = np.zeros(len(items) + 1, np.int64)
    np.cumsum(np.asarray([len(t) for t in items], np.int64), out=off[1:])
    return np.frombuffer(b"".join(items), np.uint8).copy(), off


def _unblob(blob: np.ndarray, off: np.ndarray) -> list[bytes]:
    raw = blob.tobytes()
    return [raw[int(off[i]):int(off[i + 1])] for i in range(len(off) - 1)]


# --------------------------------------------------------------------------
# one engine's state <-> one directory
# --------------------------------------------------------------------------


def _write_engine_state(eng, d: str) -> dict:
    """Write one engine's full state into ``d``; returns its manifest
    fragment (config + counters + artifact CRCs)."""
    idx = eng.index
    store = idx.store
    crcs: dict[str, int] = {}
    _save_array(d, "blockstore", store.I[:store.nblocks * store.B], crcs)
    _crash("blockstore")
    _save_array(d, "hash", idx.hash, crcs)
    vocab_blob, vocab_off = _blob(eng.vocab)
    _save_array(d, "vocab_blob", vocab_blob, crcs)
    _save_array(d, "vocab_off", vocab_off, crcs)
    _save_array(d, "fts", np.asarray(eng._fts, np.int64), crcs)
    _save_array(d, "doclens", np.asarray(eng._doclens, np.int64), crcs)
    # tombstoned docids: the chains still hold the dead postings, so the
    # mask must survive the restart byte-for-byte (forward index + live
    # df/avgdl are derived from chains+tombstones at restore)
    _save_array(d, "tombstones",
                np.asarray(sorted(idx.tombstones), np.int64), crcs)
    _crash("term_map")
    # ONE load of the published tier reference: immutable payload, so the
    # snapshot is internally consistent even mid-background-freeze
    tier = eng.static_tier()
    tier_meta = None
    if tier is not None:
        meta, arrays = tier.index.to_arrays()
        for name, arr in arrays.items():
            _save_array(d, "tier_" + name, arr, crcs)
        tier_meta = dict(meta)
        tier_meta.update(tier_num_docs=tier.num_docs,
                         tier_num_postings=tier.num_postings,
                         tier_epoch=tier.epoch, encode_s=tier.encode_s,
                         tier_compacted=tier.compacted)
    _crash("tier")
    return {
        "engine": {
            "B": store.B,
            "growth": store.policy.name,
            "growth_k": getattr(store.policy, "k", None),
            "F": store.F,
            "word_level": store.word_level,
            "nblocks": store.nblocks,
            "version": eng.version,
            "vocab_size": idx.vocab_size,
            "num_docs": idx.num_docs,
            "num_postings": idx.num_postings,
            "num_words": idx.num_words,
        },
        "lifecycle": (asdict(eng.lifecycle.policy)
                      if eng.lifecycle is not None else None),
        "tier": tier_meta,
        "files": crcs,
    }


def _restore_engine_dir(d: str, frag: dict, engine_kwargs: dict):
    """Rebuild one Engine from a directory + its manifest fragment.

    ``engine_kwargs`` forwards runtime knobs (planner, force_backend,
    decode_fn, ...); the persisted configuration wins for index shape and
    freeze policy."""
    from ..engine import Engine

    cfg = frag["engine"]
    crcs = frag["files"]
    kwargs = dict(engine_kwargs)
    kwargs.pop("tier_policy", None)  # persisted policy wins
    eng = Engine(B=int(cfg["B"]), growth=cfg["growth"], F=int(cfg["F"]),
                 word_level=bool(cfg["word_level"]), **kwargs)
    policy = make_policy(cfg["growth"], int(cfg["B"]),
                         cfg.get("growth_k") or 1.1)
    idx = DynamicIndex(B=int(cfg["B"]), growth=policy, F=int(cfg["F"]),
                       word_level=bool(cfg["word_level"]))
    store = idx.store
    blocks = _load_array(d, "blockstore", crcs)
    nblocks = int(cfg["nblocks"])
    if len(blocks) != nblocks * store.B:
        raise SnapshotCorrupt(
            f"blockstore length {len(blocks)} != nblocks*B "
            f"({nblocks}*{store.B}) in {d}")
    store.I = np.ascontiguousarray(blocks, np.uint8)
    store.nblocks = nblocks
    idx.hash = np.ascontiguousarray(_load_array(d, "hash", crcs), np.uint32)
    idx.vocab_size = int(cfg["vocab_size"])
    idx.num_docs = int(cfg["num_docs"])
    idx.num_postings = int(cfg["num_postings"])
    idx.num_words = int(cfg["num_words"])
    eng.index = idx
    vocab = _unblob(_load_array(d, "vocab_blob", crcs),
                    _load_array(d, "vocab_off", crcs))
    eng.vocab = vocab
    eng._tid = {tb: i for i, tb in enumerate(vocab)}
    eng._fts = [int(x) for x in _load_array(d, "fts", crcs)]
    eng._doclens = [int(x) for x in _load_array(d, "doclens", crcs)]
    if "tombstones" in crcs:    # absent in pre-deletion snapshots
        idx.tombstones = {int(x) for x in _load_array(d, "tombstones", crcs)}
    # forward index, live document frequencies and the deleted-token total
    # are derived state: rebuild from the restored chains + tombstones
    eng._rebuild_forward()
    eng.version = int(cfg["version"])
    if frag["lifecycle"] is not None:
        eng.enable_tiering(FreezePolicy(**frag["lifecycle"]))
        tm = frag["tier"]
        if tm is not None:
            static = StaticIndex.from_arrays(
                tm, {name[len("tier_"):]: _load_array(d, name, crcs)
                     for name in crcs if name.startswith("tier_")})
            eng.lifecycle.tier = StaticTier(
                index=static, num_docs=int(tm["tier_num_docs"]),
                num_postings=int(tm["tier_num_postings"]),
                epoch=int(tm["tier_epoch"]), encode_s=tm["encode_s"],
                compacted=int(tm.get("tier_compacted", 0)))
    return eng


# --------------------------------------------------------------------------
# snapshot directory management: stage -> manifest -> atomic rename -> gc
# --------------------------------------------------------------------------


def _seq_of(name: str) -> int:
    return int(name[len(SNAP_PREFIX):])


def list_snapshots(root: str) -> list[str]:
    """Complete (manifest-bearing) snapshot dirs under ``root``, oldest
    first.  A ``snap-`` dir without a manifest cannot exist after an atomic
    publish, but is defensively excluded anyway."""
    if not os.path.isdir(root):
        return []
    out = [n for n in os.listdir(root)
           if n.startswith(SNAP_PREFIX)
           and os.path.exists(os.path.join(root, n, MANIFEST))]
    return [os.path.join(root, n) for n in sorted(out, key=_seq_of)]


def latest_snapshot(root: str) -> str | None:
    """Path of the newest complete snapshot under ``root``, or None."""
    snaps = list_snapshots(root)
    return snaps[-1] if snaps else None


def sweep_tmp(root: str) -> int:
    """Remove orphaned ``.tmp-`` staging dirs (crashed snapshots); returns
    the number swept.  Runs automatically at the start of every snapshot."""
    swept = 0
    if not os.path.isdir(root):
        return swept
    for n in os.listdir(root):
        if n.startswith(TMP_PREFIX):
            shutil.rmtree(os.path.join(root, n), ignore_errors=True)
            swept += 1
    return swept


def _next_seq(root: str) -> int:
    seqs = [_seq_of(n) for n in os.listdir(root)
            if n.startswith(SNAP_PREFIX)]
    return (max(seqs) + 1) if seqs else 1


def _gc(root: str, keep: int) -> None:
    snaps = list_snapshots(root)
    for p in snaps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def _publish(root: str, keep: int, write_payload) -> str:
    """The atomic-publish skeleton shared by engine and fleet snapshots:
    sweep orphans, stage everything under ``.tmp-<seq>``, write the
    manifest LAST, then one ``os.rename``."""
    os.makedirs(root, exist_ok=True)
    sweep_tmp(root)
    seq = _next_seq(root)
    tmp = os.path.join(root, f"{TMP_PREFIX}{seq:010d}")
    os.makedirs(tmp)
    _crash("staged")
    manifest = write_payload(tmp)
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f, sort_keys=True, indent=1)
    _crash("manifest")
    final = os.path.join(root, f"{SNAP_PREFIX}{seq:010d}")
    os.rename(tmp, final)
    _gc(root, keep)
    return final


def _resolve(path_or_root: str) -> str:
    """Accept either a snapshot dir or a root full of them."""
    if os.path.exists(os.path.join(path_or_root, MANIFEST)):
        return path_or_root
    snap = latest_snapshot(path_or_root)
    if snap is None:
        raise FileNotFoundError(
            f"no complete snapshot under {path_or_root!r}")
    return snap


def _read_manifest(snap: str, kind: str) -> dict:
    with open(os.path.join(snap, MANIFEST)) as f:
        man = json.load(f)
    if man.get("format") != FORMAT_VERSION:
        raise SnapshotCorrupt(
            f"unsupported snapshot format {man.get('format')!r} in {snap}")
    if man.get("kind") != kind:
        raise SnapshotCorrupt(
            f"snapshot {snap} is kind={man.get('kind')!r}, expected {kind!r}")
    return man


# --------------------------------------------------------------------------
# public API: single engine
# --------------------------------------------------------------------------


def save_engine(engine, root: str, *, keep: int = 3) -> str:
    """Snapshot ``engine`` under ``root``; returns the published snapshot
    dir.  Runs on the writer thread (the single-writer model all ingest
    follows); safe while a background freeze encode is in flight."""
    def payload(tmp: str) -> dict:
        frag = _write_engine_state(engine, tmp)
        return {"format": FORMAT_VERSION, "kind": "engine", **frag}

    return _publish(root, keep, payload)


def restore_engine(path_or_root: str, **engine_kwargs):
    """Rebuild an Engine from a snapshot dir (or the newest snapshot under
    a root).  ``engine_kwargs`` forwards runtime knobs (planner,
    force_backend, decode_fn, interpret, ...) — index shape and freeze
    policy always come from the manifest."""
    snap = _resolve(path_or_root)
    man = _read_manifest(snap, "engine")
    return _restore_engine_dir(snap, man, engine_kwargs)


# --------------------------------------------------------------------------
# public API: sharded fleet
# --------------------------------------------------------------------------


def save_sharded(sharded, root: str, *, keep: int = 3) -> str:
    """Snapshot a :class:`~repro.core.sharded_index.ShardedEngine`: one
    sub-directory per shard (each the same layout as a single-engine
    snapshot) plus the fleet state — the published ``_FleetCounts`` triple
    and the fleet-wide term document frequencies — all under ONE atomic
    rename, so the fleet can never be restored torn across shards."""
    counts = sharded._counts  # one load of the published snapshot

    def payload(tmp: str) -> dict:
        shards = []
        for s, eng in enumerate(sharded.engines):
            sd = os.path.join(tmp, f"shard-{s}")
            os.makedirs(sd)
            shards.append(_write_engine_state(eng, sd))
        terms = sorted(sharded._ft)
        ft_blob, ft_off = _blob(terms)
        crcs: dict[str, int] = {}
        _save_array(tmp, "ft_blob", ft_blob, crcs)
        _save_array(tmp, "ft_off", ft_off, crcs)
        _save_array(tmp, "ft_df",
                    np.asarray([sharded._ft[t] for t in terms], np.int64),
                    crcs)
        return {
            "format": FORMAT_VERSION, "kind": "sharded",
            "num_shards": sharded.num_shards,
            "max_in_flight": sharded.coordinator.max_in_flight,
            "counts": {"version": counts.version,
                       "num_docs": counts.num_docs,
                       "total_tokens": counts.total_tokens,
                       "deleted_docs": counts.deleted_docs},
            "shards": shards,
            "files": crcs,
        }

    return _publish(root, keep, payload)


def restore_sharded(path_or_root: str, *, parallel: bool = True,
                    max_in_flight: int | None = None, **engine_kwargs):
    """Rebuild a ShardedEngine fleet from a snapshot.  Shard engines are
    restored in shard order through the normal ``engine_factory`` seam, so
    the fleet wiring (stats provider, freeze coordinator registration,
    fan-out pool) is exactly the constructor's."""
    from .sharded_index import ShardedEngine, _FleetCounts

    snap = _resolve(path_or_root)
    man = _read_manifest(snap, "sharded")
    num_shards = int(man["num_shards"])
    shard_iter = iter(range(num_shards))

    def factory():
        s = next(shard_iter)
        return _restore_engine_dir(os.path.join(snap, f"shard-{s}"),
                                   man["shards"][s], engine_kwargs)

    fleet = ShardedEngine(
        num_shards=num_shards, engine_factory=factory,
        max_in_flight=(max_in_flight if max_in_flight is not None
                       else int(man["max_in_flight"])),
        parallel=parallel)
    c = man["counts"]
    fleet._counts = _FleetCounts(int(c["version"]), int(c["num_docs"]),
                                 int(c["total_tokens"]),
                                 int(c.get("deleted_docs", 0)))
    crcs = man["files"]
    terms = _unblob(_load_array(snap, "ft_blob", crcs),
                    _load_array(snap, "ft_off", crcs))
    df = _load_array(snap, "ft_df", crcs)
    fleet._ft = {t: int(df[i]) for i, t in enumerate(terms)}
    return fleet


__all__ = ["CRASH_POINTS", "SnapshotCrash", "SnapshotCorrupt",
           "save_engine", "restore_engine", "save_sharded",
           "restore_sharded", "list_snapshots", "latest_snapshot",
           "sweep_tmp", "FORMAT_VERSION"]
