"""Extensible-list growth strategies (paper §2.5, §5.3, §5.4).

Each strategy answers one question: *given that the first z blocks of a chain
are full and hold n payload bytes in total, how big should block z+1 be?*

  * ``Const(B)``    — Eq. 3:  B_{z+1} = B                       (paper §3)
  * ``Expon(B, k)`` — Eq. 5:  B_{z+1} = B*ceil((h+(k-1)n)/B)    (B&C 2005)
  * ``Triangle(B)`` — Eq. 6:  B_{z+1} = B*ceil((h+sqrt(2hn))/B) (paper §5.4)

All sizes are B-aligned multiples of the base block size, minimum B, and for
the variable strategies capped at 2^16 bytes with z capped at 256 (paper §5.4:
"block sizes capped at 2^16 bytes ... z a one-byte integer and capped at 256").

The key property (paper Eq. 1, Eq. 2, Figure 7):

  * Const/Expon overhead (links + tail wastage) is Θ(n);
  * Triangle overhead is Θ(sqrt(n)) — at n payload bytes the next block is
    ~sqrt(2hn), so links + expected half-empty tail are both O(sqrt(n)).

Because n is defined as the sum of *payload capacities* of completed blocks,
the whole size sequence is a pure function of z — both the writer (block
allocation) and the reader (finding where a full block ends) recompute it
deterministically from the 1-byte z field in the head block.  We memoise the
schedule per (strategy, B, h).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

MAX_BLOCK_BYTES = 1 << 16
MAX_Z = 256


@dataclass(frozen=True)
class GrowthPolicy:
    """Base class: subclasses define next_size(n, h) for the raw (unaligned)
    target; ``schedule`` materializes the B-aligned deterministic sequence."""

    B: int  # base (and minimum) block size in bytes
    name: str = "base"

    def is_const(self) -> bool:
        return False

    def _raw_next(self, n: int, h: int) -> float:
        raise NotImplementedError

    def block_size(self, z: int, h: int) -> int:
        """Size in bytes of the z-th block (1-based) of a chain."""
        return self.schedule(h)[min(z, MAX_Z) - 1]

    def schedule(self, h: int):
        """Deterministic per-z block sizes, computed once and cached."""
        key = ("_sched", h)
        cached = _SCHED_CACHE.get((self.name, self.B, h))
        if cached is not None:
            return cached
        sizes = [self.B]  # B_1 = B always
        n = self.B - h  # payload capacity accumulated so far
        for _ in range(MAX_Z - 1):
            raw = self._raw_next(n, h)
            aligned = self.B * max(1, math.ceil(raw / self.B))
            aligned = min(aligned, MAX_BLOCK_BYTES)
            sizes.append(aligned)
            n += aligned - h
        _SCHED_CACHE[(self.name, self.B, h)] = tuple(sizes)
        return _SCHED_CACHE[(self.name, self.B, h)]


_SCHED_CACHE: dict = {}


@dataclass(frozen=True)
class Const(GrowthPolicy):
    """Fixed-size blocks (Eq. 3).  Asymptotic overhead ratio h/(B-h)."""

    name: str = "const"

    def is_const(self) -> bool:
        return True

    def _raw_next(self, n: int, h: int) -> float:
        return self.B


@dataclass(frozen=True)
class Expon(GrowthPolicy):
    """Geometric growth (Eq. 5) with rate k; B&C favoured k = 1.1."""

    k: float = 1.1
    name: str = "expon"

    def _raw_next(self, n: int, h: int) -> float:
        return h + (self.k - 1.0) * n


@dataclass(frozen=True)
class Triangle(GrowthPolicy):
    """The paper's new strategy (Eq. 6): B_{z+1} ≈ h + sqrt(2 h n).

    Matches Eq. 2's optimum B = sqrt(2hn): at every moment the link overhead
    (~h n / B) and expected tail wastage (~B/2) are balanced, giving total
    overhead Θ(sqrt(n)) ∈ o(n) — strictly better asymptotics than any
    constant-ratio scheme.
    """

    name: str = "triangle"

    def _raw_next(self, n: int, h: int) -> float:
        return h + math.sqrt(2.0 * h * n)


def make_policy(name: str, B: int, k: float = 1.1) -> GrowthPolicy:
    name = name.lower()
    if name == "const":
        return Const(B=B)
    if name == "expon":
        return Expon(B=B, k=k)
    if name == "triangle":
        return Triangle(B=B)
    raise ValueError(f"unknown growth policy {name!r}")


def overhead_model(policy: GrowthPolicy, n: int, h: int) -> dict:
    """Analytic overhead (links + tail slack) if a chain holds exactly n
    payload bytes — used by tests to verify the Θ(sqrt(n)) vs Θ(n) claim.

    Beyond MAX_Z blocks the chain keeps allocating at the final (capped)
    size, matching the writer/reader saturation behaviour (§5.4)."""
    sizes = policy.schedule(h)
    total_payload = 0
    links = 0
    z = 0
    while total_payload < n:
        cap = sizes[min(z, MAX_Z - 1)] - h
        total_payload += cap
        links += h
        z += 1
    slack = total_payload - n
    return {"blocks": z, "link_bytes": links, "tail_slack": slack,
            "overhead": links + slack, "ratio": (links + slack) / max(n, 1)}
