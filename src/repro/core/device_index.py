"""Device-resident immediate-access index: the TPU query path.

This is the hardware adaptation described in DESIGN.md §2.  The collated
index image (§5.5 makes every chain contiguous, which is precisely what lets
a TPU fetch a term's postings as one dense slice) is uploaded as flat arrays,
and querying becomes a fixed-shape, fully data-parallel program:

  1. *chain gather* — every query term's blocks are fetched in one gather of
     shape (Q*T*MB, B) from the block array (MB = max blocks per term);
  2. *parallel Double-VByte decode* — terminator flag bits -> per-byte code
     index via cumulative ops -> payload shift/combine; the escape-pairing
     automaton of Algorithm 2 runs as one short lax.scan across byte
     positions, vectorized over every block in flight;
  3. *docid reconstruction* — per-block prefix sums of d-gaps plus a
     cumulative sum of leading b-gaps along each chain (§3.2's skip data);
  4. *scoring* — TF×IDF scatter-add into a dense per-shard accumulator and
     top-k, or conjunctive counting (a docid matches iff its hit count equals
     the number of query terms).

Everything below is pure jnp (the oracle); kernels/dvbyte_decode provides the
Pallas VMEM-tiled implementation of step 2 and tests assert equivalence.

The decoded-postings layout is (NBLK, B) "one potential value per byte
position" with a validity mask — no dynamic shapes anywhere.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .blockstore import _OFF_NPTR, H
from .collate import is_collated
from .dvbyte import dvbyte_decode_from
from .index import DynamicIndex


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceIndex:
    """Flat-array snapshot of a collated doc-level dynamic index."""

    blocks: jnp.ndarray      # (NB, B) uint8 — the index array I
    term_slot: jnp.ndarray   # (V,) i32 — first slot of each term's chain
    term_nblk: jnp.ndarray   # (V,) i32 — chain length in blocks
    term_skip: jnp.ndarray   # (V,) i32 — byte offset of postings in head
    term_nx: jnp.ndarray     # (V,) i32 — tail write cursor (bytes)
    term_ft: jnp.ndarray     # (V,) i32 — document frequency f_t
    num_docs: int            # static
    F: int                   # static fold threshold

    def tree_flatten(self):
        return ((self.blocks, self.term_slot, self.term_nblk, self.term_skip,
                 self.term_nx, self.term_ft), (self.num_docs, self.F))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, num_docs=aux[0], F=aux[1])


def build_device_image(index: DynamicIndex, vocab: list[bytes],
                       pad_blocks: int | None = None) -> DeviceIndex:
    """Snapshot a *collated, Const-mode, doc-level* index for the device."""
    store = index.store
    if not store.const_mode:
        raise ValueError("device images require Const blocks (B-addressable)")
    if index.word_level:
        raise ValueError("device images are doc-level")
    if not is_collated(index):
        raise ValueError("collate() the index before snapshotting (§5.5)")
    B = store.B
    V = len(vocab)
    slot = np.zeros(V, np.int32)
    nblk = np.zeros(V, np.int32)
    skip = np.zeros(V, np.int32)
    nxs = np.zeros(V, np.int32)
    fts = np.zeros(V, np.int32)
    for i, t in enumerate(vocab):
        h_ptr = index.lookup(t)
        if h_ptr is None:
            continue
        hb = h_ptr * B
        chain = list(store.chain_slots(h_ptr))
        slot[i] = h_ptr
        nblk[i] = len(chain)
        skip[i] = store.head_fixed + int(store.I[hb + store.head_fixed - 1])
        nxs[i] = store.get_nx(hb)
        fts[i] = store.get_ft(hb)
    nb = store.nblocks
    if pad_blocks is not None:
        nb = max(nb, pad_blocks)
    blocks = np.zeros((nb, B), np.uint8)
    blocks[: store.nblocks] = store.I[: store.nblocks * B].reshape(-1, B)
    return DeviceIndex(
        blocks=jnp.asarray(blocks), term_slot=jnp.asarray(slot),
        term_nblk=jnp.asarray(nblk), term_skip=jnp.asarray(skip),
        term_nx=jnp.asarray(nxs), term_ft=jnp.asarray(fts),
        num_docs=index.num_docs, F=index.F)


# --------------------------------------------------------------------------
# incremental device-image refresh: frozen image + live delta (engine/)
# --------------------------------------------------------------------------
#
# A full ``collate()`` + ``build_device_image()`` is stop-the-world; the
# engine instead keeps ONE frozen collated image plus a small ``DeltaIndex``
# covering only postings appended since the freeze.  Docids are ordinal and
# every document's postings are written before the next document starts, so
# docs <= baseline.num_docs live wholly in the frozen image and newer docs
# wholly in the delta: the two docid spaces are disjoint and merging per-image
# results (top-k concat / bitmap OR) is exact.


@dataclass
class DeltaBaseline:
    """Per-term tail state captured at freeze time (host-side numpy).

    For each term id the delta decoder later needs: which block was the tail
    at the freeze (``tail_slot``), where its write cursor stood (``nx``), the
    last docid coded (``lastd`` — new in-tail postings are plain d-gaps from
    it), the tail block's first docid (``dnum`` — blocks appended later code
    their leading b-gap against it), and ``ft`` (so refresh can detect which
    terms changed at all).
    """

    tail_slot: np.ndarray   # (Vf,) i64
    nx: np.ndarray          # (Vf,) i64
    lastd: np.ndarray       # (Vf,) i64
    dnum: np.ndarray        # (Vf,) i64
    ft: np.ndarray          # (Vf,) i64
    num_docs: int           # N at freeze time
    nblocks: int            # store.nblocks at freeze time

    @property
    def vocab_size(self) -> int:
        return len(self.tail_slot)


def capture_delta_baseline(index: DynamicIndex,
                           vocab: list[bytes]) -> DeltaBaseline:
    """Record every term's tail state so later appends can be snapshotted
    incrementally.  Called at the same moment the frozen image is built."""
    store = index.store
    if not store.const_mode:
        raise ValueError("delta images require Const blocks")
    if index.word_level:
        raise ValueError("delta images are doc-level")
    V = len(vocab)
    B = store.B
    out = DeltaBaseline(
        tail_slot=np.zeros(V, np.int64), nx=np.zeros(V, np.int64),
        lastd=np.zeros(V, np.int64), dnum=np.zeros(V, np.int64),
        ft=np.zeros(V, np.int64), num_docs=index.num_docs,
        nblocks=store.nblocks)
    for i, t in enumerate(vocab):
        h_ptr = index.lookup(t)
        if h_ptr is None:
            continue
        hb = h_ptr * B
        t_ptr = store.get_tptr(hb)
        out.tail_slot[i] = t_ptr
        out.nx[i] = store.get_nx(hb)
        out.lastd[i] = store.get_lastd(hb)
        # slot 0 of the tail block is d_num while the block IS the tail —
        # exactly the window in which we read it (head included: its slot 0
        # is d_num until the chain grows).
        out.dnum[i] = store._get_u32(t_ptr * B + _OFF_NPTR)
        out.ft[i] = store.get_ft(hb)
    return out


@jax.tree_util.register_pytree_node_class
@dataclass
class DeltaIndex:
    """Flat-array snapshot of postings appended since a DeltaBaseline.

    Shares the block/decode layout of :class:`DeviceIndex` (so
    :func:`query_step` runs on it unchanged) plus two per-term docid bases:
    the first delta posting of a term is a d-gap from ``term_lastd0`` if it
    lands in the old tail block, while blocks appended after the freeze code
    b-gaps chained from ``term_dnum0`` (the old tail's first docid).
    """

    blocks: jnp.ndarray      # (ND, B) uint8 — compacted delta blocks
    term_slot: jnp.ndarray   # (V,) i32 — first delta block per term
    term_nblk: jnp.ndarray   # (V,) i32 — delta chain length (0 = unchanged)
    term_skip: jnp.ndarray   # (V,) i32 — start byte inside the first block
    term_nx: jnp.ndarray     # (V,) i32 — tail write cursor (bytes)
    term_ft: jnp.ndarray     # (V,) i32 — GLOBAL f_t (for exact idf)
    term_lastd0: jnp.ndarray  # (V,) i32 — last docid coded before the freeze
    term_dnum0: jnp.ndarray  # (V,) i32 — first docid of the first delta block
    num_docs: int            # static docid-space capacity (not live N)
    F: int                   # static fold threshold

    def tree_flatten(self):
        return ((self.blocks, self.term_slot, self.term_nblk, self.term_skip,
                 self.term_nx, self.term_ft, self.term_lastd0,
                 self.term_dnum0), (self.num_docs, self.F))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, num_docs=aux[0], F=aux[1])


def build_delta_image(index: DynamicIndex, vocab: list[bytes],
                      baseline: DeltaBaseline, *, num_docs: int,
                      pad_vocab: int | None = None,
                      pad_blocks: int | None = None,
                      global_ft: np.ndarray | None = None) -> DeltaIndex:
    """Snapshot only the blocks appended (or still filling) since ``baseline``.

    Cost is proportional to the delta, not the index: unchanged terms are
    detected by an ``f_t`` comparison and contribute nothing; changed terms
    copy their old tail block plus any blocks allocated after the freeze.
    No ``collate()`` involved — chains are compacted on the fly into the
    fresh delta block array, so the device gather stays contiguous.

    ``global_ft`` is the current per-term-id f_t array (e.g. the engine's
    incrementally maintained counters).  When given, changed terms are
    short-listed with one vectorized comparison against ``baseline.ft`` and
    unchanged terms are never touched at all; without it, every term pays a
    lookup + head-field read (O(V) per refresh).
    """
    store = index.store
    if not store.const_mode:
        raise ValueError("delta images require Const blocks")
    if index.word_level:
        raise ValueError("delta images are doc-level")
    B = store.B
    V = len(vocab)
    Vp = max(V, pad_vocab or 0)
    Vf = baseline.vocab_size
    slot = np.zeros(Vp, np.int32)
    nblk = np.zeros(Vp, np.int32)
    skip = np.zeros(Vp, np.int32)
    nxs = np.zeros(Vp, np.int32)
    fts = np.zeros(Vp, np.int32)
    lastd0 = np.zeros(Vp, np.int32)
    dnum0 = np.zeros(Vp, np.int32)
    if global_ft is not None:
        fts[:V] = global_ft[:V]
        changed = np.flatnonzero(
            np.concatenate([np.asarray(global_ft[:Vf]) != baseline.ft[:V],
                            np.ones(V - min(Vf, V), bool)]))
        candidates = [(int(i), vocab[int(i)]) for i in changed]
    else:
        candidates = list(enumerate(vocab))
    chunks: list[np.ndarray] = []
    write = 0
    for i, t in candidates:
        h_ptr = index.lookup(t)
        if h_ptr is None:
            continue
        hb = h_ptr * B
        cur_ft = store.get_ft(hb)
        fts[i] = cur_ft
        if i < Vf and cur_ft == baseline.ft[i]:
            continue  # no postings since the freeze
        if i < Vf and baseline.ft[i] > 0:
            first_slot = int(baseline.tail_slot[i])
            skip[i] = int(baseline.nx[i])
            lastd0[i] = int(baseline.lastd[i])
            dnum0[i] = int(baseline.dnum[i])
        else:
            # term born after the freeze: the delta is its whole chain and
            # the head's leading code is an absolute docid (lastd starts 0)
            first_slot = h_ptr
            skip[i] = store.head_fixed + int(store.I[hb + store.head_fixed - 1])
            lastd0[i] = 0
            (g, _), _ = dvbyte_decode_from(store.I, hb + skip[i], store.F)
            dnum0[i] = g  # d_num of the head = its first docid
        # walk old-tail -> current tail via n_ptr links
        t_ptr = store.get_tptr(hb)
        chain = [first_slot]
        p = first_slot
        while p != t_ptr:
            p = store._get_u32(p * B + _OFF_NPTR)
            chain.append(p)
        slot[i] = write
        nblk[i] = len(chain)
        nxs[i] = store.get_nx(hb)
        for ptr in chain:
            chunks.append(store.I[ptr * B:(ptr + 1) * B])
        write += len(chain)
    nd = max(write, pad_blocks or 0, 1)
    blocks = np.zeros((nd, B), np.uint8)
    if chunks:
        blocks[:write] = np.stack(chunks)
    return DeltaIndex(
        blocks=jnp.asarray(blocks), term_slot=jnp.asarray(slot),
        term_nblk=jnp.asarray(nblk), term_skip=jnp.asarray(skip),
        term_nx=jnp.asarray(nxs), term_ft=jnp.asarray(fts),
        term_lastd0=jnp.asarray(lastd0), term_dnum0=jnp.asarray(dnum0),
        num_docs=num_docs, F=index.F)


def with_global_stats(image: DeviceIndex, term_ft: np.ndarray,
                      num_docs: int, pad_vocab: int | None = None
                      ) -> DeviceIndex:
    """Rebase a frozen image's scoring statistics to the LIVE collection.

    Merged frozen+delta querying is only exact if both sides weight postings
    with the global f_t and N; the frozen block bytes stay untouched — only
    the per-term metadata arrays are re-uploaded (and zero-padded so term ids
    minted after the freeze gather empty chains instead of clipping).
    """
    V = image.term_slot.shape[0]
    Vp = max(V, pad_vocab or 0)

    def pad(x):
        return jnp.pad(x, (0, Vp - x.shape[0]))

    ft = np.zeros(Vp, np.int32)
    ft[:min(len(term_ft), Vp)] = term_ft[:Vp]
    return replace(image, term_slot=pad(image.term_slot),
                   term_nblk=pad(image.term_nblk),
                   term_skip=pad(image.term_skip),
                   term_nx=pad(image.term_nx),
                   term_ft=jnp.asarray(ft), num_docs=num_docs)


# --------------------------------------------------------------------------
# step 2: parallel Double-VByte block decode (pure-jnp oracle for the kernel)
# --------------------------------------------------------------------------


def decode_blocks(blocks: jnp.ndarray, start: jnp.ndarray, end: jnp.ndarray,
                  F: int):
    """Decode a batch of B-byte blocks of Double-VByte postings.

    Args:
      blocks: (NB, B) uint8
      start:  (NB,) i32 — first payload byte (head skip or H)
      end:    (NB,) i32 — one past the last payload byte (nx or B)
      F:      fold threshold
    Returns (g, f, valid): each (NB, B); ``valid[i, j]`` marks byte position
    j as the terminator of a *primary* code in block i, with g/f the decoded
    pair (b-gap semantics for the first valid pair of each block preserved —
    the caller handles chaining).
    """
    b = blocks.astype(jnp.int32)
    NB, B = b.shape
    pos = jnp.arange(B, dtype=jnp.int32)[None, :]
    inside = (pos >= start[:, None]) & (pos < end[:, None])
    term = ((b & 0x80) == 0) & inside           # terminator bytes
    # start-of-code = previous terminator position + 1 (clamped to `start`)
    prev_term = jnp.where(term, pos, -1)
    prev_term = jax.lax.associative_scan(jnp.maximum, prev_term, axis=1)
    code_start = jnp.concatenate(
        [jnp.full((NB, 1), -1, jnp.int32), prev_term[:, :-1]], axis=1) + 1
    code_start = jnp.maximum(code_start, start[:, None])
    pos_in_code = pos - code_start
    payload = (b & 0x7F) << (7 * jnp.clip(pos_in_code, 0, 4))
    payload = jnp.where(inside, payload, 0)
    csum = jnp.cumsum(payload, axis=1)
    csum_at_start = jnp.take_along_axis(
        jnp.pad(csum, ((0, 0), (1, 0))), code_start, axis=1)
    value = jnp.where(term, csum - csum_at_start, 0)
    is_value = term & (value > 0)               # null sentinel masks out
    # Algorithm 2 unfold: pair escapes (value % F == 0) with the next value.
    mod = value % F

    def body(carry, x):
        # carry: does the *previous value* (not byte) await its escape pair?
        prev_esc = carry
        isv, v, m = x
        consumed = isv & prev_esc
        primary = isv & ~consumed
        esc_now = primary & (m == 0)
        g = jnp.where(m > 0, 1 + v // F, v // F)
        f = jnp.where(m > 0, m, 0)
        # a consumed value completes its predecessor's escape: emit nothing
        # here, but patch f onto the predecessor via the second output
        fpatch = jnp.where(consumed, F + v - 1, 0)
        # the carry only changes at value positions (byte gaps preserve it)
        new_carry = jnp.where(isv, esc_now, prev_esc)
        return new_carry, (primary, g, f, fpatch)

    xs = (jnp.swapaxes(is_value, 0, 1), jnp.swapaxes(value, 0, 1),
          jnp.swapaxes(mod, 0, 1))
    init = jnp.zeros(NB, bool)
    # unroll: keeps HLO cost_analysis exact (while bodies count once) and
    # the body is a handful of elementwise vector ops over (NB,)
    _, (primary, g, f, fpatch) = jax.lax.scan(body, init, xs, unroll=True)
    primary = jnp.swapaxes(primary, 0, 1)
    g = jnp.swapaxes(g, 0, 1)
    f = jnp.swapaxes(f, 0, 1)
    fpatch = jnp.swapaxes(fpatch, 0, 1)
    # shift fpatch one value-slot left: the consumed value sits at the NEXT
    # terminator position after its primary; scatter back via the same
    # associative trick — for each primary with f == 0, take the fpatch of
    # the next value position.  Positions are sparse; use a reverse scan that
    # propagates the nearest fpatch to the left.
    nxt = jax.lax.associative_scan(
        lambda a, b: jnp.where(b != 0, b, a),
        jnp.where(fpatch > 0, fpatch, 0), axis=1, reverse=True)
    f = jnp.where(primary & (f == 0), nxt, f)
    valid = primary
    return g, f, valid


# --------------------------------------------------------------------------
# steps 1+3+4: full batched query
# --------------------------------------------------------------------------


MAX_BLOCKS = 64  # per-term chain-length cap for the gather (pad/truncate)


@partial(jax.jit, static_argnames=("k", "mode", "max_blocks", "decode_fn"))
def query_step(image: DeviceIndex, qterms: jnp.ndarray, qmask: jnp.ndarray,
               k: int = 10, mode: str = "ranked",
               max_blocks: int = MAX_BLOCKS, decode_fn=None,
               doclens: jnp.ndarray | None = None,
               n_stat: jnp.ndarray | None = None,
               avg_stat: jnp.ndarray | None = None):
    """Batched query execution against a device image.

    Args:
      qterms: (Q, T) i32 term ids (padded);  qmask: (Q, T) bool.
      mode: "ranked" (top-k TF×IDF, dense accumulator), "ranked_sparse"
        (top-k TF×IDF, sort-based), "bm25" (top-k BM25, sort-based —
        requires ``doclens`` (N+1,) f32; paper §6.2's future work), or
        "conjunctive" (hit bitmap counts).
      n_stat: optional dynamic collection size used for idf/avgdl statistics;
        defaults to ``image.num_docs``.  The engine's frozen+delta path sizes
        accumulators by a fixed capacity (``image.num_docs``) but must score
        with the live N, which changes every refresh — passing it dynamically
        avoids a recompile per ingested document.
      avg_stat: optional average document length for BM25.  Defaults to
        ``doclens[1:].sum() / n_stat`` — correct when ``doclens`` covers
        the whole collection, but a document-partitioned shard's local
        doclens sum is NOT the collection's, so its fan-out layer passes
        the fleet-wide average here.
    Returns (top docids (Q, k) i32, top scores (Q, k) f32) for ranked
    modes, or (matches (Q, N) bool, counts) for conjunctive mode.

    ``image`` may also be a :class:`DeltaIndex`; the only difference is docid
    reconstruction, which chains from the delta's per-term bases instead of
    zero (see ``DeltaIndex`` docstring).
    """
    B = image.blocks.shape[1]
    Q, T = qterms.shape
    flat_terms = qterms.reshape(-1)
    slot = image.term_slot[flat_terms]
    nblk = image.term_nblk[flat_terms]
    skip = image.term_skip[flat_terms]
    nx = image.term_nx[flat_terms]
    # ---- step 1: contiguous chain gather (collation makes this a slice) ----
    bidx = slot[:, None] + jnp.arange(max_blocks, dtype=jnp.int32)[None, :]
    bvalid = (jnp.arange(max_blocks)[None, :] < nblk[:, None]) \
        & qmask.reshape(-1)[:, None]
    bidx = jnp.where(bvalid, bidx, 0)
    gathered = image.blocks[bidx.reshape(-1)]          # (QT*MB, B)
    # per-block payload bounds
    is_head = jnp.broadcast_to(jnp.arange(max_blocks)[None, :] == 0,
                               (Q * T, max_blocks))
    is_tail = (jnp.arange(max_blocks)[None, :] == (nblk - 1)[:, None])
    start = jnp.where(is_head, skip[:, None], H).reshape(-1)
    end = jnp.where(is_tail, nx[:, None], B).reshape(-1)
    end = jnp.where(bvalid.reshape(-1), end, 0)        # invalid block: empty
    # ---- step 2: parallel decode ----
    fn = decode_fn if decode_fn is not None else decode_blocks
    g, f, valid = fn(gathered, start, end, image.F)    # (QT*MB, B)
    g = g.reshape(Q * T, max_blocks, B)
    f = f.reshape(Q * T, max_blocks, B)
    valid = valid.reshape(Q * T, max_blocks, B)
    # ---- step 3: docid reconstruction ----
    gv = jnp.where(valid, g, 0)
    within = jnp.cumsum(gv, axis=2)                    # in-block gap sums
    # leading value of each block is a b-gap (or the absolute first docid for
    # the head, since last_d starts at 0): chain first-docids = cumsum of the
    # per-block first gaps
    first_gap = jnp.max(jnp.where(
        jnp.cumsum(valid, axis=2) == 1, gv, 0), axis=2)  # (QT, MB)
    if isinstance(image, DeltaIndex):
        # delta chains don't start at docid 0: the first block's leading code
        # is a d-gap from lastd0 (it continues the old tail), while later
        # blocks chain b-gaps from dnum0 (the old tail's first docid)
        lastd0 = image.term_lastd0[flat_terms]
        dnum0 = image.term_dnum0[flat_terms]
        cum = jnp.cumsum(first_gap, axis=1)
        bf0 = lastd0[:, None] + first_gap[:, :1]
        bfr = dnum0[:, None] + (cum - first_gap[:, :1])
        block_first = jnp.concatenate([bf0, bfr[:, 1:]], axis=1)
    else:
        block_first = jnp.cumsum(first_gap, axis=1)    # absolute first docids
    docid = block_first[:, :, None] + (within - first_gap[:, :, None])
    docid = jnp.where(valid, docid, 0)                 # (QT, MB, B)
    # ---- step 4: scoring ----
    N = image.num_docs
    Ns = jnp.float32(N) if n_stat is None else n_stat.astype(jnp.float32)
    flat_docs = docid.reshape(Q, -1)
    if mode == "conjunctive":
        hits = jnp.zeros((Q, N + 1), jnp.int32)
        ones = valid.reshape(Q, -1).astype(jnp.int32)
        hits = jax.vmap(lambda h, dd, oo: h.at[dd].add(oo))(hits, flat_docs,
                                                            ones)
        nterms = qmask.sum(axis=1)
        matches = (hits[:, 1:] == nterms[:, None]) & (nterms[:, None] > 0)
        return matches, matches.sum(axis=1)
    ft = jnp.maximum(image.term_ft[flat_terms], 1).astype(jnp.float32)
    if mode == "bm25":
        # Okapi BM25 (k1=0.9, b=0.4): saturated tf with length normalization
        k1, b = 0.9, 0.4
        idf = jnp.log1p((Ns - ft + 0.5) / (ft + 0.5))
        idf = (idf * qmask.reshape(-1)).reshape(Q, T)
        dl = doclens[docid.reshape(Q, -1)]                  # (Q, P)
        avgdl = (jnp.maximum(doclens[1:].sum() / Ns, 1e-9)
                 if avg_stat is None
                 else jnp.maximum(avg_stat.astype(jnp.float32), 1e-9))
        fv = jnp.where(valid, f, 0).astype(jnp.float32).reshape(Q, -1)
        tf = (fv * (k1 + 1.0)) / (fv + k1 * (1.0 - b + b * dl / avgdl))
        w = (tf.reshape(Q, T, max_blocks, B)
             * idf[:, :, None, None]).reshape(Q, -1)
    else:
        idf = jnp.log1p(Ns / ft)
        idf = (idf * qmask.reshape(-1)).reshape(Q, T)
        w = jnp.log1p(jnp.where(valid, f, 0).astype(jnp.float32))
        w = w.reshape(Q, T, max_blocks, B) * idf[:, :, None, None]
        w = w.reshape(Q, -1)
    if mode in ("ranked_sparse", "bm25"):
        # §Perf H1: sort-based sparse aggregation.  The dense accumulator
        # touches (Q, N) floats (N = shard docs, >> touched postings); here
        # cost is O(Q * P log P) on P = T*max_blocks*B posting slots only.
        order = jnp.argsort(flat_docs, axis=1)
        d_s = jnp.take_along_axis(flat_docs, order, axis=1)   # (Q, P)
        w_s = jnp.take_along_axis(w, order, axis=1)
        csum = jnp.cumsum(w_s, axis=1)
        P = d_s.shape[1]
        nxt = jnp.concatenate(
            [d_s[:, 1:], jnp.full((Q, 1), -1, d_s.dtype)], axis=1)
        is_end = d_s != nxt                                   # run ends
        # csum at the previous run end, gather-free (same trick as decode)
        pos = jnp.arange(P)[None, :]
        prev_end = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_end, pos, -1), axis=1)
        prev_end = jnp.concatenate(
            [jnp.full((Q, 1), -1), prev_end[:, :-1]], axis=1)
        prev_csum = jnp.where(
            prev_end >= 0,
            jnp.take_along_axis(csum, jnp.maximum(prev_end, 0), axis=1), 0.0)
        run_score = jnp.where(is_end & (d_s > 0), csum - prev_csum, -jnp.inf)
        # k may exceed the posting-slot count (top_k requires k <= minor
        # dim); clamping is exact — distinct scored docids never exceed P
        top_s, pos_k = jax.lax.top_k(run_score, min(k, P))
        top_d = jnp.take_along_axis(d_s, pos_k, axis=1)
        return top_d.astype(jnp.int32), top_s
    scores = jnp.zeros((Q, N + 1), jnp.float32)
    scores = jax.vmap(lambda s, dd, ww: s.at[dd].add(ww))(scores, flat_docs, w)
    scores = scores.at[:, 0].set(-jnp.inf)
    top_s, top_d = jax.lax.top_k(scores, min(k, N + 1))  # clamp: k <= cols
    return top_d.astype(jnp.int32), top_s
