"""Device-resident immediate-access index: the TPU query path.

This is the hardware adaptation described in DESIGN.md §2.  The collated
index image (§5.5 makes every chain contiguous, which is precisely what lets
a TPU fetch a term's postings as one dense slice) is uploaded as flat arrays,
and querying becomes a fixed-shape, fully data-parallel program:

  1. *chain gather* — every query term's blocks are fetched in one gather of
     shape (Q*T*MB, B) from the block array (MB = max blocks per term);
  2. *parallel Double-VByte decode* — terminator flag bits -> per-byte code
     index via cumulative ops -> payload shift/combine; the escape-pairing
     automaton of Algorithm 2 runs as one short lax.scan across byte
     positions, vectorized over every block in flight;
  3. *docid reconstruction* — per-block prefix sums of d-gaps plus a
     cumulative sum of leading b-gaps along each chain (§3.2's skip data);
  4. *scoring* — TF×IDF scatter-add into a dense per-shard accumulator and
     top-k, or conjunctive counting (a docid matches iff its hit count equals
     the number of query terms).

Everything below is pure jnp (the oracle); kernels/dvbyte_decode provides the
Pallas VMEM-tiled implementation of step 2 and tests assert equivalence.

The decoded-postings layout is (NBLK, B) "one potential value per byte
position" with a validity mask — no dynamic shapes anywhere.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .blockstore import H
from .collate import is_collated
from .index import DynamicIndex


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceIndex:
    """Flat-array snapshot of a collated doc-level dynamic index."""

    blocks: jnp.ndarray      # (NB, B) uint8 — the index array I
    term_slot: jnp.ndarray   # (V,) i32 — first slot of each term's chain
    term_nblk: jnp.ndarray   # (V,) i32 — chain length in blocks
    term_skip: jnp.ndarray   # (V,) i32 — byte offset of postings in head
    term_nx: jnp.ndarray     # (V,) i32 — tail write cursor (bytes)
    term_ft: jnp.ndarray     # (V,) i32 — document frequency f_t
    num_docs: int            # static
    F: int                   # static fold threshold

    def tree_flatten(self):
        return ((self.blocks, self.term_slot, self.term_nblk, self.term_skip,
                 self.term_nx, self.term_ft), (self.num_docs, self.F))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, num_docs=aux[0], F=aux[1])


def build_device_image(index: DynamicIndex, vocab: list[bytes],
                       pad_blocks: int | None = None) -> DeviceIndex:
    """Snapshot a *collated, Const-mode, doc-level* index for the device."""
    store = index.store
    if not store.const_mode:
        raise ValueError("device images require Const blocks (B-addressable)")
    if index.word_level:
        raise ValueError("device images are doc-level")
    if not is_collated(index):
        raise ValueError("collate() the index before snapshotting (§5.5)")
    B = store.B
    V = len(vocab)
    slot = np.zeros(V, np.int32)
    nblk = np.zeros(V, np.int32)
    skip = np.zeros(V, np.int32)
    nxs = np.zeros(V, np.int32)
    fts = np.zeros(V, np.int32)
    for i, t in enumerate(vocab):
        h_ptr = index.lookup(t)
        if h_ptr is None:
            continue
        hb = h_ptr * B
        chain = list(store.chain_slots(h_ptr))
        slot[i] = h_ptr
        nblk[i] = len(chain)
        skip[i] = store.head_fixed + int(store.I[hb + store.head_fixed - 1])
        nxs[i] = store.get_nx(hb)
        fts[i] = store.get_ft(hb)
    nb = store.nblocks
    if pad_blocks is not None:
        nb = max(nb, pad_blocks)
    blocks = np.zeros((nb, B), np.uint8)
    blocks[: store.nblocks] = store.I[: store.nblocks * B].reshape(-1, B)
    return DeviceIndex(
        blocks=jnp.asarray(blocks), term_slot=jnp.asarray(slot),
        term_nblk=jnp.asarray(nblk), term_skip=jnp.asarray(skip),
        term_nx=jnp.asarray(nxs), term_ft=jnp.asarray(fts),
        num_docs=index.num_docs, F=index.F)


# --------------------------------------------------------------------------
# step 2: parallel Double-VByte block decode (pure-jnp oracle for the kernel)
# --------------------------------------------------------------------------


def decode_blocks(blocks: jnp.ndarray, start: jnp.ndarray, end: jnp.ndarray,
                  F: int):
    """Decode a batch of B-byte blocks of Double-VByte postings.

    Args:
      blocks: (NB, B) uint8
      start:  (NB,) i32 — first payload byte (head skip or H)
      end:    (NB,) i32 — one past the last payload byte (nx or B)
      F:      fold threshold
    Returns (g, f, valid): each (NB, B); ``valid[i, j]`` marks byte position
    j as the terminator of a *primary* code in block i, with g/f the decoded
    pair (b-gap semantics for the first valid pair of each block preserved —
    the caller handles chaining).
    """
    b = blocks.astype(jnp.int32)
    NB, B = b.shape
    pos = jnp.arange(B, dtype=jnp.int32)[None, :]
    inside = (pos >= start[:, None]) & (pos < end[:, None])
    term = ((b & 0x80) == 0) & inside           # terminator bytes
    # start-of-code = previous terminator position + 1 (clamped to `start`)
    prev_term = jnp.where(term, pos, -1)
    prev_term = jax.lax.associative_scan(jnp.maximum, prev_term, axis=1)
    code_start = jnp.concatenate(
        [jnp.full((NB, 1), -1, jnp.int32), prev_term[:, :-1]], axis=1) + 1
    code_start = jnp.maximum(code_start, start[:, None])
    pos_in_code = pos - code_start
    payload = (b & 0x7F) << (7 * jnp.clip(pos_in_code, 0, 4))
    payload = jnp.where(inside, payload, 0)
    csum = jnp.cumsum(payload, axis=1)
    csum_at_start = jnp.take_along_axis(
        jnp.pad(csum, ((0, 0), (1, 0))), code_start, axis=1)
    value = jnp.where(term, csum - csum_at_start, 0)
    is_value = term & (value > 0)               # null sentinel masks out
    # Algorithm 2 unfold: pair escapes (value % F == 0) with the next value.
    mod = value % F

    def body(carry, x):
        # carry: does the *previous value* (not byte) await its escape pair?
        prev_esc = carry
        isv, v, m = x
        consumed = isv & prev_esc
        primary = isv & ~consumed
        esc_now = primary & (m == 0)
        g = jnp.where(m > 0, 1 + v // F, v // F)
        f = jnp.where(m > 0, m, 0)
        # a consumed value completes its predecessor's escape: emit nothing
        # here, but patch f onto the predecessor via the second output
        fpatch = jnp.where(consumed, F + v - 1, 0)
        # the carry only changes at value positions (byte gaps preserve it)
        new_carry = jnp.where(isv, esc_now, prev_esc)
        return new_carry, (primary, g, f, fpatch)

    xs = (jnp.swapaxes(is_value, 0, 1), jnp.swapaxes(value, 0, 1),
          jnp.swapaxes(mod, 0, 1))
    init = jnp.zeros(NB, bool)
    # unroll: keeps HLO cost_analysis exact (while bodies count once) and
    # the body is a handful of elementwise vector ops over (NB,)
    _, (primary, g, f, fpatch) = jax.lax.scan(body, init, xs, unroll=True)
    primary = jnp.swapaxes(primary, 0, 1)
    g = jnp.swapaxes(g, 0, 1)
    f = jnp.swapaxes(f, 0, 1)
    fpatch = jnp.swapaxes(fpatch, 0, 1)
    # shift fpatch one value-slot left: the consumed value sits at the NEXT
    # terminator position after its primary; scatter back via the same
    # associative trick — for each primary with f == 0, take the fpatch of
    # the next value position.  Positions are sparse; use a reverse scan that
    # propagates the nearest fpatch to the left.
    nxt = jax.lax.associative_scan(
        lambda a, b: jnp.where(b != 0, b, a),
        jnp.where(fpatch > 0, fpatch, 0), axis=1, reverse=True)
    f = jnp.where(primary & (f == 0), nxt, f)
    valid = primary
    return g, f, valid


# --------------------------------------------------------------------------
# steps 1+3+4: full batched query
# --------------------------------------------------------------------------


MAX_BLOCKS = 64  # per-term chain-length cap for the gather (pad/truncate)


@partial(jax.jit, static_argnames=("k", "mode", "max_blocks", "decode_fn"))
def query_step(image: DeviceIndex, qterms: jnp.ndarray, qmask: jnp.ndarray,
               k: int = 10, mode: str = "ranked",
               max_blocks: int = MAX_BLOCKS, decode_fn=None,
               doclens: jnp.ndarray | None = None):
    """Batched query execution against a device image.

    Args:
      qterms: (Q, T) i32 term ids (padded);  qmask: (Q, T) bool.
      mode: "ranked" (top-k TF×IDF, dense accumulator), "ranked_sparse"
        (top-k TF×IDF, sort-based), "bm25" (top-k BM25, sort-based —
        requires ``doclens`` (N+1,) f32; paper §6.2's future work), or
        "conjunctive" (hit bitmap counts).
    Returns (top docids (Q, k) i32, top scores (Q, k) f32) for ranked
    modes, or (matches (Q, N) bool, counts) for conjunctive mode.
    """
    B = image.blocks.shape[1]
    Q, T = qterms.shape
    flat_terms = qterms.reshape(-1)
    slot = image.term_slot[flat_terms]
    nblk = image.term_nblk[flat_terms]
    skip = image.term_skip[flat_terms]
    nx = image.term_nx[flat_terms]
    # ---- step 1: contiguous chain gather (collation makes this a slice) ----
    bidx = slot[:, None] + jnp.arange(max_blocks, dtype=jnp.int32)[None, :]
    bvalid = (jnp.arange(max_blocks)[None, :] < nblk[:, None]) \
        & qmask.reshape(-1)[:, None]
    bidx = jnp.where(bvalid, bidx, 0)
    gathered = image.blocks[bidx.reshape(-1)]          # (QT*MB, B)
    # per-block payload bounds
    is_head = jnp.broadcast_to(jnp.arange(max_blocks)[None, :] == 0,
                               (Q * T, max_blocks))
    is_tail = (jnp.arange(max_blocks)[None, :] == (nblk - 1)[:, None])
    start = jnp.where(is_head, skip[:, None], H).reshape(-1)
    end = jnp.where(is_tail, nx[:, None], B).reshape(-1)
    end = jnp.where(bvalid.reshape(-1), end, 0)        # invalid block: empty
    # ---- step 2: parallel decode ----
    fn = decode_fn if decode_fn is not None else decode_blocks
    g, f, valid = fn(gathered, start, end, image.F)    # (QT*MB, B)
    g = g.reshape(Q * T, max_blocks, B)
    f = f.reshape(Q * T, max_blocks, B)
    valid = valid.reshape(Q * T, max_blocks, B)
    # ---- step 3: docid reconstruction ----
    gv = jnp.where(valid, g, 0)
    within = jnp.cumsum(gv, axis=2)                    # in-block gap sums
    # leading value of each block is a b-gap (or the absolute first docid for
    # the head, since last_d starts at 0): chain first-docids = cumsum of the
    # per-block first gaps
    first_gap = jnp.max(jnp.where(
        jnp.cumsum(valid, axis=2) == 1, gv, 0), axis=2)  # (QT, MB)
    block_first = jnp.cumsum(first_gap, axis=1)        # absolute first docids
    docid = block_first[:, :, None] + (within - first_gap[:, :, None])
    docid = jnp.where(valid, docid, 0)                 # (QT, MB, B)
    # ---- step 4: scoring ----
    N = image.num_docs
    flat_docs = docid.reshape(Q, -1)
    if mode == "conjunctive":
        hits = jnp.zeros((Q, N + 1), jnp.int32)
        ones = valid.reshape(Q, -1).astype(jnp.int32)
        hits = jax.vmap(lambda h, dd, oo: h.at[dd].add(oo))(hits, flat_docs,
                                                            ones)
        nterms = qmask.sum(axis=1)
        matches = (hits[:, 1:] == nterms[:, None]) & (nterms[:, None] > 0)
        return matches, matches.sum(axis=1)
    ft = jnp.maximum(image.term_ft[flat_terms], 1).astype(jnp.float32)
    if mode == "bm25":
        # Okapi BM25 (k1=0.9, b=0.4): saturated tf with length normalization
        k1, b = 0.9, 0.4
        idf = jnp.log1p((N - ft + 0.5) / (ft + 0.5))
        idf = (idf * qmask.reshape(-1)).reshape(Q, T)
        dl = doclens[docid.reshape(Q, -1)]                  # (Q, P)
        avgdl = jnp.maximum(doclens[1:].sum() / N, 1e-9)
        fv = jnp.where(valid, f, 0).astype(jnp.float32).reshape(Q, -1)
        tf = (fv * (k1 + 1.0)) / (fv + k1 * (1.0 - b + b * dl / avgdl))
        w = (tf.reshape(Q, T, max_blocks, B)
             * idf[:, :, None, None]).reshape(Q, -1)
    else:
        idf = jnp.log1p(N / ft)
        idf = (idf * qmask.reshape(-1)).reshape(Q, T)
        w = jnp.log1p(jnp.where(valid, f, 0).astype(jnp.float32))
        w = w.reshape(Q, T, max_blocks, B) * idf[:, :, None, None]
        w = w.reshape(Q, -1)
    if mode in ("ranked_sparse", "bm25"):
        # §Perf H1: sort-based sparse aggregation.  The dense accumulator
        # touches (Q, N) floats (N = shard docs, >> touched postings); here
        # cost is O(Q * P log P) on P = T*max_blocks*B posting slots only.
        order = jnp.argsort(flat_docs, axis=1)
        d_s = jnp.take_along_axis(flat_docs, order, axis=1)   # (Q, P)
        w_s = jnp.take_along_axis(w, order, axis=1)
        csum = jnp.cumsum(w_s, axis=1)
        P = d_s.shape[1]
        nxt = jnp.concatenate(
            [d_s[:, 1:], jnp.full((Q, 1), -1, d_s.dtype)], axis=1)
        is_end = d_s != nxt                                   # run ends
        # csum at the previous run end, gather-free (same trick as decode)
        pos = jnp.arange(P)[None, :]
        prev_end = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_end, pos, -1), axis=1)
        prev_end = jnp.concatenate(
            [jnp.full((Q, 1), -1), prev_end[:, :-1]], axis=1)
        prev_csum = jnp.where(
            prev_end >= 0,
            jnp.take_along_axis(csum, jnp.maximum(prev_end, 0), axis=1), 0.0)
        run_score = jnp.where(is_end & (d_s > 0), csum - prev_csum, -jnp.inf)
        top_s, pos_k = jax.lax.top_k(run_score, k)
        top_d = jnp.take_along_axis(d_s, pos_k, axis=1)
        return top_d.astype(jnp.int32), top_s
    scores = jnp.zeros((Q, N + 1), jnp.float32)
    scores = jax.vmap(lambda s, dd, ww: s.at[dd].add(ww))(scores, flat_docs, w)
    scores = scores.at[:, 0].set(-jnp.inf)
    top_s, top_d = jax.lax.top_k(scores, k)
    return top_d.astype(jnp.int32), top_s
