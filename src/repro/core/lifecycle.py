"""Tiered index lifecycle: dynamic → delta → static (Figure 2, closed loop).

The paper's triple goal includes "fast conversion of the dynamic index to a
'normal' static compressed inverted index", but a conversion nobody queries
is just a benchmark.  This module turns the :class:`~repro.core.static_index.
StaticIndex` into a live serving tier, following the production shape of
Asadi & Lin (Fast, Incremental Inverted Indexing, 2013): a write-optimized
in-memory segment continuously frozen into compressed read-optimized
segments, with queries spanning both — and, per Vigna's Quasi-Succinct
Indices, the frozen tier kept in its most compact codec.

Lifecycle of one freeze (driven by :class:`FreezeManager`):

  1. **policy trigger** — after an ingest, ``maybe_freeze`` compares the
     un-frozen suffix (docs/postings past the current tier horizon) against
     the :class:`FreezePolicy` thresholds;
  2. **snapshot** (caller thread, cheap) — ``Engine.collate_now()`` runs the
     §5.5 collation (which also refreezes the device image + delta
     baseline, so all tiers share one freeze point), then the collated
     index is ``clone()``-d: one memcpy, after which the background thread
     shares no mutable state with ingest;
  3. **convert** (background thread, expensive) — the clone is encoded into
     a :class:`StaticIndex` (bp128 or interp) while ingest and queries
     continue against the live index and the *previous* tier: there is no
     moment at which any document is unqueryable (zero availability gap);
  4. **swap** (atomic) — the finished tier is published as a single
     reference assignment of an immutable :class:`StaticTier`; the epoch
     counter bumps, invalidating the serving layer's query-result cache.

Exactness across tiers: docids are ordinal and each document's postings are
written before the next document starts, so docs ``<= tier.num_docs`` live
wholly in the static tier and later docs wholly in the dynamic suffix — the
same disjoint-docid-range argument :class:`~repro.core.device_index.
DeltaBaseline` makes for the device path.  The engine's tiered backend
(``engine.backends.TieredBackend``) merges the two ranges and rebases
idf/BM25 statistics to the live collection, so results are byte-identical
to a host-backend evaluation of the full dynamic index.

Word-level engines follow the identical lifecycle: ``StaticIndex.freeze``
regroups each occurrence stream into docid/count/w-gap streams (§5.1's
⟨d,w⟩ form), and the same disjointness argument covers positions too —
a document's occurrences never straddle the horizon, so phrase queries
evaluated over chained static+dynamic positional cursors are exact.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass

from .static_index import StaticIndex


@dataclass(frozen=True)
class FreezePolicy:
    """When (and how) to freeze the dynamic prefix into the static tier.

    ``every_docs`` / ``every_postings``: freeze once the un-frozen suffix
    reaches that many documents / postings (either trigger suffices; None
    disables that trigger).  ``codec`` picks the static codec; ``background``
    runs the conversion on a freeze thread (the production mode — ``False``
    makes every freeze synchronous, which tests use for determinism).
    """

    every_docs: int | None = None
    every_postings: int | None = None
    codec: str = "bp128"
    background: bool = True


@dataclass(frozen=True)
class StaticTier:
    """An immutable published tier: the compressed image, its docid horizon
    (every docid <= num_docs is served from it), the freeze epoch, and the
    encode wall-clock.  Everything a reader learns about a freeze rides on
    this ONE object — the manager's ``epoch``/``freezes``/``last_freeze_s``
    are derived views, so the tier swap is a single reference assignment
    with no multi-field publication window."""

    index: StaticIndex
    num_docs: int
    num_postings: int
    epoch: int
    encode_s: float | None = None
    # tombstoned docids this tier's encode dropped (freeze-time compaction:
    # the tier is rebuilt anyway, so dead docids are excluded for free —
    # ``num_docs`` stays the docid HORIZON, which tombstoning never moves)
    compacted: int = 0


class FreezeCoordinator:
    """Fleet-wide freeze scheduling: at most ``max_in_flight`` concurrent
    static-tier encodes across every registered :class:`FreezeManager`.

    A fleet of independently-freezing shards can hit its policy thresholds
    simultaneously (round-robin ingest makes that the COMMON case — shards
    fill in lockstep) and pay N encode threads at once: N clones resident,
    N cores stolen from serving.  The coordinator turns that spike into a
    stagger: a manager asks for an encode slot before starting its
    background thread, and a refused manager queues FIFO and simply retries
    at a later ``maybe_freeze`` — deferral, not blocking, so the writer
    thread never stalls and the snapshot is taken when the slot is actually
    granted (a FRESHER horizon than at queue time, which is strictly
    better).  ``ShardedEngine`` pumps every queued manager on EVERY fleet
    ingest (the fleet shares one writer thread), so the queue head cannot
    wedge the FIFO by never receiving documents of its own; a fully idle
    fleet drains deferred freezes via ``drain_freezes``.

    Thread model: ``try_acquire`` runs on writer threads, ``release`` on
    encode threads, both under one condition variable.  ``acquire`` (the
    blocking variant, used by synchronous freezes) jumps the FIFO — it
    holds the caller's writer thread, so making it wait for queued
    background work could stall ingest indefinitely; the budget invariant
    (never more than ``max_in_flight`` encodes alive) still holds.

    Observability: ``in_flight`` (current), ``peak_in_flight`` (high-water
    mark — the bench's staggered-vs-simultaneous headline), ``epoch`` (sum
    of all managers' epochs — a composite, monotone tier-swap counter that
    serving caches key on).
    """

    def __init__(self, max_in_flight: int = 1):
        if max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got "
                             f"{max_in_flight}")
        self.max_in_flight = max_in_flight
        self.managers: list[FreezeManager] = []
        self._cond = threading.Condition()
        self._in_flight = 0                             # guarded_by: _cond
        self._waiters: deque[FreezeManager] = deque()   # guarded_by: _cond
        self.peak_in_flight = 0                         # guarded_by: _cond
        # refused try_acquires (queue pressure)
        self.deferrals = 0                              # guarded_by: _cond

    def register(self, manager: "FreezeManager") -> "FreezeManager":
        """Adopt a manager: its background freezes now need an encode slot."""
        manager.coordinator = self
        self.managers.append(manager)
        return manager

    # -- slot accounting ---------------------------------------------------

    def _grant(self) -> None:       # requires: _cond
        self._in_flight += 1
        self.peak_in_flight = max(self.peak_in_flight, self._in_flight)

    def try_acquire(self, manager: "FreezeManager") -> bool:
        """Non-blocking slot request (writer thread).  FIFO-fair: a refused
        manager is queued and nobody may overtake it while slots are
        contended."""
        with self._cond:
            if manager not in self._waiters:
                self._waiters.append(manager)
            if (self._in_flight < self.max_in_flight
                    and self._waiters[0] is manager):
                self._waiters.popleft()
                self._grant()
                return True
            self.deferrals += 1
            return False

    def acquire(self, manager: "FreezeManager") -> None:
        """Blocking slot request (synchronous freezes).  Jumps the FIFO —
        see class docstring — but still counts against ``max_in_flight``."""
        with self._cond:
            if manager in self._waiters:
                self._waiters.remove(manager)
            while self._in_flight >= self.max_in_flight:
                self._cond.wait()
            self._grant()

    def release(self, manager: "FreezeManager") -> None:
        with self._cond:
            self._in_flight -= 1
            self._cond.notify_all()

    # -- observability -----------------------------------------------------

    @property
    def in_flight(self) -> int:
        with self._cond:
            return self._in_flight

    @property
    def pending(self) -> int:
        """Managers queued for a slot (deferred freezes)."""
        with self._cond:
            return len(self._waiters)

    @property
    def epoch(self) -> int:
        """Composite tier epoch: sum of every manager's epoch.  Monotone
        (epochs only grow), and it changes whenever ANY shard swaps its
        tier — exactly the invalidation granularity a fleet-level
        query-result cache needs."""
        return sum(m.epoch for m in self.managers)

    @property
    def freezes(self) -> int:
        return sum(m.freezes for m in self.managers)

    def wait(self) -> None:
        """Join every in-flight encode (tests / shutdown).  Queued-but-
        deferred freezes are NOT started here — drive those through the
        owning engines' ``maybe_freeze`` (see ``ShardedEngine.drain_freezes``)."""
        for m in self.managers:
            m.wait()


class FreezeManager:
    """Owns the static tier of one engine: policy, background freeze, swap.

    Thread model: ``maybe_freeze``/``freeze`` run on the engine's single
    writer thread; the conversion runs on at most one background thread at a
    time, touching only its private clone; ``tier`` is swapped by a single
    reference assignment (readers grab the reference once per query, so a
    mid-query swap is invisible).  A freeze request while one is in flight
    is a no-op — the next ``maybe_freeze`` re-evaluates the policy against
    the new horizon.

    When a :class:`FreezeCoordinator` has adopted this manager (fleet
    serving), every encode additionally needs a slot from it: background
    freezes defer (return False, retried at the next ``maybe_freeze``)
    while the fleet is at its encode budget; blocking freezes wait.
    """

    def __init__(self, engine, policy: FreezePolicy | None = None):
        self.engine = engine
        self.policy = policy or FreezePolicy()
        self.tier: StaticTier | None = None             # published
        self._thread: threading.Thread | None = None    # writer_only
        self.coordinator: FreezeCoordinator | None = None

    # -- observability ----------------------------------------------------

    @property
    def epoch(self) -> int:
        """Freeze epoch of the published tier (0 before the first swap).
        Derived from the single published ``tier`` reference — one load, so
        ``epoch``/``freezes``/the horizon can never be observed mutually
        inconsistent the way separate counter fields could."""
        tier = self.tier
        return tier.epoch if tier is not None else 0

    @property
    def freezes(self) -> int:
        """Completed freezes == the published epoch (each freeze bumps the
        epoch by exactly one, starting from zero)."""
        return self.epoch

    @property
    def last_freeze_s(self) -> float | None:
        """Encode wall-clock of the most recent freeze (rides on the tier)."""
        tier = self.tier
        return tier.encode_s if tier is not None else None

    @property
    def tombstones_compacted(self) -> int:
        """Dead docids the PUBLISHED tier's encode dropped (rides on the
        tier reference like every other freeze observable — tombstones only
        grow, so this is monotone across swaps)."""
        tier = self.tier
        return tier.compacted if tier is not None else 0

    @property
    def in_flight(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def wait(self) -> None:
        """Join an in-flight background conversion (tests / shutdown)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def quiesce(self) -> None:
        """Snapshot barrier (``core/persist.py``): join any in-flight
        background encode so a subsequent ``Engine.snapshot`` captures the
        newest tier.  Optional — a snapshot is consistent WITHOUT it (the
        persist path reads the published ``tier`` reference exactly once,
        and the tiered merge is exact at any horizon); quiescing only moves
        the persisted horizon forward.  Writer thread only, like every
        freeze entry point."""
        self.wait()

    def suffix_size(self) -> tuple[int, int]:
        """(docs, postings) ingested past the current tier horizon."""
        idx = self.engine.index
        tier = self.tier        # snapshot ONCE: a background swap between
        if tier is None:        # loads would mix two horizons (torn read)
            return idx.num_docs, idx.num_postings
        return (idx.num_docs - tier.num_docs,
                idx.num_postings - tier.num_postings)

    # -- the lifecycle -----------------------------------------------------

    def maybe_freeze(self) -> bool:
        """Policy check after an ingest; starts a freeze when due (and, under
        a coordinator, when the fleet encode budget grants a slot — a
        refused attempt is simply retried on the next ingest)."""
        if self.in_flight:
            return False
        pol = self.policy
        docs, postings = self.suffix_size()
        due = ((pol.every_docs is not None and docs >= pol.every_docs)
               or (pol.every_postings is not None
                   and postings >= pol.every_postings))
        if not due or docs == 0:
            return False
        return self.freeze(blocking=not pol.background)

    def freeze(self, blocking: bool = False) -> bool:
        """Snapshot now, convert (in background unless ``blocking``), swap.

        Returns False if a freeze is already in flight, or if a coordinator
        refused the encode slot (background mode only — the freeze stays
        queued and a later ``maybe_freeze`` retries).  The caller thread
        pays for ``collate_now`` (the §5.5 copy plus, on device-capable
        layouts, the device-image snapshot it has always implied) and one
        ``clone()`` memcpy — the expensive static re-encode runs off-thread;
        queries keep being served from the previous tier + dynamic suffix
        until the swap.
        """
        if self.in_flight:
            if not blocking:
                return False
            self.wait()
        coord = self.coordinator
        if coord is not None:
            # the slot covers snapshot + encode: the clone a freeze keeps
            # resident is part of the budget the coordinator meters
            if blocking:
                coord.acquire(self)
            elif not coord.try_acquire(self):
                return False
        eng = self.engine
        # from here to the handoff, the slot must not leak: if the snapshot
        # (collate/clone) raises, work() — whose finally owns the release —
        # never runs, and a leaked slot would wedge the whole fleet's
        # freeze budget permanently
        handed_off = False
        try:
            eng.collate_now()       # shared freeze point with the device tier
            snapshot = eng.index.clone()
            epoch = self.epoch + 1
            t0 = time.perf_counter()

            def work():
                try:
                    static = StaticIndex.freeze(snapshot, self.policy.codec)
                    static.epoch = epoch
                    tier = StaticTier(index=static,
                                      num_docs=snapshot.num_docs,
                                      num_postings=snapshot.num_postings,
                                      epoch=epoch,
                                      encode_s=time.perf_counter() - t0,
                                      compacted=len(snapshot.tombstones))
                    # atomic publish: ONE reference assignment of an
                    # immutable payload — epoch/freezes/last_freeze_s are
                    # all derived views of this reference, so there is no
                    # window where a reader sees them inconsistent
                    self.tier = tier
                finally:
                    if coord is not None:
                        coord.release(self)

            if blocking:
                handed_off = True   # work()'s finally releases, even raising
                work()
            else:
                self._thread = threading.Thread(target=work, daemon=True,
                                                name=f"freeze-epoch-{epoch}")
                self._thread.start()
                handed_off = True
        except BaseException:
            if coord is not None and not handed_off:
                coord.release(self)
            raise
        return True


__all__ = ["FreezePolicy", "StaticTier", "FreezeManager",
           "FreezeCoordinator"]
