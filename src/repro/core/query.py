"""Query processing over the dynamic index (paper §3.6, §4.6).

Two query modes, both operating on the live block structure while ingest
continues (immediate access):

  * conjunctive Boolean, document-at-a-time, with ``seek_GEQ`` skipping that
    touches only each block's leading b-gap and n_ptr (§3.2: "an indexed
    sequential access mode") — Culpepper & Moffat-style adaptive DAAT;
  * top-k disjunctive ranking with the paper's TF×IDF model
        w_{t,d} = log(1 + f_{t,d}) * log(1 + N / f_t)
    tracked in a min-heap (§4.6).

All ranked scorers (TF×IDF, BM25, and the position-aware ``bm25_prox``)
consume DOCUMENT-granular statistics on word-level indexes via the
positional cursor protocol (``WordPostingsCursor`` / ``StaticWordCursor`` /
``ChainedCursor``): f_{t,d} is the per-document occurrence count and f_t
the document frequency — never the §5.1 occurrence stream's w-gaps or
occurrence totals.  Phrase and proximity operators run positional DAAT over
the same cursors, so every mode serves identically from the dynamic chains
and the compressed static tier.  Ranked ties follow one canonical order
everywhere: higher score first, then lower docid.

A vectorized term-at-a-time scorer and a brute-force oracle are included for
benchmarks and tests.

These functions are the HOST backend of the unified query engine
(``repro.engine``): callers that want planner-driven routing across the
host / device-oracle / Pallas backends should go through
``Engine.execute(Query(...))`` rather than calling these directly; the
engine guarantees identical results across backends (differential-tested)
and keeps the device images refreshed incrementally.
"""

from __future__ import annotations

import heapq
from typing import NamedTuple

import numpy as np

from .blockstore import H, BlockStore
from .dvbyte import dvbyte_decode_from
from .index import DynamicIndex, group_occurrences


class PostingsCursor:
    """A DAAT cursor over one term's chain supporting next()/seek_GEQ().

    Maintains (docid, payload) of the current posting.  ``seek_GEQ`` advances
    block-at-a-time using only the leading b-gap of each block — the paper's
    skip mechanism — then decodes within the final candidate block.
    """

    __slots__ = ("store", "h_ptr", "_blocks", "_bi", "_pos", "_end",
                 "_block_first_d", "_prev_block_first_d", "docid", "payload",
                 "_exhausted", "_first_in_block", "_nx")

    def __init__(self, store: BlockStore, h_ptr: int):
        self.store = store
        self.h_ptr = h_ptr
        # materialize chain slot list once (ptr, z, is_tail)
        self._blocks = list(store.chain_slots(h_ptr))
        self._bi = 0
        self._nx = store.get_nx(h_ptr * store.B)
        self._prev_block_first_d = 0
        self._block_first_d = 0
        self.docid = 0
        self.payload = 0
        self._exhausted = False
        self._enter_block(0)
        self.next()

    # -- block helpers ---------------------------------------------------

    def _block_bounds(self, bi: int):
        store = self.store
        ptr, z, is_tail = self._blocks[bi]
        base = ptr * store.B
        if ptr == self.h_ptr:
            start = store.head_fixed + int(store.I[base + store.head_fixed - 1])
        else:
            start = H
        cap = store.B if store.const_mode else store.block_size_at(z)
        end = base + (self._nx if is_tail else cap)
        return base, base + start, end

    def _enter_block(self, bi: int) -> None:
        self._bi = bi
        _, pos, end = self._block_bounds(bi)
        self._pos = pos
        self._end = end
        self._first_in_block = True

    def _peek_block_first_d(self, bi: int, prev_first_d: int) -> int:
        """First docid of block bi, reading only its leading b-gap."""
        _, pos, _ = self._block_bounds(bi)
        (major, minor), _ = dvbyte_decode_from(self.store.I, pos,
                                               self.store.F)
        if self.store.word_level:
            return prev_first_d + (minor - 1)
        return prev_first_d + major

    # -- iteration --------------------------------------------------------

    def next(self) -> bool:
        """Advance to the next posting; False when exhausted."""
        store = self.store
        while True:
            if self._pos >= self._end or store.I[self._pos] == 0:
                if self._bi + 1 >= len(self._blocks):
                    self._exhausted = True
                    return False
                self._prev_block_first_d = self._block_first_d
                self._enter_block(self._bi + 1)
                continue
            (major, minor), self._pos = dvbyte_decode_from(
                store.I, self._pos, store.F)
            if store.word_level:
                g = minor - 1
                self.payload = major
            else:
                g = major
                self.payload = minor
            if self._first_in_block and self._bi > 0:
                self.docid = self._prev_block_first_d + g  # b-gap
            else:
                self.docid = self.docid + g
            if self._first_in_block:
                self._block_first_d = self.docid
                self._first_in_block = False
            return True

    def seek_geq(self, target: int) -> bool:
        """Position on the first posting with docid >= target.

        Word-level chains must not hop a block whose first docid EQUALS the
        target: the target document's earlier occurrences may end the
        current block, and a seek must land on its FIRST occurrence (the
        w-gap there is the absolute position — the invariant
        ``WordPostingsCursor`` and the tiered suffix reader rely on).
        Doc-level docids are unique, so the equal-hop stays (it skips
        decoding the current block entirely).
        """
        if self._exhausted:
            return False
        # fast block skip: hop while the NEXT block still starts <= target
        # (strictly < for word-level, see above)
        while self._bi + 1 < len(self._blocks):
            nxt_first = self._peek_block_first_d(self._bi + 1,
                                                 self._block_first_d)
            if (nxt_first < target
                    or (nxt_first == target and not self.store.word_level)):
                self._prev_block_first_d = self._block_first_d
                self._enter_block(self._bi + 1)
                self.docid = 0  # will be set by the b-gap on first next()
                self.next()
                self._block_first_d = self.docid
            else:
                break
        while self.docid < target:
            if not self.next():
                return False
        return True

    @property
    def exhausted(self) -> bool:
        return self._exhausted


class WordPostingsCursor:
    """Document-granular view over a word-level occurrence cursor.

    A word-level :class:`PostingsCursor` yields one entry per OCCURRENCE
    (docid repeats, payload = w-gap).  This wrapper groups the run of equal
    docids into one step: ``docid`` advances over unique documents,
    ``payload`` is the doc's occurrence count f_{t,d}, and ``positions()``
    returns the doc's absolute word positions (cumulative w-gaps).  It is
    the dynamic-chain counterpart of :class:`~repro.core.static_index.
    StaticWordCursor`, so phrase/conjunctive evaluation is uniform across
    tiers.  The wrapped cursor must be positioned on the FIRST occurrence
    of its current document (true after construction or any ``seek_geq`` —
    occurrences are stored in (d, w) order, so a docid-targeted seek always
    lands on a document's first occurrence).
    """

    __slots__ = ("_cur", "_pending", "_positions", "docid", "payload",
                 "_exhausted")

    def __init__(self, cur: "PostingsCursor"):
        self._cur = cur
        self._exhausted = cur.exhausted
        self.docid = 0
        self.payload = 0
        self._positions = np.zeros(0, dtype=np.int64)
        self._pending = False
        if not self._exhausted:
            self._gather()

    def _gather(self) -> None:
        """Consume the current document's occurrence run; leaves the wrapped
        cursor parked on the next document's first occurrence (or spent)."""
        cur = self._cur
        d = cur.docid
        ws = []
        w = 0
        while True:
            w += cur.payload          # w-gap -> absolute position
            ws.append(w)
            if not cur.next() or cur.docid != d:
                break
        self.docid = d
        self.payload = len(ws)
        self._positions = np.asarray(ws, dtype=np.int64)
        self._pending = not cur.exhausted

    def positions(self) -> np.ndarray:
        """Absolute word positions of the current document, ascending."""
        return self._positions

    def next(self) -> bool:
        if self._exhausted:
            return False
        if not self._pending:
            self._exhausted = True
            return False
        self._gather()
        return True

    def seek_geq(self, target: int) -> bool:
        if self._exhausted:
            return False
        if self.docid >= target:
            return True
        if not self._pending or not self._cur.seek_geq(target):
            self._exhausted = True
            return False
        self._gather()
        return True

    @property
    def exhausted(self) -> bool:
        return self._exhausted


def word_cursor(index: DynamicIndex, term) -> WordPostingsCursor | None:
    """Document-granular positional cursor over a word-level dynamic index
    (None if the term is unknown)."""
    h = index.lookup(term)
    if h is None:
        return None
    return WordPostingsCursor(PostingsCursor(index.store, h))


def doc_cursor(index: DynamicIndex, term):
    """Document-granular DAAT cursor over any dynamic index: the raw
    :class:`PostingsCursor` for doc-level chains, the
    :class:`WordPostingsCursor` wrapper for word-level ones — so ``payload``
    is f_{t,d} in both cases (None if the term is unknown)."""
    h = index.lookup(term)
    if h is None:
        return None
    c = PostingsCursor(index.store, h)
    return WordPostingsCursor(c) if index.word_level else c


def positional_cursor(index, term):
    """Document-granular POSITIONAL cursor over ``index``: a tiered view's
    chained static+dynamic cursor when the object provides ``cursor``
    (:class:`~repro.engine.backends.TieredView`), else a dynamic
    :func:`word_cursor`.  The uniform entry point of the proximity and
    position-aware ranked operators."""
    factory = getattr(index, "cursor", None)
    if factory is not None:
        return factory(term)
    return word_cursor(index, term)


class ChainedCursor:
    """Concatenate cursors over disjoint, ascending docid ranges.

    The tiered engine path chains a :class:`~repro.core.static_index.
    StaticPostingsCursor` over the frozen tier (docids <= horizon) with a
    :class:`PostingsCursor` sought past the horizon — one DAAT cursor over
    the whole collection, same ``next``/``seek_geq`` protocol.  ``None`` and
    initially-exhausted parts are dropped.  When the parts are positional
    (word-level) cursors, ``positions()`` delegates to the active part, so
    a chained cursor is itself a valid phrase-operator input.
    """

    __slots__ = ("_cs", "_i", "docid", "payload", "_exhausted")

    def __init__(self, cursors):
        self._cs = [c for c in cursors if c is not None and not c.exhausted]
        self._i = 0
        self.docid = 0
        self.payload = 0
        self._exhausted = not self._cs
        if not self._exhausted:
            self._adopt()

    def _adopt(self) -> None:
        c = self._cs[self._i]
        self.docid = c.docid
        self.payload = c.payload

    def next(self) -> bool:
        if self._exhausted:
            return False
        if self._cs[self._i].next():
            self._adopt()
            return True
        self._i += 1
        if self._i < len(self._cs):
            self._adopt()
            return True
        self._exhausted = True
        return False

    def seek_geq(self, target: int) -> bool:
        if self._exhausted:
            return False
        while self._i < len(self._cs):
            if self._cs[self._i].seek_geq(target):
                self._adopt()
                return True
            self._i += 1
        self._exhausted = True
        return False

    def positions(self) -> np.ndarray:
        """Word positions of the current document (positional parts only)."""
        return self._cs[self._i].positions()

    @property
    def exhausted(self) -> bool:
        return self._exhausted


# --------------------------------------------------------------------------
# term statistics (planner inputs)
# --------------------------------------------------------------------------


class TermStats(NamedTuple):
    """Cheap per-term observables: f_t is one head-block field read, the
    chain length one link walk.  The engine planner routes on these."""

    ft: int = 0
    nblocks: int = 0


class CollectionStats(NamedTuple):
    """Collection-wide ranking statistics for scoring a PARTITION exactly.

    A document-partitioned shard sees only its own slice of the collection,
    so its local N, f_t, and average document length are biased estimators
    of the global ones — scoring with them breaks the byte-identical-merge
    contract every other backend honors.  A fan-out layer (``ShardedEngine``)
    maintains these three globally at ingest and hands them to every ranked
    scorer, which then weights each posting with exactly the numbers a
    single-engine oracle over the full stream would use; per-shard top-k
    merge is then exact (same doubles, same canonical tie order).

    ``ft`` maps term bytes to the global DOCUMENT frequency (never the
    word-level occurrence count — the same doc-granularity rule as
    :func:`doc_ft`).
    """

    num_docs: int
    avg_doclen: float
    ft: dict
    fts_cache: dict | None = None   # id(vocab list) -> aligned f_t array

    def doc_ft(self, term) -> int:
        tb = term.encode() if isinstance(term, str) else term
        return self.ft.get(tb, 0)

    def fts_for(self, vocab) -> "np.ndarray":
        """Global f_t aligned to an engine's term-id vocabulary (the array
        shape device images rebase their metadata with).

        With a ``fts_cache`` (the fleet maintains one, keyed by the
        identity of each engine's append-only vocab list and value-updated
        incrementally at ingest), only the suffix of terms interned since
        the last call pays a dict lookup — a device refresh never re-walks
        the whole vocabulary.  Callers must treat the returned array as
        read-only (it IS the live cache entry)."""
        if self.fts_cache is None:
            return np.asarray([self.ft.get(tb, 0) for tb in vocab],
                              dtype=np.int64)
        arr = self.fts_cache.get(id(vocab))
        V = len(vocab)
        if arr is None:
            arr = np.asarray([self.ft.get(tb, 0) for tb in vocab],
                             dtype=np.int64)
        elif len(arr) < V:
            ext = np.asarray([self.ft.get(tb, 0) for tb in vocab[len(arr):]],
                             dtype=np.int64)
            arr = np.concatenate([arr, ext]) if len(arr) else ext
        self.fts_cache[id(vocab)] = arr
        return arr


def _tombstones(index):
    """The index-like's tombstone set, or None when empty/absent.  Every
    query operator masks members of this set — deleted documents' postings
    stay in the chains (the docid space is never renumbered), so serving
    correctness lives here."""
    dead = getattr(index, "tombstones", None)
    return dead if dead else None


def _drop_dead(docids: np.ndarray, dead) -> np.ndarray:
    """Filter tombstoned docids out of a result/postings array."""
    if not dead or len(docids) == 0:
        return docids
    deadarr = np.fromiter(dead, dtype=np.int64, count=len(dead))
    return docids[~np.isin(docids, deadarr)]


def _live_postings(index, term, dead):
    """Document-granular postings with tombstoned docs removed — the shape
    every deletion-aware ranked scorer accumulates from (so live document
    frequency is simply ``len(docids)``)."""
    docids, fs = _doc_level_postings(index, term)
    if not dead or len(docids) == 0:
        return docids, fs
    deadarr = np.fromiter(dead, dtype=np.int64, count=len(dead))
    keep = ~np.isin(docids, deadarr)
    return docids[keep], fs[keep]


def term_stats(index: DynamicIndex, term) -> TermStats:
    h_ptr = index.lookup(term)
    if h_ptr is None:
        return TermStats(0, 0)
    store = index.store
    return TermStats(store.get_ft(h_ptr * store.B),
                     sum(1 for _ in store.chain_slots(h_ptr)))


def doc_ft(index, term) -> int:
    """Document frequency |{d : t ∈ d}| — the f_t every ranked scorer needs.

    Doc-level indexes read it from the head block (their stored f_t already
    counts documents); word-level chains store one posting per OCCURRENCE
    (§5.1), so their stored f_t is an occurrence count and the document
    frequency must be recovered by counting unique docids (one decode pass
    — dynamic chains have no cheaper document-granular statistic)."""
    if not getattr(index, "word_level", False):
        return index.ft(term)
    docids, _ = _doc_level_postings(index, term)
    return len(docids)


def _doc_level_postings(index, term):
    """(unique docids, doc-level f_{t,d}) — uniform over doc- and word-level
    indexes, and over index-like views.  Prefers the object's own
    ``doc_postings`` (DynamicIndex, StaticIndex, TieredView — the tiered
    view serves the frozen prefix from the compressed ⟨d,w⟩ image without
    touching positions); otherwise groups the raw occurrence stream."""
    grouped = getattr(index, "doc_postings", None)
    if grouped is not None:
        return grouped(term)
    docids, seconds = index.postings(term)
    if not getattr(index, "word_level", False):
        return docids, seconds
    return group_occurrences(docids)


# --------------------------------------------------------------------------
# conjunctive Boolean (DAAT with skipping)
# --------------------------------------------------------------------------


def conjunctive_query(index: DynamicIndex, terms) -> np.ndarray:
    """All docids containing every query term (sorted ascending, unique).

    Word-level indexes run the same DAAT loop over document-granular
    :class:`WordPostingsCursor` wrappers, so the occurrence streams'
    repeated docids never reach the intersection."""
    if not terms:
        return np.zeros(0, dtype=np.int64)
    ptrs = []
    for t in terms:
        h = index.lookup(t)
        if h is None:
            return np.zeros(0, dtype=np.int64)
        ptrs.append(h)
    # rarest-first ordering minimizes candidate count
    ptrs.sort(key=lambda h: index.store.get_ft(h * index.store.B))
    cursors = [PostingsCursor(index.store, h) for h in ptrs]
    if index.word_level:
        cursors = [WordPostingsCursor(c) for c in cursors]
    return _drop_dead(conjunctive_from_cursors(cursors), _tombstones(index))


def conjunctive_from_cursors(cursors) -> np.ndarray:
    """DAAT AND over any positioned postings cursors (``PostingsCursor``,
    ``StaticPostingsCursor``, ``ChainedCursor`` — anything speaking the
    ``next``/``seek_geq`` protocol).  Callers order rarest-first; an
    initially-exhausted (or missing) cursor makes the intersection empty."""
    if not cursors or any(c is None or c.exhausted for c in cursors):
        return np.zeros(0, dtype=np.int64)
    out = []
    lead = cursors[0]
    while not lead.exhausted:
        d = lead.docid
        ok = True
        for c in cursors[1:]:
            if not c.seek_geq(d):
                return np.asarray(out, dtype=np.int64)
            if c.docid != d:
                ok = False
                d = c.docid  # next candidate
                break
        if ok:
            out.append(d)
            if not lead.next():
                break
        else:
            if not lead.seek_geq(d):
                break
    return np.asarray(out, dtype=np.int64)


# --------------------------------------------------------------------------
# ranked disjunctive top-k (§4.6)
# --------------------------------------------------------------------------


def tfidf_weight(f_td: np.ndarray, f_t: int, N: int) -> np.ndarray:
    return np.log1p(f_td) * np.log1p(N / f_t)


def _topk_by_score(scores: np.ndarray, k: int):
    """Top-k of a dense score accumulator under the canonical tie order:
    higher score first, then LOWER docid.  One lexsort over the nonzero
    candidates — selection and ordering share the same comparator, so the
    DAAT heap, the TAAT scorers, and the tiered backend can never disagree
    on which documents sit at a tied k boundary."""
    nz = np.flatnonzero(scores)
    order = np.lexsort((nz, -scores[nz]))[:k]
    top = nz[order]
    return top.astype(np.int64), scores[top]


def ranked_disjunctive(index: DynamicIndex, terms, k: int = 10,
                       stats: CollectionStats | None = None):
    """DAAT top-k with a min-heap of "best seen so far" (paper §4.6).

    Runs over DOCUMENT-granular cursors (:func:`doc_cursor`), so on
    word-level indexes ``payload`` is f_{t,d} — never a w-gap — and the idf
    uses the true document frequency (:func:`doc_ft`), not the §5.1
    occurrence count.  Ties at the k boundary follow the canonical order
    (higher score, then lower docid): the heap compares full ``(score, -d)``
    tuples, which is exactly that order inverted.

    ``stats`` (a :class:`CollectionStats`) rebases N and f_t to the full
    collection when ``index`` is one shard of a partitioned fleet.

    Returns (docids, scores) sorted by descending score, docid ascending
    within ties.
    """
    dead = _tombstones(index)
    if stats is None:
        N = index.num_docs - (len(dead) if dead else 0)
    else:
        N = stats.num_docs
    cursors = []
    idfs = []
    for t in terms:
        c = doc_cursor(index, t)
        if c is None:
            continue
        if stats is None:
            # live document frequency: an index that never saw the dead
            # documents would count exactly the surviving ones
            ft = (len(_live_postings(index, t, dead)[0]) if dead
                  else doc_ft(index, t))
        else:
            ft = stats.doc_ft(t)
        if ft <= 0:
            continue    # every containing doc is dead ≡ unknown term
        cursors.append(c)
        idfs.append(np.log1p(N / ft))
    if not cursors:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float64))
    heap: list[tuple[float, int]] = []  # (score, -docid) min-heap
    while True:
        # candidate = min current docid among live cursors
        live = [c for c in cursors if not c.exhausted]
        if not live:
            break
        d = min(c.docid for c in live)
        if dead is not None and d in dead:
            # skipped BEFORE the size-k heap: a dead entry would evict a
            # live one at the boundary, not just vanish from the output
            for c in cursors:
                if not c.exhausted and c.docid == d:
                    c.next()
            continue
        score = 0.0
        for c, idf in zip(cursors, idfs):
            if not c.exhausted and c.docid == d:
                score += np.log1p(c.payload) * idf
                c.next()
        if len(heap) < k:
            heapq.heappush(heap, (score, -d))
        elif (score, -d) > heap[0]:
            heapq.heapreplace(heap, (score, -d))
    items = sorted(heap, key=lambda x: (-x[0], -x[1]))
    return (np.asarray([-d for _, d in items], dtype=np.int64),
            np.asarray([s for s, _ in items], dtype=np.float64))


def ranked_disjunctive_taat(index, terms, k: int = 10,
                            stats: CollectionStats | None = None):
    """Vectorized term-at-a-time scorer (identical results, numpy-fast).

    The paper notes (§4.2) TAAT shares the document-sorted index requirement,
    so this is a legitimate execution strategy over the same structure.
    Accepts any index-like with ``num_docs`` + postings access (DynamicIndex,
    TieredView, sharded fan-outs); word-level indexes are scored through
    :func:`_doc_level_postings`, so f_{t,d}/f_t are document-level — the
    occurrence stream's repeated docids and w-gap payloads never reach the
    accumulator.  ``stats`` rebases N and f_t to the full collection when
    ``index`` is one shard of a partitioned fleet (the accumulator stays
    sized by the LOCAL docid space; only the idf arithmetic goes global).
    """
    N = index.num_docs
    dead = _tombstones(index)
    if stats is None:
        Ns = N - (len(dead) if dead else 0)
    else:
        Ns = stats.num_docs
    scores = np.zeros(N + 1, dtype=np.float64)
    touched = False
    for t in terms:
        # dead docs never reach the accumulator, so live df is len(docids)
        docids, fs = _live_postings(index, t, dead)
        if len(docids) == 0:
            continue
        touched = True
        ft = len(docids) if stats is None else stats.doc_ft(t)
        scores[docids] += tfidf_weight(fs, ft, Ns)
    if not touched:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float64))
    return _topk_by_score(scores, k)


# --------------------------------------------------------------------------
# brute-force oracle (tests)
# --------------------------------------------------------------------------


def brute_conjunctive(index: DynamicIndex, terms) -> np.ndarray:
    sets = []
    for t in terms:
        docids, _ = index.postings(t)
        sets.append(set(int(x) for x in docids))
    if not sets:
        return np.zeros(0, dtype=np.int64)
    inter = set.intersection(*sets)
    dead = _tombstones(index)
    if dead:
        inter -= dead
    return np.asarray(sorted(inter), dtype=np.int64)


# --------------------------------------------------------------------------
# BM25 ranked querying (paper §6.2's stated future work)
# --------------------------------------------------------------------------
#
# "Our immediate next goal will be to consider how to integrate responsive
#  querying modes ... using similarity scoring models such as BM25."
# The only extra state BM25 needs beyond the paper's index is the document-
# length array, which §3.6 explicitly places outside the core index ("we
# consider that to be not part of the core inverted index").  DynamicIndex
# callers maintain it trivially at ingest: doclens.append(len(terms)).


def _live_avg_doclen(doclens: np.ndarray, N: int, dead) -> float:
    """Average document length over LIVE docs only — the avgdl an index
    that never ingested the tombstoned documents would report."""
    if not N:
        return 0.0
    total = float(doclens[1:N + 1].sum())
    live_n = N
    if dead:
        deadarr = np.fromiter(dead, dtype=np.int64, count=len(dead))
        total -= float(doclens[deadarr].sum())
        live_n -= len(dead)
    return total / live_n if live_n else 0.0


def bm25_weight(f_td, doclen, avg_len, f_t, N, k1=0.9, b=0.4):
    idf = np.log(1.0 + (N - f_t + 0.5) / (f_t + 0.5))
    tf = (f_td * (k1 + 1.0)) / (
        f_td + k1 * (1.0 - b + b * doclen / max(avg_len, 1e-9)))
    return idf * tf


def ranked_bm25(index, terms, doclens: np.ndarray,
                k: int = 10, k1: float = 0.9, b: float = 0.4,
                stats: CollectionStats | None = None):
    """Top-k BM25 (TAAT; doclens is 1-indexed via position 0 padding).

    Like :func:`ranked_disjunctive_taat`, accepts any index-like and scores
    word-level indexes through document-granular postings, so f_{t,d} and
    f_t are doc-level everywhere.  ``stats`` rebases N, f_t, AND the average
    document length to the full collection when ``index`` is one shard of a
    partitioned fleet (``doclens`` stays the shard-local array — each doc's
    own length is partition-invariant).  Returns (docids, scores) by
    descending score, docid ascending within ties."""
    N = index.num_docs
    dead = _tombstones(index)
    if stats is None:
        Ns = N - (len(dead) if dead else 0)
        avg = _live_avg_doclen(doclens, N, dead)
    else:
        Ns = stats.num_docs
        avg = stats.avg_doclen
    scores = np.zeros(N + 1, dtype=np.float64)
    for t in terms:
        docids, fs = _live_postings(index, t, dead)
        if len(docids) == 0:
            continue
        ft = len(docids) if stats is None else stats.doc_ft(t)
        scores[docids] += bm25_weight(
            fs.astype(np.float64), doclens[docids], avg, ft, Ns, k1, b)
    return _topk_by_score(scores, k)


# --------------------------------------------------------------------------
# phrase querying over the word-level index (the paper's §1.1 motivation
# for word-level postings: "to support phrase or proximity querying modes")
# --------------------------------------------------------------------------


def phrase_from_cursors(cursors) -> np.ndarray:
    """Documents where ``cursors`` (one POSITIONAL cursor per phrase slot,
    in phrase order) align consecutively: doc matches iff some position p
    has cursors[i] occurring at p+i for every i.

    Works over anything speaking the positional protocol —
    :class:`WordPostingsCursor` (dynamic chains), :class:`~repro.core.
    static_index.StaticWordCursor` (compressed tier), and
    :class:`ChainedCursor` chains of the two — so the tiered backend
    evaluates phrases without materializing either tier.  DAAT over docids
    with ``seek_geq`` skipping; positions are intersected (with slot
    offsets) only on documents containing every term.  Cursor order is
    semantic (slot i's positions shift by i), hence no rarest-first
    reordering here."""
    if not cursors or any(c is None or c.exhausted for c in cursors):
        return np.zeros(0, dtype=np.int64)
    out = []
    lead = cursors[0]
    while not lead.exhausted:
        d = lead.docid
        ok = True
        for c in cursors[1:]:
            if not c.seek_geq(d):
                return np.asarray(out, dtype=np.int64)
            if c.docid != d:
                ok = False
                d = c.docid
                break
        if ok:
            starts = lead.positions()
            for i, c in enumerate(cursors[1:], start=1):
                starts = np.intersect1d(starts, c.positions() - i,
                                        assume_unique=True)
                if len(starts) == 0:
                    break
            if len(starts):
                out.append(d)
            if not lead.next():
                break
        else:
            if not lead.seek_geq(d):
                break
    return np.asarray(out, dtype=np.int64)


def phrase_query(index: DynamicIndex, terms) -> np.ndarray:
    """Documents containing ``terms`` as a consecutive phrase (word-level
    index required).  One positional DAAT pass via
    :func:`phrase_from_cursors` — repeated phrase terms get independent
    cursors, one per slot."""
    if not index.word_level:
        raise ValueError("phrase_query needs a word-level index (§5.1)")
    if not terms:
        return np.zeros(0, dtype=np.int64)
    return _drop_dead(phrase_from_cursors([word_cursor(index, t)
                                           for t in terms]),
                      _tombstones(index))


# --------------------------------------------------------------------------
# proximity querying (§1.1's "phrase or proximity querying modes"): DAAT over
# the positional cursor protocol — no wholesale decode of any tier
# --------------------------------------------------------------------------


def _window_match(pos_lists, counts, window: int) -> bool:
    """True iff some window of span <= ``window`` contains >= counts[i]
    DISTINCT positions of term i for every i — the injective-binding
    semantics for repeated query terms (a doc with one occurrence of "a"
    must NOT match the query ["a", "a"]).  Two-pointer sweep over the
    merged position list: the maximal window ending at each rightmost
    occurrence dominates every sub-window, so the sweep is complete."""
    positions = np.concatenate(pos_lists)
    labels = np.concatenate(
        [np.full(len(ws), i) for i, ws in enumerate(pos_lists)])
    order = np.argsort(positions, kind="stable")
    positions, labels = positions[order], labels[order]
    have = [0] * len(pos_lists)
    satisfied = 0
    left = 0
    for right in range(len(positions)):
        lr = labels[right]
        have[lr] += 1
        if have[lr] == counts[lr]:
            satisfied += 1
        while positions[right] - positions[left] > window:
            ll = labels[left]
            if have[ll] == counts[ll]:
                satisfied -= 1
            have[ll] -= 1
            left += 1
        if satisfied == len(pos_lists):
            return True
    return False


def proximity_from_cursors(cursors, window: int, counts=None) -> np.ndarray:
    """Documents where the cursors' terms co-occur within ``window`` words.

    One POSITIONAL document-granular cursor per UNIQUE query term;
    ``counts[i]`` is that term's multiplicity in the query — a match must
    bind that many DISTINCT positions of it inside one window.  Like
    :func:`phrase_from_cursors`, works over anything speaking the
    positional protocol (``WordPostingsCursor``, ``StaticWordCursor``,
    ``ChainedCursor``), so the tiered backend evaluates proximity without
    materializing either tier: DAAT over docids with ``seek_geq`` skipping,
    positions touched only on documents containing every term."""
    if counts is None:
        counts = [1] * len(cursors)
    if not cursors or any(c is None or c.exhausted for c in cursors):
        return np.zeros(0, dtype=np.int64)
    out = []
    lead = cursors[0]
    while not lead.exhausted:
        d = lead.docid
        ok = True
        for c in cursors[1:]:
            if not c.seek_geq(d):
                return np.asarray(out, dtype=np.int64)
            if c.docid != d:
                ok = False
                d = c.docid
                break
        if ok:
            # payload = f_{t,d}: a doc lacking m occurrences can't bind them
            if (all(c.payload >= m for c, m in zip(cursors, counts))
                    and _window_match([c.positions() for c in cursors],
                                      counts, window)):
                out.append(d)
            if not lead.next():
                break
        else:
            if not lead.seek_geq(d):
                break
    return np.asarray(out, dtype=np.int64)


def proximity_query(index, terms, window: int) -> np.ndarray:
    """Documents where all ``terms`` co-occur within ``window`` words
    (word-level index required; repeated terms bind distinct positions).
    Accepts a DynamicIndex or a tiered view (anything
    :func:`positional_cursor` serves)."""
    if not getattr(index, "word_level", False):
        raise ValueError("proximity_query needs a word-level index")
    if not terms:
        return np.zeros(0, dtype=np.int64)
    need: dict = {}
    for t in terms:
        need[t] = need.get(t, 0) + 1
    items = list(need.items())
    ft = getattr(index, "ft", None)
    if ft is not None:
        # unlike phrase, proximity is term-order-symmetric: lead with the
        # rarest term so the DAAT loop skips instead of enumerating the
        # most common term's documents (f_t is an O(1) head-block read on
        # the dynamic index, an engine counter on the tiered view)
        items.sort(key=lambda kv: ft(kv[0]))
    return _drop_dead(proximity_from_cursors(
        [positional_cursor(index, t) for t, _ in items],
        window, [m for _, m in items]), _tombstones(index))


# --------------------------------------------------------------------------
# position-aware ranked querying: BM25 + MinDist proximity bonus (Tao & Zhai
# 2007, "An exploration of proximity measures in information retrieval") —
# the §5.1 payoff for carrying word positions into the ranked path
# --------------------------------------------------------------------------


def min_pair_dist(pos_lists):
    """Minimum |p - q| over occurrences p, q of two DIFFERENT terms, or
    None when fewer than two of the lists are non-empty.  The closest
    cross-term pair is always adjacent in the merged position order (any
    position between them would form a closer pair with one end), so one
    linear scan over the merge suffices."""
    lists = [p for p in pos_lists if p is not None and len(p)]
    if len(lists) < 2:
        return None
    positions = np.concatenate(lists)
    labels = np.concatenate(
        [np.full(len(p), i) for i, p in enumerate(lists)])
    order = np.argsort(positions, kind="stable")
    positions, labels = positions[order], labels[order]
    gaps = np.diff(positions)[labels[1:] != labels[:-1]]
    return int(gaps.min()) if len(gaps) else None


def ranked_bm25_prox(index, terms, doclens: np.ndarray, k: int = 10,
                     k1: float = 0.9, b: float = 0.4, alpha: float = 1.0,
                     stats: CollectionStats | None = None):
    """Position-aware top-k: BM25 plus the MinDist additive term —

        score(d) = BM25(d) + ln(alpha + exp(-delta(d)))

    where delta(d) is the minimum distance between occurrences of two
    DISTINCT query terms in d (delta = +inf, i.e. bonus = ln(alpha), when
    fewer than two distinct terms are present; the default alpha = 1 makes
    that bonus exactly 0).  Word-level only — the whole point is consuming
    positions on the ranked path.  Evaluated through the document-granular
    positional cursors (:func:`positional_cursor`), so a TieredView serves
    it from the compressed ⟨d,w⟩ tier byte-identically to the host path.
    Returns (docids, scores) by descending score, docid ascending on ties.
    """
    if not getattr(index, "word_level", False):
        raise ValueError("ranked_bm25_prox needs a word-level index")
    N = index.num_docs
    dead = _tombstones(index)
    if stats is None:
        Ns = N - (len(dead) if dead else 0)
        avg = _live_avg_doclen(doclens, N, dead)
    else:
        Ns = stats.num_docs
        avg = stats.avg_doclen
    # pass 1 — the plain BM25 TAAT accumulation over doc-level postings
    # (the tiered view's doc_postings never touches the w-gap stream);
    # tombstoned docs are dropped at the gather, so they neither score nor
    # count toward presence in the positional pass
    uniq = list(dict.fromkeys(terms))
    gathered = {t: _live_postings(index, t, dead) for t in uniq}
    scores = np.zeros(N + 1, dtype=np.float64)
    for t in terms:  # repeated query terms contribute per slot, as in BM25
        ds, fs = gathered[t]
        if len(ds) == 0:
            continue
        ft = len(ds) if stats is None else stats.doc_ft(t)
        scores[ds] += bm25_weight(fs.astype(np.float64), doclens[ds], avg,
                                  ft, Ns, k1, b)
    # pass 2 — positions only where the bonus can be nonzero: docs holding
    # >= 2 distinct query terms, visited by a fresh seek_geq-skipping
    # positional cursor (lazy ⟨d,w⟩ block decode on the static tier)
    present = np.zeros(N + 1, dtype=np.int64)
    for t in uniq:
        present[gathered[t][0]] += 1
    multi = np.flatnonzero(present >= 2)
    pos_of: dict = {t: {} for t in uniq}
    for t in uniq:
        need = multi[np.isin(multi, gathered[t][0], assume_unique=True)]
        if len(need) == 0:
            continue
        c = positional_cursor(index, t)
        for d in need:  # ascending, and every d is in the term's list
            c.seek_geq(int(d))
            pos_of[t][int(d)] = c.positions()
    # every matched doc gets exactly one bonus addition: ln(alpha) when
    # fewer than two distinct terms are present (delta = +inf), else the
    # full MinDist term — BM25 weights are > 0, so multi ⊆ nonzero
    nz = np.flatnonzero(scores)
    scores[nz[present[nz] < 2]] += np.log(alpha)
    for d in multi:
        delta = min_pair_dist([pos_of[t].get(int(d)) for t in uniq])
        scores[d] += np.log(alpha + np.exp(-float(delta)))
    return _topk_by_score(scores, k)
