"""Distributed immediate-access index: document-partitioned shard_map query.

This realizes the paper's Figure 2 at datacenter scale.  Each device owns one
*dynamic sub-shard* (a collated device image of its slice of the document
stream); ingest is a host-side concern (one writer per shard); queries fan
out to every shard and the per-shard top-k results are fused:

  mesh axes:  "data" (and "pod" when multi-pod) partition the document space;
              "model" partitions the query batch.

  query:      replicated over data/pod, sharded over model
  index:      sharded over (pod, data), replicated over model
  execution:  local decode+score (device_index.query_step)
              -> local top-k
              -> all_gather over (pod, data)
              -> merge top-k            (the paper's "results fused")

Conjunctive queries need no merge at all (docid spaces are disjoint): the
local hit bitmaps concatenate, so the collective is a pure reshard.

Local docids are 1..N_shard; global ids are formed as
``shard_rank * N_shard + local`` inside the mapped function.

Two layers live here:

  * the jitted ``shard_map`` query step below (device-mesh execution of one
    fused program across TPU shards), and
  * :class:`ShardedEngine` — the host-level fan-out that owns one
    ``repro.engine.Engine`` per shard and routes ``execute_many`` through
    the same unified engine API, so every shard independently plans
    host/device/Pallas execution and keeps its own frozen+delta device
    image fresh.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .device_index import DeviceIndex, decode_blocks, query_step

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.5: experimental home, check_vma spelled check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_compat

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_compat(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


def stack_images(images: list[DeviceIndex]) -> DeviceIndex:
    """Concatenate per-shard images along a leading shard axis.

    All shards must share (V, B) and are padded to the max block count.
    """
    nb = max(int(im.blocks.shape[0]) for im in images)
    B = images[0].blocks.shape[1]

    def padb(x):
        return jnp.pad(x, ((0, nb - x.shape[0]), (0, 0)))

    return DeviceIndex(
        blocks=jnp.concatenate([padb(im.blocks) for im in images]),
        term_slot=jnp.concatenate([im.term_slot for im in images]),
        term_nblk=jnp.concatenate([im.term_nblk for im in images]),
        term_skip=jnp.concatenate([im.term_skip for im in images]),
        term_nx=jnp.concatenate([im.term_nx for im in images]),
        term_ft=jnp.concatenate([im.term_ft for im in images]),
        num_docs=max(im.num_docs for im in images),
        F=images[0].F)


def make_sharded_query_step(mesh, *, k: int = 10, max_blocks: int = 64,
                            num_docs: int = 1 << 20, F: int = 4,
                            decode_fn=None, mode: str = "ranked"):
    """Build the jitted sharded query step for ``mesh``.

    Index arrays are sharded over the document axes ("pod","data"), the query
    batch over "model".  Returns (fn, in_shardings, out_shardings) ready for
    ``jax.jit(...).lower()`` — launch/dryrun.py lowers exactly this.  The
    mapped function takes the six image arrays explicitly (pytree aux fields
    cannot carry shardings): fn(blocks, slot, nblk, skip, nx, ft, qt, qm).
    """
    doc_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    img_specs = (P(doc_axes, None), P(doc_axes), P(doc_axes), P(doc_axes),
                 P(doc_axes), P(doc_axes))
    q_spec = P("model", None)

    if mode == "conjunctive":
        # Boolean AND needs no score fusion at all: docid spaces are
        # disjoint, so the per-shard hit bitmaps simply tile the global
        # docid axis — output stays sharded (model x doc-axes), zero
        # cross-shard traffic beyond the replicated query broadcast.
        def fn_conj(blocks, slot, nblk, skip, nx, ft, qterms, qmask):
            image = DeviceIndex(blocks, slot, nblk, skip, nx, ft,
                                num_docs=num_docs, F=F)
            matches, counts = query_step(
                image, qterms, qmask, k=k, mode="conjunctive",
                max_blocks=max_blocks, decode_fn=decode_fn)
            total = counts
            for ax in doc_axes:
                total = jax.lax.psum(total, ax)
            return matches, total

        in_specs = img_specs + (q_spec, q_spec)
        out_specs = (P("model", doc_axes), P("model"))
        mapped = shard_map(fn_conj, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
        in_sharding = tuple(jax.NamedSharding(mesh, s) for s in in_specs)
        out_sharding = tuple(jax.NamedSharding(mesh, s) for s in out_specs)
        return mapped, in_sharding, out_sharding

    def fn(blocks, slot, nblk, skip, nx, ft, qterms, qmask):
        image = DeviceIndex(blocks, slot, nblk, skip, nx, ft,
                            num_docs=num_docs, F=F)
        local_d, local_s = query_step(
            image, qterms, qmask, k=k, mode=mode,
            max_blocks=max_blocks, decode_fn=decode_fn)
        # globalize docids by shard rank over the document axes
        rank = jnp.int32(0)
        nshards = 1
        for ax in doc_axes:
            # mesh axis sizes are static; jax.lax.axis_size only exists on
            # newer jax, so read them from the mesh closure instead
            rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
            nshards *= mesh.shape[ax]
        global_d = jnp.where(local_d > 0,
                             local_d + rank * jnp.int32(image.num_docs), 0)
        # fuse: all-gather the per-shard top-k and re-select
        gs = local_s
        gd = global_d
        for ax in doc_axes:
            gs = jax.lax.all_gather(gs, ax, axis=0, tiled=False)
            gd = jax.lax.all_gather(gd, ax, axis=0, tiled=False)
        gs = gs.reshape(-1, local_s.shape[-2], k)    # (S, Qloc, k)
        gd = gd.reshape(-1, local_d.shape[-2], k)
        gs = jnp.moveaxis(gs, 0, 1).reshape(local_s.shape[-2], -1)
        gd = jnp.moveaxis(gd, 0, 1).reshape(local_d.shape[-2], -1)
        top_s, pos = jax.lax.top_k(gs, k)
        top_d = jnp.take_along_axis(gd, pos, axis=1)
        return top_d, top_s

    # NB: shard_map requires explicit specs for every input leaf
    in_specs = img_specs + (q_spec, q_spec)
    out_specs = (P("model", None), P("model", None))
    mapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    in_sharding = tuple(jax.NamedSharding(mesh, s) for s in in_specs)
    out_sharding = tuple(jax.NamedSharding(mesh, s) for s in out_specs)
    return mapped, in_sharding, out_sharding


def sharded_input_specs(mesh, *, shard_blocks: int, B: int = 64,
                        vocab: int = 1 << 17, qbatch: int = 256,
                        qterms: int = 8, num_docs: int = 1 << 20):
    """ShapeDtypeStruct stand-ins for the sharded query step (dry-run)."""
    nshards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            nshards *= mesh.shape[ax]
    meta = jax.ShapeDtypeStruct((nshards * vocab,), jnp.int32)
    q = jax.ShapeDtypeStruct((qbatch, qterms), jnp.int32)
    m = jax.ShapeDtypeStruct((qbatch, qterms), jnp.bool_)
    return (jax.ShapeDtypeStruct((nshards * shard_blocks, B), jnp.uint8),
            meta, meta, meta, meta, meta, q, m)


# --------------------------------------------------------------------------
# host-level shard fan-out through the unified engine
# --------------------------------------------------------------------------


class ShardedEngine:
    """Document-partitioned fan-out of per-shard query engines.

    Documents are assigned round-robin; each shard runs a full
    ``repro.engine.Engine`` (its planner may independently pick host,
    device, or Pallas execution, and its device image refreshes
    incrementally).  Queries fan out to every shard and results fuse:

      * boolean modes — per-shard docid lists are globalized and
        concatenated (docid spaces are disjoint, no dedup needed);
      * ranked modes — per-shard top-k lists merge by score.

    Ranked scores use shard-local (N, f_t) statistics, the standard
    document-partitioned IDF approximation; with round-robin assignment the
    shard statistics are unbiased estimators of the global ones.  Boolean
    results are exact.
    """

    def __init__(self, num_shards: int = 2, engine_factory=None,
                 **engine_kwargs):
        from ..engine import Engine
        if engine_factory is None:
            def engine_factory():
                return Engine(**engine_kwargs)
        self.engines = [engine_factory() for _ in range(num_shards)]
        # global docid 0 is the usual 1-based padding slot
        self._owner: list[tuple[int, int]] = [(0, 0)]  # g -> (shard, local)
        self._to_global: list[list[int]] = [[0] for _ in self.engines]
        self._next_shard = 0

    @property
    def num_docs(self) -> int:
        return len(self._owner) - 1

    def add_document(self, terms) -> int:
        shard = self._next_shard
        self._next_shard = (self._next_shard + 1) % len(self.engines)
        local = self.engines[shard].add_document(terms)
        g = len(self._owner)
        self._owner.append((shard, local))
        assert len(self._to_global[shard]) == local
        self._to_global[shard].append(g)
        return g

    def collate_now(self) -> None:
        for e in self.engines:
            e.collate_now()

    def execute(self, query):
        return self.execute_many([query])[0]

    def _globalize(self, shard: int, docids) -> "np.ndarray":
        import numpy as np
        lut = np.asarray(self._to_global[shard], dtype=np.int64)
        return lut[np.asarray(docids, dtype=np.int64)]

    def execute_many(self, queries):
        """Fan a batch out to every shard engine and fuse per query."""
        import numpy as np

        from ..engine.types import QueryResult
        per_shard = [e.execute_many(queries) for e in self.engines]
        out = []
        for qi, q in enumerate(queries):
            shard_res = [per_shard[s][qi] for s in range(len(self.engines))]
            gids = np.concatenate([self._globalize(s, r.docids)
                                   for s, r in enumerate(shard_res)])
            if q.mode in ("conjunctive", "phrase", "proximity"):
                out.append(QueryResult(np.sort(gids), None,
                                       shard_res[0].backend, "sharded"))
            else:
                scores = np.concatenate([r.scores for r in shard_res])
                # canonical ranked tie order across shards: higher score
                # first, then lower GLOBAL docid (not shard arrival order)
                order = np.lexsort((gids, -scores))[:q.k]
                out.append(QueryResult(gids[order], scores[order],
                                       shard_res[0].backend, "sharded"))
        return out

    def stats(self):
        return [e.stats() for e in self.engines]
