"""Distributed immediate-access index: document-partitioned shard_map query.

This realizes the paper's Figure 2 at datacenter scale.  Each device owns one
*dynamic sub-shard* (a collated device image of its slice of the document
stream); ingest is a host-side concern (one writer per shard); queries fan
out to every shard and the per-shard top-k results are fused:

  mesh axes:  "data" (and "pod" when multi-pod) partition the document space;
              "model" partitions the query batch.

  query:      replicated over data/pod, sharded over model
  index:      sharded over (pod, data), replicated over model
  execution:  local decode+score (device_index.query_step)
              -> local top-k
              -> all_gather over (pod, data)
              -> merge top-k            (the paper's "results fused")

Conjunctive queries need no merge at all (docid spaces are disjoint): the
local hit bitmaps concatenate, so the collective is a pure reshard.

Local docids are 1..N_shard; global ids are formed inside the mapped
function as ``doc_offset[shard] + local``, where the offsets are the
exclusive prefix sum of the shards' own document counts
(:func:`shard_doc_offsets`) — exact even when shard sizes diverge.

Two layers live here:

  * the jitted ``shard_map`` query step below (device-mesh execution of one
    fused program across TPU shards), and
  * :class:`ShardedEngine` — the host-level fan-out that owns one
    ``repro.engine.Engine`` per shard and routes ``execute_many`` through
    the same unified engine API, so every shard independently plans
    host/device/Pallas execution and keeps its own frozen+delta device
    image fresh.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .device_index import DeviceIndex, decode_blocks, query_step

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.5: experimental home, check_vma spelled check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_compat

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_compat(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


def stack_images(images: list[DeviceIndex]) -> DeviceIndex:
    """Concatenate per-shard images along a leading shard axis.

    All shards must share (V, B) and are padded to the max block count.
    ``num_docs`` of the stacked image is the TOTAL collection size (the sum
    over shards — it is a collection statistic, not a per-shard capacity;
    the per-shard docid capacity is the ``num_docs`` argument of
    :func:`make_sharded_query_step`, and per-shard rank offsets come from
    :func:`shard_doc_offsets`, so shards of unequal size globalize
    correctly).
    """
    nb = max(int(im.blocks.shape[0]) for im in images)
    B = images[0].blocks.shape[1]

    def padb(x):
        return jnp.pad(x, ((0, nb - x.shape[0]), (0, 0)))

    return DeviceIndex(
        blocks=jnp.concatenate([padb(im.blocks) for im in images]),
        term_slot=jnp.concatenate([im.term_slot for im in images]),
        term_nblk=jnp.concatenate([im.term_nblk for im in images]),
        term_skip=jnp.concatenate([im.term_skip for im in images]),
        term_nx=jnp.concatenate([im.term_nx for im in images]),
        term_ft=jnp.concatenate([im.term_ft for im in images]),
        num_docs=sum(im.num_docs for im in images),
        F=images[0].F)


def shard_doc_offsets(images: list[DeviceIndex]) -> "jnp.ndarray":
    """Per-shard global-docid offsets: shard i's local docid d maps to
    ``offsets[i] + d``.  Built from each shard's OWN ``num_docs`` (an
    exclusive prefix sum), so shards of different sizes pack the global
    docid space contiguously — a uniform ``rank * max(num_docs)`` stride
    would leave holes and, worse, disagree with any host-side mapping that
    concatenates the shard collections."""
    sizes = [int(im.num_docs) for im in images]
    off = [0]
    for s in sizes[:-1]:
        off.append(off[-1] + s)
    return jnp.asarray(off, dtype=jnp.int32)


def make_sharded_query_step(mesh, *, k: int = 10, max_blocks: int = 64,
                            num_docs: int = 1 << 20, F: int = 4,
                            decode_fn=None, mode: str = "ranked"):
    """Build the jitted sharded query step for ``mesh``.

    Index arrays are sharded over the document axes ("pod","data"), the query
    batch over "model".  Returns (fn, in_shardings, out_shardings) ready for
    ``jax.jit(...).lower()`` — launch/dryrun.py lowers exactly this.  The
    mapped function takes the six image arrays explicitly (pytree aux fields
    cannot carry shardings) plus the per-shard global-docid offsets
    (:func:`shard_doc_offsets` — each shard reads its OWN offset, so shards
    of unequal document count globalize exactly):
    fn(blocks, slot, nblk, skip, nx, ft, doc_offsets, qt, qm).

    ``num_docs`` is both the per-shard docid CAPACITY (accumulators are
    sized by it; every shard's local docids must fit) and the N the mapped
    scorer weights idf with.  For exact global ranked statistics, rebase
    each shard's ``term_ft`` to the collection-wide document frequencies
    via :func:`~repro.core.device_index.with_global_stats` — KEEPING each
    image's shard-local ``num_docs`` (``shard_doc_offsets`` prefix-sums it,
    so overwriting it with the global N corrupts every offset) — and pass
    the collection total as THIS function's ``num_docs``
    (tests/test_sharded_index.py's unequal-shard test is the reference
    recipe).  Shard-local ``term_ft`` gives the standard
    document-partitioned idf approximation instead, not a merge-exact
    score.
    """
    doc_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    img_specs = (P(doc_axes, None), P(doc_axes), P(doc_axes), P(doc_axes),
                 P(doc_axes), P(doc_axes))
    off_spec = P(doc_axes)
    q_spec = P("model", None)

    if mode == "conjunctive":
        # Boolean AND needs no score fusion at all: docid spaces are
        # disjoint, so the per-shard hit bitmaps simply tile the global
        # docid axis — output stays sharded (model x doc-axes), zero
        # cross-shard traffic beyond the replicated query broadcast.
        def fn_conj(blocks, slot, nblk, skip, nx, ft, offs, qterms, qmask):
            image = DeviceIndex(blocks, slot, nblk, skip, nx, ft,
                                num_docs=num_docs, F=F)
            matches, counts = query_step(
                image, qterms, qmask, k=k, mode="conjunctive",
                max_blocks=max_blocks, decode_fn=decode_fn)
            total = counts
            for ax in doc_axes:
                total = jax.lax.psum(total, ax)
            return matches, total

        in_specs = img_specs + (off_spec, q_spec, q_spec)
        out_specs = (P("model", doc_axes), P("model"))
        mapped = shard_map(fn_conj, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
        in_sharding = tuple(jax.NamedSharding(mesh, s) for s in in_specs)
        out_sharding = tuple(jax.NamedSharding(mesh, s) for s in out_specs)
        return mapped, in_sharding, out_sharding

    def fn(blocks, slot, nblk, skip, nx, ft, offs, qterms, qmask):
        image = DeviceIndex(blocks, slot, nblk, skip, nx, ft,
                            num_docs=num_docs, F=F)
        local_d, local_s = query_step(
            image, qterms, qmask, k=k, mode=mode,
            max_blocks=max_blocks, decode_fn=decode_fn)
        # globalize docids by this shard's own offset (exclusive prefix sum
        # of the preceding shards' num_docs — NOT a uniform rank stride,
        # which would misplace docids the moment shard sizes diverge)
        global_d = jnp.where(local_d > 0, local_d + offs[0], 0)
        # fuse: all-gather the per-shard top-k and re-select
        gs = local_s
        gd = global_d
        for ax in doc_axes:
            gs = jax.lax.all_gather(gs, ax, axis=0, tiled=False)
            gd = jax.lax.all_gather(gd, ax, axis=0, tiled=False)
        gs = gs.reshape(-1, local_s.shape[-2], k)    # (S, Qloc, k)
        gd = gd.reshape(-1, local_d.shape[-2], k)
        gs = jnp.moveaxis(gs, 0, 1).reshape(local_s.shape[-2], -1)
        gd = jnp.moveaxis(gd, 0, 1).reshape(local_d.shape[-2], -1)
        top_s, pos = jax.lax.top_k(gs, k)
        top_d = jnp.take_along_axis(gd, pos, axis=1)
        return top_d, top_s

    # NB: shard_map requires explicit specs for every input leaf
    in_specs = img_specs + (off_spec, q_spec, q_spec)
    out_specs = (P("model", None), P("model", None))
    mapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    in_sharding = tuple(jax.NamedSharding(mesh, s) for s in in_specs)
    out_sharding = tuple(jax.NamedSharding(mesh, s) for s in out_specs)
    return mapped, in_sharding, out_sharding


def sharded_input_specs(mesh, *, shard_blocks: int, B: int = 64,
                        vocab: int = 1 << 17, qbatch: int = 256,
                        qterms: int = 8, num_docs: int = 1 << 20):
    """ShapeDtypeStruct stand-ins for the sharded query step (dry-run)."""
    nshards = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            nshards *= mesh.shape[ax]
    meta = jax.ShapeDtypeStruct((nshards * vocab,), jnp.int32)
    offs = jax.ShapeDtypeStruct((nshards,), jnp.int32)
    q = jax.ShapeDtypeStruct((qbatch, qterms), jnp.int32)
    m = jax.ShapeDtypeStruct((qbatch, qterms), jnp.bool_)
    return (jax.ShapeDtypeStruct((nshards * shard_blocks, B), jnp.uint8),
            meta, meta, meta, meta, meta, offs, q, m)


# --------------------------------------------------------------------------
# host-level shard fan-out through the unified engine
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class _FleetCounts:
    """Fleet-wide ingest counters, published as ONE immutable snapshot so a
    pool-thread reader (ranked scoring mid-fan-out) always sees a mutually
    consistent (version, N, total_tokens) triple — three separate counter
    fields could be observed mid-update between stores."""

    version: int        # bumps per ingested/deleted document (cache key)
    num_docs: int       # docid HORIZON (includes tombstoned — round-robin
    #                     assignment arithmetic must never renumber)
    total_tokens: int   # LIVE token total (decremented at delete)
    deleted_docs: int = 0   # tombstoned fleet-wide (live N = num_docs - this)


class ShardedEngine:
    """Document-partitioned fan-out of per-shard query engines — a
    first-class Engine: exact, parallel, and freeze-coordinated.

    Documents are assigned round-robin; each shard runs a full
    ``repro.engine.Engine`` (its planner may independently pick host,
    device, Pallas, or tiered execution, and its device image refreshes
    incrementally — each shard owns a
    :class:`~repro.engine.device_backend.ResidentImageManager`, so its
    frozen block array uploads once per shard freeze and batched fan-out
    queries reuse the per-shard resident images across flushes).  Queries
    fan out to every shard — on a thread pool, so fan-out wall-clock is
    the max over shards, not the sum — and results fuse:

      * boolean modes (conjunctive / phrase / proximity) — per-shard docid
        lists are globalized and concatenated (docid spaces are disjoint,
        no dedup needed);
      * ranked modes — per-shard top-k lists merge under the canonical tie
        order (higher score, then lower global docid).

    **Docid arithmetic** — round-robin assignment is pure arithmetic, no
    per-document maps: global docid ``g`` lives on shard ``(g-1) % S`` as
    local docid ``(g-1) // S + 1``; local ``l`` on shard ``s`` globalizes
    to ``(l-1)*S + s + 1``.  Globalization is one vectorized affine map and
    the engine carries O(1) routing state regardless of collection size.
    The map is strictly monotone per shard, so per-shard canonical tie
    order IS global canonical tie order — which is what makes the top-k
    merge exact at tied boundaries.

    **Exact global ranked statistics** — the fan-out maintains the
    collection-wide document frequencies, N, and total token count at
    ingest and hands every shard a :class:`~repro.core.query.
    CollectionStats` provider (the same rebasing seam the device
    frozen+delta path uses).  Shards therefore weight postings with exactly
    the numbers a single-engine oracle over the full stream would use, and
    the merged top-k is byte-identical to that oracle (same doubles, same
    canonical tie order) — no shard-local IDF approximation remains.

    **Coordinated freezes** — per-shard static-tier lifecycles register
    with one :class:`~repro.core.lifecycle.FreezeCoordinator`; at most
    ``max_in_flight`` background encodes run fleet-wide, and refused
    shards retry on any later fleet ingest (every queued shard is pumped
    per ingest — see the coordinator docstring) or via
    :meth:`drain_freezes`.

    **Serving integration** — ``version`` (bumps per ingested document) and
    ``lifecycle.epoch`` (composite tier epoch, bumps on any shard's swap)
    give ``serve.QueryService`` the same cache-key components a single
    engine exposes, so result caching and invalidation work unchanged.
    """

    def __init__(self, num_shards: int = 2, engine_factory=None,
                 max_in_flight: int = 1, parallel: bool = True,
                 **engine_kwargs):
        from ..engine import Engine
        from .lifecycle import FreezeCoordinator
        if engine_factory is None:
            def engine_factory():
                return Engine(**engine_kwargs)
        self.engines = [engine_factory() for _ in range(num_shards)]
        self.num_shards = len(self.engines)
        self._counts = _FleetCounts(0, 0, 0)            # published
        # term -> global DOCUMENT frequency
        self._ft: dict[bytes, int] = {}                 # gil_shared
        # per-shard global-f_t arrays aligned to each shard's term ids
        # (keyed by the identity of the engine's append-only vocab list),
        # value-updated incrementally at ingest and suffix-extended at read
        # time — a device-image refresh never re-walks the vocabulary
        self._gft_cache: dict[int, "np.ndarray"] = {}   # gil_shared
        # every shard scores with the fleet's collection-wide statistics
        for e in self.engines:
            e.stats_provider = self.collection_stats
        # fleet freeze scheduling: one coordinator owns every shard lifecycle
        self.coordinator = FreezeCoordinator(max_in_flight=max_in_flight)
        for e in self.engines:
            if getattr(e, "lifecycle", None) is not None:
                self.coordinator.register(e.lifecycle)
        self._pool = None
        if parallel and self.num_shards > 1:
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=self.num_shards,
                thread_name_prefix="shard-fanout")

    def close(self) -> None:
        """Release the fan-out thread pool and join in-flight freezes.
        Idempotent; the engine degrades to serial fan-out afterwards —
        transient fleets (benchmarks, resize/rebuild cycles) should close
        rather than leak ``num_shards`` worker threads until exit."""
        for e in self.engines:
            if getattr(e, "lifecycle", None) is not None:
                e.lifecycle.wait()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "ShardedEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # collection statistics (the exactness seam)
    # ------------------------------------------------------------------

    def collection_stats(self):
        """Fleet-wide (N, avg doclen, f_t) — what every ranked scorer and
        device-image refresh rebases with.  ``avg`` is total tokens over N,
        which equals the oracle's ``doclens[1:N+1].mean()`` bit-for-bit
        (integer sums below 2**53 are exact in float64)."""
        from .query import CollectionStats
        c = self._counts
        live = c.num_docs - c.deleted_docs
        return CollectionStats(
            num_docs=live,
            avg_doclen=c.total_tokens / live if live else 0.0,
            ft=self._ft,
            fts_cache=self._gft_cache)

    @property
    def version(self) -> int:
        """Bumps per ingested document (serving cache-key component)."""
        return self._counts.version

    @property
    def num_docs(self) -> int:
        return self._counts.num_docs

    @property
    def deleted_docs(self) -> int:
        return self._counts.deleted_docs

    @property
    def num_postings(self) -> int:
        return sum(e.index.num_postings for e in self.engines)

    @property
    def lifecycle(self):
        """The fleet coordinator: exposes the composite ``epoch`` the
        serving cache keys on (duck-compatible with a single engine's
        ``FreezeManager`` for that purpose)."""
        return self.coordinator

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------

    def add_document(self, terms) -> int:
        """Ingest one document (single front-door thread — queries and
        ingest are serialized by the caller, the same one-writer model as
        ``Engine``/``QueryService``; the fan-out pool is only ever busy
        INSIDE ``execute_many``, never concurrently with an ingest)."""
        c = self._counts
        g = c.num_docs + 1
        shard = (g - 1) % self.num_shards
        # global stats BEFORE the shard ingest, so the maybe_freeze hooks
        # that fire inside it already see statistics covering this doc
        tbs = [t.encode() if isinstance(t, str) else t for t in terms]
        # resolve each shard's materialized aligned-f_t array once per doc
        # (most fleets have none until a device query materializes them)
        live = [(e._tid, arr) for e in self.engines
                if (arr := self._gft_cache.get(id(e.vocab))) is not None]
        for tb in dict.fromkeys(tbs):
            df = self._ft.get(tb, 0) + 1
            self._ft[tb] = df
            # keep the materialized per-shard aligned f_t arrays current
            # (terms a shard interns later are picked up by the suffix
            # extension in CollectionStats.fts_for)
            for tid_map, arr in live:
                tid = tid_map.get(tb)
                if tid is not None and tid < len(arr):
                    arr[tid] = df
        self._counts = _FleetCounts(c.version + 1, g,
                                    c.total_tokens + len(terms),
                                    c.deleted_docs)
        local = self.engines[shard].add_document(terms)
        assert local == (g - 1) // self.num_shards + 1
        # a global ingest changes every shard's scoring state (N, f_t, avg
        # all moved): bump the non-owner versions too so their device
        # images re-rebase statistics on the next refresh
        for s, e in enumerate(self.engines):
            if s != shard:
                e.version += 1
        # pump deferred freezes fleet-wide: the fleet shares ONE writer
        # thread (this method), so a shard whose encode-slot request was
        # refused may retry on ANY ingest — not only its own — which keeps
        # the coordinator's FIFO live even if routing ever skews away from
        # the queue head
        if self.coordinator.pending:
            for s, e in enumerate(self.engines):
                if s != shard and getattr(e, "lifecycle", None) is not None:
                    e.lifecycle.maybe_freeze()
        return g

    @property
    def word_level(self) -> bool:
        return self.engines[0].index.word_level

    def route_batch(self, prepared):
        """Assign global docids round-robin and update the fleet-wide
        statistics for a whole batch of
        :class:`~repro.core.prepare.PreparedDoc` records — WITHOUT touching
        any shard engine.  Returns ``(gids, per_shard, extra_bumps)``:

          * ``gids`` — the global docids, in submission order;
          * ``per_shard[s]`` — the sub-batch shard ``s`` owns, in local
            docid order (round-robin arithmetic: global ``g`` lands on
            shard ``(g-1) % S`` as local ``(g-1)//S + 1``);
          * ``extra_bumps[s]`` — the number of batch documents shard ``s``
            does NOT own.  A global ingest changes every shard's scoring
            state (N, f_t, avgdl all move), so each shard's version must
            advance by the FULL batch size: its own ingest bumps it by
            ``len(per_shard[s])``, and whoever applies the sub-batch adds
            ``extra_bumps[s]`` on top.  Splitting it this way keeps each
            shard engine's ``version`` written by exactly one thread in
            the pipelined path (its writer), never the router.

        This is the router half of the pipelined write path
        (``serve.ingest_pipeline``): it runs on the submitting thread —
        fleet counters and the global df map stay single-writer — while
        per-shard writer threads apply the returned sub-batches.  Global
        statistics are published BEFORE any shard ingest (one
        ``_FleetCounts`` store), so freeze hooks firing inside a shard's
        apply already see statistics covering the whole batch — the same
        order ``add_document`` uses.
        """
        c = self._counts
        S = self.num_shards
        base = c.num_docs
        gids = list(range(base + 1, base + len(prepared) + 1))
        per_shard: list[list] = [[] for _ in range(S)]
        df_delta: dict[bytes, int] = {}
        tokens = 0
        for i, p in enumerate(prepared):
            per_shard[(base + i) % S].append(p)
            tokens += p.doclen
            for tb in p.uniq:
                df_delta[tb] = df_delta.get(tb, 0) + 1
        live = [(e._tid, arr) for e in self.engines
                if (arr := self._gft_cache.get(id(e.vocab))) is not None]
        for tb, dd in df_delta.items():
            df = self._ft.get(tb, 0) + dd
            self._ft[tb] = df
            for tid_map, arr in live:
                tid = tid_map.get(tb)
                if tid is not None and tid < len(arr):
                    arr[tid] = df
        self._counts = _FleetCounts(c.version + len(prepared),
                                    base + len(prepared),
                                    c.total_tokens + tokens,
                                    c.deleted_docs)
        extra = [len(prepared) - len(per_shard[s]) for s in range(S)]
        return gids, per_shard, extra

    def add_documents(self, docs) -> list[int]:
        """Batched fleet ingest (synchronous: same single front-door
        thread model as ``add_document``; the pipelined variant lives in
        ``serve.ingest_pipeline``).  Answer-identical to a per-document
        loop — same global docids, same fleet statistics, same per-shard
        chains."""
        from .prepare import prepare_batch
        prepared = prepare_batch(docs, self.word_level)
        gids, per_shard, extra = self.route_batch(prepared)
        for s, e in enumerate(self.engines):
            if per_shard[s]:
                e.add_documents(per_shard[s])
            if extra[s]:
                e.version += extra[s]
        # pump deferred freezes fleet-wide (see add_document): every queued
        # shard may retry on any ingest
        if self.coordinator.pending:
            for e in self.engines:
                if getattr(e, "lifecycle", None) is not None:
                    e.lifecycle.maybe_freeze()
        return gids

    def delete_document(self, docid: int) -> None:
        """Tombstone one document fleet-wide (same single-writer model as
        ``add_document``).  The global docid routes to its owner shard by
        the round-robin arithmetic — no per-document map — and the owner's
        returned ``(tid, occurrences)`` pairs mirror the document-frequency
        decrements into the fleet's global ``_ft`` (and every materialized
        per-shard aligned f_t array), so every shard immediately scores
        with statistics of a collection that never held the document."""
        c = self._counts
        if not 1 <= docid <= c.num_docs:
            raise ValueError(f"docid {docid} out of range 1..{c.num_docs}")
        shard = (docid - 1) % self.num_shards
        local = (docid - 1) // self.num_shards + 1
        eng = self.engines[shard]
        doclen = eng._doclens[local]
        entry = eng.delete_document(local)  # raises on double delete
        live = [(e._tid, arr) for e in self.engines
                if (arr := self._gft_cache.get(id(e.vocab))) is not None]
        for tid, _occ in entry:
            tb = eng.vocab[tid]
            df = self._ft.get(tb, 0) - 1
            self._ft[tb] = df
            for tid_map, arr in live:
                t = tid_map.get(tb)
                if t is not None and t < len(arr):
                    arr[t] = df
        # horizon stays put (docid arithmetic is append-only); live token
        # total and the tombstone count move — published as ONE snapshot
        self._counts = _FleetCounts(c.version + 1, c.num_docs,
                                    c.total_tokens - doclen,
                                    c.deleted_docs + 1)
        # a delete changes every shard's scoring state (N, f_t, avg): bump
        # the non-owner versions so their device images re-rebase
        for s, e in enumerate(self.engines):
            if s != shard:
                e.version += 1

    def update_document(self, docid: int, terms) -> int:
        """Atomic-from-the-caller's-view revision: tombstone ``docid`` and
        ingest ``terms`` as a NEW document (new global docid, returned) —
        the same delete+add semantics as ``Engine.update_document``."""
        self.delete_document(docid)
        return self.add_document(terms)

    def collate_now(self) -> None:
        for e in self.engines:
            e.collate_now()

    def drain_freezes(self) -> None:
        """Run every due-or-deferred freeze to completion (tests, shutdown,
        bulk-load tails).  No ingest may run concurrently — this pumps the
        writer-thread side of deferred freezes that would otherwise wait
        for the next document.  Bails out (rather than spinning) if an
        epoch fails to advance — a crashed encode thread must not wedge
        shutdown."""
        mgrs = [e.lifecycle for e in self.engines
                if getattr(e, "lifecycle", None) is not None]
        while True:
            for m in mgrs:
                m.wait()
            before = [m.epoch for m in mgrs]
            if not any([m.maybe_freeze() for m in mgrs]):
                break
            for m in mgrs:
                m.wait()
            if [m.epoch for m in mgrs] == before:
                break
        for m in mgrs:
            m.wait()

    # ------------------------------------------------------------------
    # query fan-out
    # ------------------------------------------------------------------

    def execute(self, query):
        return self.execute_many([query])[0]

    def _globalize(self, shard: int, docids) -> "np.ndarray":
        """Vectorized round-robin globalization: (l-1)*S + shard + 1."""
        import numpy as np
        local = np.asarray(docids, dtype=np.int64)
        return (local - 1) * self.num_shards + shard + 1

    def execute_many(self, queries):
        """Fan a batch out to every shard engine (in parallel) and fuse per
        query.  Each shard result's docids are globalized arithmetically;
        the fused ``backend`` reports the SET of backends that actually
        served the shards (e.g. ``"host+tiered"``)."""
        import numpy as np

        from ..engine.types import QueryResult
        if self._pool is not None:
            per_shard = list(self._pool.map(
                lambda e: e.execute_many(queries), self.engines))
        else:
            per_shard = [e.execute_many(queries) for e in self.engines]
        out = []
        for qi, q in enumerate(queries):
            shard_res = [per_shard[s][qi] for s in range(self.num_shards)]
            backend = "+".join(sorted({r.backend for r in shard_res}))
            reason = f"sharded fan-out x{self.num_shards}"
            gids = np.concatenate([self._globalize(s, r.docids)
                                   for s, r in enumerate(shard_res)])
            if q.mode in ("conjunctive", "phrase", "proximity"):
                out.append(QueryResult(np.sort(gids), None, backend, reason))
            else:
                scores = np.concatenate([r.scores for r in shard_res])
                # canonical ranked tie order across shards: higher score
                # first, then lower GLOBAL docid (not shard arrival order)
                order = np.lexsort((gids, -scores))[:q.k]
                out.append(QueryResult(gids[order], scores[order],
                                       backend, reason))
        return out

    # ------------------------------------------------------------------
    # persistence (core/persist.py)
    # ------------------------------------------------------------------

    def snapshot(self, root: str, *, keep: int = 3,
                 quiesce: bool = False) -> str:
        """Persist the whole fleet under ``root`` — per-shard engine state
        plus the fleet counters and global term statistics, all published
        by ONE atomic rename (shards can never restore torn against each
        other).  Writer thread only.  ``quiesce=True`` joins in-flight
        shard encodes first so every shard's newest tier is captured."""
        from . import persist
        if quiesce:
            for e in self.engines:
                if getattr(e, "lifecycle", None) is not None:
                    e.lifecycle.quiesce()
        return persist.save_sharded(self, root, keep=keep)

    @classmethod
    def restore(cls, path_or_root: str, *, parallel: bool = True,
                max_in_flight: int | None = None,
                **engine_kwargs) -> "ShardedEngine":
        """Rebuild a fleet from a snapshot dir (or the newest under a
        root); per-shard ``engine_kwargs`` forward runtime knobs."""
        from . import persist
        return persist.restore_sharded(path_or_root, parallel=parallel,
                                       max_in_flight=max_in_flight,
                                       **engine_kwargs)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def stats(self):
        """One composite :class:`~repro.engine.types.EngineStats` for the
        fleet (summed counters, merged backend histogram, composite tier
        epoch).  Per-shard detail remains available as
        ``[e.stats() for e in engine.engines]``."""
        from ..engine.types import EngineStats
        agg = EngineStats()
        for e in self.engines:
            s = e.stats()
            agg.deleted_docs += s.deleted_docs
            agg.tombstones_compacted += s.tombstones_compacted
            agg.num_postings += s.num_postings
            agg.num_words += s.num_words
            agg.queries += s.queries
            agg.query_batches += s.query_batches
            agg.query_time_s += s.query_time_s
            agg.ingest_docs += s.ingest_docs
            agg.ingest_batches += s.ingest_batches
            agg.ingest_time_s += s.ingest_time_s
            agg.collations += s.collations
            agg.delta_refreshes += s.delta_refreshes
            agg.delta_compactions += s.delta_compactions
            agg.resident_uploads += s.resident_uploads
            agg.freezes += s.freezes
            for k, v in s.by_backend.items():
                agg.by_backend[k] = agg.by_backend.get(k, 0) + v
        agg.num_docs = self.num_docs
        agg.vocab_size = len(self._ft)
        agg.tier_epoch = self.coordinator.epoch
        agg.num_shards = self.num_shards
        return agg
