"""Gradient compression for cross-pod all-reduce: int8 + error feedback.

The "pod" axis crosses the slowest links, so gradients are quantized to int8
with per-tensor scale before the cross-pod reduction and the quantization
error is fed back into the next step (EF-SGD / 1-bit-Adam lineage: the error
buffer keeps the compressed optimizer unbiased in the long run).

compress -> all-reduce(int8 as int32 accum) -> decompress is 4x less traffic
on the pod links; tests bound the induced error and verify EF convergence on
a quadratic.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: dict  # same pytree structure as grads


def ef_init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, ef: ErrorFeedback):
    """Apply error feedback then quantize every leaf.

    Returns (quantized tree of (q, scale), new ErrorFeedback)."""
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, ef.residual)
    q_tree = jax.tree.map(compress_int8, corrected,
                          is_leaf=lambda x: isinstance(x, jnp.ndarray))
    deq = jax.tree.map(lambda qs: decompress_int8(*qs), q_tree,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return q_tree, ErrorFeedback(residual=new_res)


def psum_compressed(grads, axis_name: str, ef: ErrorFeedback):
    """Cross-pod compressed mean-reduce inside shard_map.

    int8 payloads are summed in int32 (no overflow for pod counts < 2^23),
    scales are averaged — an upper-bound reconstruction matching EF-SGD.
    """
    q_tree, ef = ef_compress_tree(grads, ef)

    def reduce_one(qs):
        q, s = qs
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_mean = jax.lax.pmean(s, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (tot.astype(jnp.float32) * s_mean) / n

    out = jax.tree.map(reduce_one, q_tree,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, ef
