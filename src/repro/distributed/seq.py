"""Scheduling serialization for unrolled chunk loops.

Unrolled (python-loop) chunking keeps HLO cost_analysis exact, but the chunk
bodies are data-independent, so XLA schedules them concurrently and every
chunk's temporaries are live simultaneously — the memory win evaporates
(observed: 16 x 0.83 GiB replicated gathers live at once on dlrm retrieval).

``serialize_after(tree, dep)`` threads a fake data dependency through
``lax.optimization_barrier`` so chunk i+1 cannot be scheduled before chunk
i's output exists, restoring one-chunk-at-a-time liveness while keeping the
loop unrolled (exact FLOP accounting — the reason we don't just use scan).
"""

from __future__ import annotations

import jax


def serialize_after(tree, dep):
    """Return ``tree`` with a scheduling dependency on ``dep``."""
    out, _ = jax.lax.optimization_barrier((tree, dep))
    return out
