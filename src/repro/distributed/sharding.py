"""Sharding rules: logical-axis -> mesh-axis mapping per architecture family.

Mesh axes (launch/mesh.py): ("data", "model") single pod, ("pod", "data",
"model") multi-pod.  Policy:

  * LM dense — FSDP: every weight matrix shards its d_model-sized dim over
    "data" (ZeRO-3; XLA inserts per-layer all-gathers), and its heads/ff/vocab
    dim over "model" (tensor parallel, Megatron-style pairing in/out
    projections so each block needs one reduce per sub-layer).  The "pod"
    axis extends data parallelism — gradient all-reduce crosses the pod link
    once per step.
  * LM MoE — experts shard over "model" (EP); within-expert weights shard
    over "data" (FSDP).  Dispatch/combine lower to all-to-alls over "model".
  * Embedding tables (LM vocab, recsys rows) — row-sharded over the whole
    mesh when huge (recsys: "data"+"model" flattened), or over "model"
    (LM vocab, pairing with the final projection).
  * Activations — batch over ("pod","data"); long-sequence shapes optionally
    shard the sequence dim over "model" (sequence parallelism) between
    attention blocks.

Rules are expressed as regex -> PartitionSpec over *logical* names, resolved
to mesh axes here, so configs stay declarative.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axes(mesh, *names):
    """Filter mesh-axis names to those present (pod optional)."""
    got = []
    for n in names:
        if isinstance(n, tuple):
            sub = tuple(x for x in n if x in mesh.axis_names)
            got.append(sub if sub else None)
        else:
            got.append(n if n in mesh.axis_names else None)
    return got


def lm_param_rules(mesh) -> list[tuple[str, P]]:
    """(regex, PartitionSpec) table for transformer parameter pytree paths."""
    d, m = "data", "model"
    return [
        (r"embed", P(m, d)),                       # (V, D)
        (r"(wq|wk|wv)$", P(None, d, m)),           # (L, D, H*dh)
        (r"wo$", P(None, m, d)),                   # (L, H*dh, D)
        (r"(w_gate|w_up)$", P(None, d, m)),        # (L, D, F)
        (r"w_down$", P(None, m, d)),               # (L, F, D)
        (r"router$", P(None, d, None)),            # (L, D, E)
        (r"(moe_w_gate|moe_w_up)$", P(None, m, d, None)),   # (L, E, D, F)
        (r"moe_w_down$", P(None, m, None, d)),     # (L, E, F, D)
        (r"(norm|scale|ln)", P(None)),             # (L, D) / (D,)
        (r"out_proj$", P(d, m)),                   # (D, V)
        (r".*", P()),
    ]


def spec_for(path: str, rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return P()


def tree_shardings(params_shape, mesh, rules):
    """Map a pytree of ShapeDtypeStruct/arrays to NamedShardings via rules."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        spec = spec_for(name, rules)
        # drop axes the leaf cannot accommodate
        if len(spec) > leaf.ndim:
            spec = P(*spec[: leaf.ndim])
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def constrain(x, mesh, *spec):
    """with_sharding_constraint with absent-axis tolerance."""
    cleaned = []
    for s in spec:
        if s is None:
            cleaned.append(None)
        elif isinstance(s, tuple):
            sub = tuple(a for a in s if a in mesh.axis_names)
            cleaned.append(sub if sub else None)
        else:
            cleaned.append(s if s in mesh.axis_names else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))


def batch_axes(mesh):
    """The data-parallel axes tuple — ("pod","data") when multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def remesh(tree, new_mesh, rules):
    """Elastic re-scaling: move a (possibly sharded) pytree onto a new mesh.

    Used when the device pool grows/shrinks: the same rule table re-resolves
    against the new mesh and arrays are device_put with the new shardings —
    XLA performs the minimal resharding collective.
    """
    shardings = tree_shardings(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree),
        new_mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
