from .sharding import lm_param_rules, constrain  # noqa: F401
from .compression import compress_int8, decompress_int8, ErrorFeedback  # noqa: F401
