"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: (16, 16) = 256 chips, axes
("data", "model").  Multi-pod: (2, 16, 16) = 512 chips with a leading "pod"
axis whose collectives cross the inter-pod links (DCN/ICI-optical); the
gradient all-reduce and the index result fusion are the only ops that
traverse it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
