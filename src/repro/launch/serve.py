"""Serving driver: immediate-access index ingest+query service (the paper's
workload) or LM decode with the Triangle-paged KV cache.

``--mode index``: streams synthetic documents into a DynamicIndex while
serving conjunctive + ranked queries between ingest batches — the paper's
interleaved operation stream (§4.5/§4.6), reporting ingest and query
latencies.

``--mode lm``: batched token-by-token decode of a reduced LM with the paged
KV cache from repro.serve (Triangle page growth).
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def serve_index(n_docs: int, n_queries: int):
    from repro.core.index import DynamicIndex
    from repro.core.query import conjunctive_query, ranked_disjunctive_taat
    from repro.data.corpus import CorpusSpec, SyntheticCorpus

    corpus = SyntheticCorpus(CorpusSpec(n_docs=n_docs, words_per_doc=120,
                                        universe=50_000))
    idx = DynamicIndex(B=64, growth="const")
    rng = np.random.default_rng(0)
    seen_terms: list[str] = []
    q_lat, i_lat = [], []
    qi = 0
    for d, doc in enumerate(corpus.doc_terms()):
        t0 = time.perf_counter()
        idx.add_document(doc)
        i_lat.append(time.perf_counter() - t0)
        if d < 50:
            seen_terms.extend(doc[:5])
        # interleave queries with ingest (immediate access)
        if d % 10 == 9 and seen_terms:
            terms = list(rng.choice(seen_terms,
                                    size=min(3, len(seen_terms))))
            t0 = time.perf_counter()
            if qi % 2 == 0:
                conjunctive_query(idx, terms)
            else:
                ranked_disjunctive_taat(idx, terms, k=10)
            q_lat.append(time.perf_counter() - t0)
            qi += 1
            if qi >= n_queries:
                break
    print(f"[serve-index] docs={idx.num_docs} postings={idx.num_postings} "
          f"bytes/posting={idx.bytes_per_posting():.3f}")
    print(f"[serve-index] ingest mean {np.mean(i_lat)*1e6:.1f}us/doc; "
          f"query mean {np.mean(q_lat)*1e3:.2f}ms "
          f"p95 {np.percentile(q_lat, 95)*1e3:.2f}ms over {qi} queries")


def serve_lm(steps: int):
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import reduced_lm
    from repro.configs import get_arch
    from repro.models import lm as lm_mod
    from repro.serve import PagedKVCache

    mesh = make_host_mesh()
    cfg = reduced_lm(get_arch("llama3.2-3b").cfg)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 128
    pool = PagedKVCache(n_pages=256, page_tokens=16, policy="triangle")
    for b in range(B):
        pool.add_sequence(b)
    with mesh:
        serve = jax.jit(lm_mod.make_serve_step(cfg, mesh),
                        static_argnames=())
        cache = {k: jnp.zeros(v.shape, v.dtype)
                 for k, v in lm_mod.make_cache_shape(cfg, B, S).items()}
        tok = jnp.zeros((B,), jnp.int32)
        t0 = time.perf_counter()
        for pos in range(steps):
            for b in range(B):
                pool.append_tokens(b, 1)
            logits, cache = serve(params, cache, tok, pos)
            tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)
        dt = time.perf_counter() - t0
    ovh = [pool.overhead_tokens(b) for b in range(B)]
    print(f"[serve-lm] {steps} decode steps x {B} seqs in {dt:.2f}s "
          f"({dt/steps*1e3:.1f} ms/step); page overhead/seq {ovh} tokens")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["index", "lm"], default="index")
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=100)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()
    if args.mode == "index":
        serve_index(args.docs, args.queries)
    else:
        serve_lm(args.steps)


if __name__ == "__main__":
    main()
