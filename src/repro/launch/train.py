"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Runs a REDUCED config of the selected architecture on the host devices (this
container is CPU-only; the full configs are exercised via dryrun.py), wiring
together the full production stack: config -> sharded params -> fault-
tolerant Trainer (checkpoint/restart, straggler log, NaN fuse) ->
deterministic data pipeline.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.models import gnn as gnn_mod
from repro.models import lm as lm_mod
from repro.models import recsys as rec_mod
from repro.optim import adamw_init, adamw_update
from repro.train import Trainer


def reduced_lm(cfg: lm_mod.LMConfig) -> lm_mod.LMConfig:
    from dataclasses import replace
    moe = cfg.moe
    if moe is not None:
        from repro.models.lm import MoEConfig
        moe = MoEConfig(n_experts=min(moe.n_experts, 8),
                        top_k=min(moe.top_k, 2))
    return replace(cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                   d_head=32, d_ff=256, vocab=512, moe=moe, microbatch=1,
                   q_chunk=32, kv_chunk=64, loss_chunk=64, pad_multiple=16,
                   dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    mesh = make_host_mesh()
    arch = get_arch(args.arch)
    with mesh:
        if arch.family == "lm":
            cfg = reduced_lm(arch.cfg)
            params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
            opt = adamw_init(params)
            step = jax.jit(lm_mod.make_train_step(
                cfg, mesh, lambda p, g, s: adamw_update(p, g, s, 1e-3)))
            from repro.data.lm import TokenBatches
            data = TokenBatches(cfg.vocab, args.batch, args.seq)

            def batch_at(i):
                b = data.batch_at(i)
                return {k: jnp.asarray(v) for k, v in b.items()}
        elif arch.family == "gnn":
            cfg = gnn_mod.SchNetConfig(n_interactions=2, d_hidden=32,
                                       n_rbf=16, d_feat=16, n_out=1)
            params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
            opt = adamw_init(params)
            step = jax.jit(gnn_mod.make_train_step(
                cfg, mesh, lambda p, g, s: adamw_update(p, g, s, 1e-3),
                n_graphs=args.batch))
            rng = np.random.default_rng(0)
            N, E = args.batch * 16, args.batch * 40

            def batch_at(i):
                r = np.random.default_rng(i)
                return {
                    "node_feat": jnp.asarray(
                        r.standard_normal((N, 16)), jnp.float32),
                    "src": jnp.asarray(r.integers(0, N, E), jnp.int32),
                    "dst": jnp.asarray(r.integers(0, N, E), jnp.int32),
                    "dist": jnp.asarray(r.random(E) * 10, jnp.float32),
                    "edge_mask": jnp.ones(E, bool),
                    "node_mask": jnp.ones(N, jnp.float32),
                    "graph_ids": jnp.asarray(
                        np.arange(N) % args.batch, jnp.int32),
                    "target": jnp.zeros(args.batch, jnp.float32)}
        else:  # recsys
            from repro.data.recsys import RecsysBatches
            dcfg = rec_mod.DLRMConfig(table_rows=(512, 256, 128, 64),
                                      embed_dim=16, bot_mlp=(32, 16),
                                      top_mlp=(64, 32, 1))
            params = rec_mod.dlrm_init(dcfg, jax.random.PRNGKey(0))
            opt = adamw_init(params)
            step = jax.jit(rec_mod.make_train_step(
                lambda p, b: rec_mod.dlrm_loss(p, b, dcfg, mesh),
                lambda p, g, s: adamw_update(p, g, s, 1e-3)))
            data = RecsysBatches(args.batch, table_rows=dcfg.table_rows)

            def batch_at(i):
                b = data.batch_at(i)
                return {"dense": jnp.asarray(b["dense"][:, :13]),
                        "sparse": jnp.asarray(b["sparse"]),
                        "label": jnp.asarray(b["label"])}

        trainer = Trainer(step, params, opt, batch_at,
                          ckpt_dir=args.ckpt_dir, ckpt_every=10)
        metrics = trainer.run(args.steps)
        first, last = metrics[0]["loss"], metrics[-1]["loss"]
        print(f"[train] {args.arch}: loss {first:.4f} -> {last:.4f} over "
              f"{len(metrics)} steps; stragglers={trainer.straggler_steps}")


if __name__ == "__main__":
    main()
