import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For every cell we record:

  * ``compiled.memory_analysis()``   — proves the cell fits per-device HBM;
  * ``compiled.cost_analysis()``     — HLO FLOPs / bytes for §Roofline;
  * collective bytes parsed from the compiled HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute operand sizes) —
    cost_analysis does not report them;
  * the analytic MODEL_FLOPS from the config, for the useful-compute ratio.

Results append to results/dryrun/<arch>__<shape>__<mesh>.json; re-runs skip
existing cells unless --force.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-large-123b \
        --shape train_4k --mesh single
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)

# per-chip link-traffic multiplier on the op's result bytes (ring algorithms)
_COLL_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Returns {"by_op": {...}, "link_bytes": weighted per-chip traffic}."""
    by_op: dict[str, float] = {}
    link = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        _, dtype, dims, op = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dtype]
        by_op[op] = by_op.get(op, 0.0) + b
        link += _COLL_FACTOR[op] * b
    return {"by_op": by_op, "link_bytes": link}


def run_cell(arch_id: str, shape_id: str, mesh_kind: str,
             out_dir: str = RESULTS_DIR, force: bool = False,
             verbose: bool = True, probe_layers: int | None = None) -> dict:
    """Lower + compile one cell.  ``mesh_kind`` ∈ {single, multi}; probe
    cells (LM only) lower unrolled probe_layers variants on the single-pod
    mesh for exact FLOP counting (XLA cost_analysis counts while bodies
    once; see EXPERIMENTS.md §Roofline methodology)."""
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch_id.replace('/', '_')}__{shape_id}__{mesh_kind}"
    if probe_layers is not None:
        tag = f"{arch_id.replace('/', '_')}__{shape_id}__probe{probe_layers}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
           "probe_layers": probe_layers, "status": "error"}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        arch = get_arch(arch_id)
        if probe_layers is not None:
            cell = arch.build(mesh, shape_id, probe_layers=probe_layers)
        else:
            cell = arch.build(mesh, shape_id)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            coll = collective_bytes(compiled.as_text())
        rec.update(
            status="ok",
            kind=cell.kind,
            chips=int(mesh.devices.size),
            model_flops=cell.model_flops,
            cost_scale=getattr(cell, "cost_scale", 1.0),
            hlo_flops=float(ca.get("flops", 0.0)),
            hlo_bytes=float(ca.get("bytes accessed", 0.0)),
            collectives=coll,
            memory=dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                generated_code_bytes=int(ma.generated_code_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
            ),
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            notes=cell.notes,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        status = rec["status"]
        extra = (f"flops={rec.get('hlo_flops', 0):.3e} "
                 f"temp={rec.get('memory', {}).get('temp_bytes', 0)/2**30:.2f}GiB"
                 if status == "ok" else rec.get("error", ""))
        print(f"[dryrun] {tag}: {status} ({time.time()-t0:.1f}s) {extra}",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="lower LM roofline probes (unrolled L=1,2)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()
    arch_ids = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch_id in arch_ids:
        arch = get_arch(arch_id)
        shapes = list(arch.shapes) if args.shape == "all" else [args.shape]
        for shape_id in shapes:
            if args.probe:
                if arch.family != "lm":
                    continue  # non-LM cells are unrolled-exact already
                for pl in (1, 2):
                    rec = run_cell(arch_id, shape_id, "single",
                                   out_dir=args.out, force=args.force,
                                   probe_layers=pl)
                    failures += rec["status"] != "ok"
                continue
            for mesh_kind in meshes:
                rec = run_cell(arch_id, shape_id, mesh_kind,
                               out_dir=args.out, force=args.force)
                failures += rec["status"] != "ok"
    print(f"[dryrun] done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
