"""Paged KV cache with Triangle page-table growth (beyond-paper transfer).

The paper's §5.4 result — square-root block growth makes extensible-list
overhead o(n) instead of Θ(n) — applies to ANY append-only buffer whose final
length is unknown.  A serving KV cache is exactly that: each sequence's cache
grows one token at a time to an unknown final length.  vLLM-style paged
attention uses Const pages (linear page-table overhead + fixed tail waste);
here the per-sequence page capacity follows the paper's Eq. 6, so long
sequences hold a few large pages (small page tables, coalesced DMA) while
short sequences never over-allocate — the same head-block trick as §3.2:
the first page is small, later pages grow as sqrt of tokens held.

Device-side, pages live in one big (n_pages, page_tokens, kv_heads, d_head)
pool; the page table indirection is a gather, as in PagedAttention.  The
allocator below is the host-side control plane (as in vLLM); tests verify
the o(n) overhead claim against Const/Expon paging empirically.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


def triangle_page_schedule(base_tokens: int, h_cost: int = 1,
                           max_pages: int = 4096) -> list[int]:
    """Per-page token capacities following Eq. 6 (B-aligned to base)."""
    sizes = [base_tokens]
    n = base_tokens
    for _ in range(max_pages - 1):
        raw = h_cost + math.sqrt(2.0 * h_cost * n)
        sizes.append(base_tokens * max(1, math.ceil(raw / base_tokens)))
        n += sizes[-1]
    return sizes


@dataclass
class SequenceState:
    seq_id: int
    length: int = 0
    pages: list[int] = field(default_factory=list)   # physical page ids
    page_capacity: list[int] = field(default_factory=list)


class PagedKVCache:
    """Host control plane of the paged KV pool (device pool is a jnp array).

    ``policy`` ∈ {"const", "triangle"}: const = vLLM-style fixed pages;
    triangle = the paper's growth schedule (capacities in units of the base
    page, physically realized as runs of consecutive base pages so the device
    pool stays uniform).
    """

    def __init__(self, n_pages: int, page_tokens: int = 16,
                 policy: str = "triangle"):
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        self.policy = policy
        self.free: list[int] = list(range(n_pages - 1, -1, -1))
        self.seqs: dict[int, SequenceState] = {}
        self._tri = triangle_page_schedule(page_tokens)

    # -- allocation -------------------------------------------------------

    def _next_capacity(self, seq: SequenceState) -> int:
        if self.policy == "const":
            return self.page_tokens
        z = len(seq.pages)
        return self._tri[min(z, len(self._tri) - 1)]

    def add_sequence(self, seq_id: int) -> SequenceState:
        s = SequenceState(seq_id=seq_id)
        self.seqs[seq_id] = s
        return s

    def append_tokens(self, seq_id: int, n_tokens: int) -> list[int]:
        """Reserve space for n new tokens; returns newly-claimed page ids."""
        s = self.seqs[seq_id]
        claimed: list[int] = []
        capacity = sum(s.page_capacity)
        s.length += n_tokens
        while capacity < s.length:
            cap = self._next_capacity(s)
            units = cap // self.page_tokens
            if len(self.free) < units:
                raise MemoryError("KV pool exhausted (preemption point)")
            run = [self.free.pop() for _ in range(units)]
            s.pages.extend(run)
            s.page_capacity.append(cap)
            claimed.extend(run)
            capacity += cap
        return claimed

    def release(self, seq_id: int) -> None:
        s = self.seqs.pop(seq_id)
        self.free.extend(reversed(s.pages))

    # -- accounting (the §5.4 claim, measured) ------------------------------

    def overhead_tokens(self, seq_id: int) -> int:
        """Allocated-but-unused token slots + 1 slot/page table entry."""
        s = self.seqs[seq_id]
        return sum(s.page_capacity) - s.length + len(s.page_capacity)

    def page_table(self, seq_id: int, pad_to: int) -> np.ndarray:
        s = self.seqs[seq_id]
        out = np.full(pad_to, -1, np.int32)
        out[: len(s.pages)] = s.pages
        return out
