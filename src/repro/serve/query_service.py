"""Serving front door: request batching + mixed ingest/query streams.

Production traffic (ROADMAP north star) arrives as an interleaved stream of
document ingests and queries.  The service keeps the paper's immediate-access
contract — a query sees every document ingested before it — while batching
adjacent queries so the engine planner can route them to the batched device
path (``device_min_batch``): the classic serving trade of a tiny queueing
delay for much higher throughput.

Synchronous core, deliberately: one writer per shard is the paper's (and
Asadi & Lin's) concurrency model, and a thread-safe wrapper can wrap
``submit``/``flush`` without touching engine internals.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from ..engine.types import Query, QueryResult


@dataclass
class Ticket:
    """A pending query; ``result`` is filled at flush time."""

    query: Query
    result: QueryResult | None = None
    submitted_at: float = field(default_factory=time.perf_counter)
    latency_s: float | None = None

    @property
    def done(self) -> bool:
        return self.result is not None


class QueryService:
    """Batching executor over an :class:`~repro.engine.Engine` (or a
    :class:`~repro.core.sharded_index.ShardedEngine` — anything with
    ``add_document``/``execute_many``)."""

    def __init__(self, engine, max_batch: int = 32):
        self.engine = engine
        self.max_batch = max_batch
        self._pending: list[Ticket] = []
        self.query_latencies: list[float] = []
        self.ingest_latencies: list[float] = []

    # -- ingest ---------------------------------------------------------

    def ingest(self, terms) -> int:
        """Ingest one document.  Pending queries were submitted BEFORE this
        document, so they are NOT flushed first — immediate access only
        requires a query to see documents ingested before its submission."""
        t0 = time.perf_counter()
        d = self.engine.add_document(terms)
        self.ingest_latencies.append(time.perf_counter() - t0)
        return d

    # -- querying -------------------------------------------------------

    def submit(self, query: Query) -> Ticket:
        """Queue a query; auto-flushes when the batch fills."""
        t = Ticket(query)
        self._pending.append(t)
        if len(self._pending) >= self.max_batch:
            self.flush()
        return t

    def flush(self) -> list[Ticket]:
        """Execute every pending query as one planned batch."""
        batch, self._pending = self._pending, []
        if not batch:
            return []
        results = self.engine.execute_many([t.query for t in batch])
        now = time.perf_counter()
        for t, r in zip(batch, results):
            t.result = r
            t.latency_s = now - t.submitted_at
            self.query_latencies.append(t.latency_s)
        return batch

    def query(self, query: Query) -> QueryResult:
        """Synchronous single query (flushes anything already queued so
        ordering against prior submissions is preserved)."""
        t = self.submit(query)
        self.flush()
        assert t.result is not None
        return t.result

    # -- streams --------------------------------------------------------

    def run_stream(self, ops) -> list[Ticket]:
        """Drive a mixed stream of ("doc", terms) / ("query", Query) ops;
        returns every query ticket in submission order."""
        tickets = []
        for kind, payload in ops:
            if kind == "doc":
                self.ingest(payload)
            elif kind == "query":
                tickets.append(self.submit(payload))
            else:
                raise ValueError(f"unknown op {kind!r}")
        self.flush()
        return tickets

    # -- observability ---------------------------------------------------

    def latency_summary(self) -> dict:
        import numpy as np
        out = {}
        for name, xs in (("query", self.query_latencies),
                         ("ingest", self.ingest_latencies)):
            if xs:
                a = np.asarray(xs)
                out[name] = {"n": len(a), "mean_us": float(a.mean() * 1e6),
                             "p99_us": float(np.quantile(a, 0.99) * 1e6)}
        return out
