"""Serving front door: request batching + mixed ingest/query streams.

Production traffic (ROADMAP north star) arrives as an interleaved stream of
document ingests and queries.  The service keeps the paper's immediate-access
contract — a query sees every document ingested before it — while batching
adjacent queries so the engine planner can route them to the batched device
path (``device_min_batch``): the classic serving trade of a tiny queueing
delay for much higher throughput.

Synchronous core, deliberately: one writer per shard is the paper's (and
Asadi & Lin's) concurrency model, and a thread-safe wrapper can wrap
``submit``/``flush`` without touching engine internals.  With
``pipelined=True`` the write path moves onto per-shard writer queues
(:class:`~repro.serve.ingest_pipeline.IngestPipeline`): ``ingest`` /
``ingest_batch`` enqueue and return immediately, and the immediate-access
barrier moves to ``flush`` — which drains the pipeline before executing,
so a query still sees every document submitted before it.  The front door
itself stays a single thread; per-shard appends run in parallel behind it.

**Result cache**: repeated queries between ingests are answered from a small
LRU keyed by ``(engine.version, static-tier epoch, query)``.  Both key
components exist precisely so invalidation is free: every ingest bumps
``version`` and every lifecycle tier swap bumps the epoch, so a stale entry
can never be returned — it simply stops being addressable.  Entries are
bounded by ``cache_size`` (0 disables caching entirely).
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field

from ..engine.types import Query, QueryResult


@dataclass
class Ticket:
    """A pending query; ``result`` is filled at flush time."""

    query: Query
    result: QueryResult | None = None
    submitted_at: float = field(default_factory=time.perf_counter)
    latency_s: float | None = None

    @property
    def done(self) -> bool:
        return self.result is not None


class QueryService:
    """Batching executor over an :class:`~repro.engine.Engine` (or a
    :class:`~repro.core.sharded_index.ShardedEngine` — anything with
    ``add_document``/``execute_many``)."""

    def __init__(self, engine, max_batch: int = 32, cache_size: int = 256,
                 pipelined: bool = False, pipeline_queue: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self._pending: list[Ticket] = []                # writer_only
        self.query_latencies: list[float] = []
        self.ingest_latencies: list[float] = []
        self.cache_size = cache_size
        self._cache: OrderedDict[tuple, QueryResult] \
            = OrderedDict()                             # writer_only
        self.cache_hits = 0
        self.cache_misses = 0
        self.pipeline = None
        if pipelined:
            from .ingest_pipeline import IngestPipeline
            self.pipeline = IngestPipeline(engine, max_queue=pipeline_queue)

    def close(self) -> None:
        """Drain and stop the ingest pipeline, if one is attached.  The
        service remains usable afterwards on the synchronous write path."""
        if self.pipeline is not None:
            self.pipeline.close()
            self.pipeline = None

    # -- result cache ----------------------------------------------------

    def _cache_key(self, query: Query) -> tuple | None:
        """(version, tier epoch, query) — None when the engine exposes no
        version counter or caching is off.  Works identically over a
        single :class:`~repro.engine.Engine` and a
        :class:`~repro.core.sharded_index.ShardedEngine`: the sharded
        fan-out exposes a per-ingest ``version`` and its ``lifecycle`` is
        the fleet :class:`~repro.core.lifecycle.FreezeCoordinator`, whose
        composite ``epoch`` (sum over shards) bumps whenever ANY shard
        swaps its static tier — so a sharded entry can never outlive the
        tier state it was computed against."""
        if self.cache_size <= 0:
            return None
        version = getattr(self.engine, "version", None)
        if version is None:
            return None
        lifecycle = getattr(self.engine, "lifecycle", None)
        epoch = lifecycle.epoch if lifecycle is not None else 0
        return (version, epoch, query)

    @staticmethod
    def _copy_result(r: QueryResult) -> QueryResult:
        """Results are mutable dataclasses over writable arrays; the cache
        stores and serves private copies so no caller's in-place edits can
        corrupt a later hit."""
        return QueryResult(r.docids.copy(),
                           None if r.scores is None else r.scores.copy(),
                           r.backend, r.reason)

    @property
    def hit_rate(self) -> float:
        """Result-cache hit rate over every CACHEABLE lookup so far (hits /
        (hits + misses)); 0.0 before any lookup.  Uncacheable submissions
        (caching disabled, or an engine without a version counter) count as
        neither hit nor miss — they never consulted the cache."""
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def cache_stats(self) -> dict:
        """Counters for dashboards and the traffic bench: cumulative hits /
        misses, the derived hit rate, and current entry count."""
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "hit_rate": self.hit_rate, "entries": len(self._cache)}

    # -- ingest ---------------------------------------------------------

    def ingest(self, terms) -> int:
        """Ingest one document.  Pending queries were submitted BEFORE this
        document, so they are NOT flushed first — immediate access only
        requires a query to see documents ingested before its submission.
        On the pipelined path this enqueues and returns the docid
        immediately; visibility is settled by ``flush``'s drain."""
        t0 = time.perf_counter()
        if self.pipeline is not None:
            d = self.pipeline.submit([terms])[0]
        else:
            d = self.engine.add_document(terms)
        self.ingest_latencies.append(time.perf_counter() - t0)
        return d

    def ingest_batch(self, docs) -> list[int]:
        """Ingest a batch of documents in one write-path pass (one chain-tail
        lookup and one contiguous encode per distinct term — see
        ``DynamicIndex.add_documents``).  Same flush semantics as
        ``ingest``: pending queries legally miss these documents."""
        t0 = time.perf_counter()
        if self.pipeline is not None:
            dids = self.pipeline.submit(docs)
        else:
            dids = self.engine.add_documents(docs)
        self.ingest_latencies.append(time.perf_counter() - t0)
        return dids

    def delete(self, docid: int) -> None:
        """Tombstone one document.  Pending queries were submitted while it
        was still live, so they are FLUSHED first — the mirror image of
        ``ingest``'s no-flush rule: an ingest only adds visibility (pending
        queries may legally miss a later document), but a delete removes
        it, and a pending query must not miss a document that was alive at
        its submission.  The engine's version bump makes every cached
        result under the old version unaddressable (invalidation is free,
        same as ingest)."""
        self.flush()
        t0 = time.perf_counter()
        self.engine.delete_document(docid)
        self.ingest_latencies.append(time.perf_counter() - t0)

    def update(self, docid: int, terms) -> int:
        """Revise a document: tombstone ``docid``, ingest ``terms`` as a new
        document (new docid returned).  Flushes pending queries first, like
        ``delete`` — they must see the pre-revision state they were
        submitted against."""
        self.flush()
        t0 = time.perf_counter()
        d = self.engine.update_document(docid, terms)
        self.ingest_latencies.append(time.perf_counter() - t0)
        return d

    # -- querying -------------------------------------------------------

    def submit(self, query: Query) -> Ticket:
        """Queue a query; auto-flushes when the batch fills."""
        t = Ticket(query)
        self._pending.append(t)
        if len(self._pending) >= self.max_batch:
            self.flush()
        return t

    def flush(self) -> list[Ticket]:
        """Execute every pending query as one planned batch (cache-aware:
        hits are filled without touching the engine; one engine batch runs
        the misses).  Duplicate queries within a flush execute once — the
        engine batch carries unique queries only (the fused device path
        then decodes each term chain set once per flush), and duplicates
        are fanned back out as private result copies.

        Pipelined mode: the in-flight ingest queues are DRAINED first —
        every pending query was submitted after those documents, so this
        one barrier honors every ticket's high-water mark at once, and
        after it the writer threads are idle, making the cache keys below
        (engine version) stable and the engine safe to fan out over."""
        if self.pipeline is not None:
            self.pipeline.drain()
        batch, self._pending = self._pending, []
        if not batch:
            return []
        # the key is computed ONCE per ticket and reused at store time: a
        # background freeze may bump lifecycle.epoch while execute_many
        # runs, and recomputing the key there would file the result under
        # an engine state it was never computed against (a later query at
        # the new epoch would then hit a stale entry)
        misses: list[tuple[Ticket, tuple | None]] = []
        for t in batch:
            key = self._cache_key(t.query)
            hit = self._cache.get(key) if key is not None else None
            if hit is not None:
                self._cache.move_to_end(key)
                self.cache_hits += 1
                t.result = self._copy_result(hit)
            else:
                self.cache_misses += key is not None
                misses.append((t, key))
        if misses:
            unique: dict = {}        # Query -> slot in the executed batch
            for t, _ in misses:
                unique.setdefault(t.query, len(unique))
            results = self.engine.execute_many(list(unique))
            handed: set[int] = set()
            for t, key in misses:
                slot = unique[t.query]
                r = results[slot]
                # the first ticket of each query takes the result object;
                # duplicates get copies (results are mutable arrays)
                t.result = r if slot not in handed else self._copy_result(r)
                handed.add(slot)
                if key is not None:
                    self._cache[key] = self._copy_result(r)
                    while len(self._cache) > self.cache_size:
                        self._cache.popitem(last=False)
        now = time.perf_counter()
        for t in batch:
            t.latency_s = now - t.submitted_at
            self.query_latencies.append(t.latency_s)
        return batch

    def query(self, query: Query) -> QueryResult:
        """Synchronous single query (flushes anything already queued so
        ordering against prior submissions is preserved)."""
        t = self.submit(query)
        self.flush()
        assert t.result is not None
        return t.result

    def phrase(self, terms, backend: str | None = None) -> QueryResult:
        """Synchronous phrase query over a word-level engine (served from
        the compressed static tier when one is published; results are
        cached under the same version/epoch key as every other mode)."""
        return self.query(Query(terms=tuple(terms), mode="phrase",
                                backend=backend))

    def proximity(self, terms, window: int,
                  backend: str | None = None) -> QueryResult:
        """Synchronous proximity query: documents where ``terms`` co-occur
        within ``window`` words (repeated terms bind distinct positions).
        Served from the compressed static tier once one is published;
        ``window`` is part of the ``Query`` value, hence of the cache key —
        the same terms at different windows never collide."""
        return self.query(Query(terms=tuple(terms), mode="proximity",
                                window=window, backend=backend))

    # -- streams --------------------------------------------------------

    def run_stream(self, ops) -> list[Ticket]:
        """Drive a mixed stream of ("doc", terms) / ("docs", batch) /
        ("query", Query) / ("delete", docid) / ("update", (docid, terms))
        ops; returns every query ticket in submission order."""
        tickets = []
        for kind, payload in ops:
            if kind == "doc":
                self.ingest(payload)
            elif kind == "docs":
                self.ingest_batch(payload)
            elif kind == "query":
                tickets.append(self.submit(payload))
            elif kind == "delete":
                self.delete(payload)
            elif kind == "update":
                self.update(*payload)
            else:
                raise ValueError(f"unknown op {kind!r}")
        self.flush()
        return tickets

    # -- observability ---------------------------------------------------

    def latency_summary(self) -> dict:
        import numpy as np
        out = {}
        for name, xs in (("query", self.query_latencies),
                         ("ingest", self.ingest_latencies)):
            if xs:
                a = np.asarray(xs)
                out[name] = {"n": len(a), "mean_us": float(a.mean() * 1e6),
                             "p99_us": float(np.quantile(a, 0.99) * 1e6)}
        if self.cache_hits or self.cache_misses:
            out["cache"] = {"hits": self.cache_hits,
                            "misses": self.cache_misses,
                            "entries": len(self._cache)}
        return out
