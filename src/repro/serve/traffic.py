"""Open-loop traffic driver: tail latency, cache hit rate, availability.

Drives an :class:`~repro.engine.Engine` or
:class:`~repro.core.sharded_index.ShardedEngine` through a
:class:`~repro.serve.query_service.QueryService` with a pre-generated
:mod:`~repro.serve.workload` schedule, and reports what production cares
about and the mean-of-32-uniform-queries benches cannot show: p50/p99/p999
latency over a mixed Zipf ingest+query stream, result-cache hit rate, and
availability under freeze storms.

**Open-loop latency.**  Each event carries a scheduled arrival time; a
query's latency is its completion time minus the LATER-OF-NOTHING rule:

    latency = completion - min(scheduled_arrival, submit_time)

i.e. when the driver has fallen behind schedule (``submit > sched``) the
queueing delay counts against the system — the open-loop discipline that
makes tail percentiles honest (a closed loop would let a slow system slow
the arrival process and hide its own backlog).  When the driver runs ahead
of schedule (it never sleeps unless ``pace=True``), the event is charged
service time only.

**Determinism.**  The schedule is pure in its seed (see ``workload``), and
``clock`` is pluggable: tests pass a :class:`FakeClock` (fixed tick per
call) so the whole percentile report is bit-reproducible; benches use the
real ``time.perf_counter``.

**Availability.**  Every query is executed under a try/except; an exception
(or a missing result) counts into ``availability_gap``.  The zero-gap
acceptance criterion is exactly the lifecycle's promise: background freezes
swap tiers atomically, so no query ever fails or blocks on a swap.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .query_service import QueryService
from .workload import Event


class FakeClock:
    """Deterministic stand-in for ``time.perf_counter``: every call advances
    a fixed tick, so latencies (hence percentiles) are pure functions of
    the event schedule and call pattern."""

    def __init__(self, tick_s: float = 1e-6):
        self.tick_s = tick_s
        self.now = 0.0

    def __call__(self) -> float:
        self.now += self.tick_s
        return self.now


@dataclass(frozen=True)
class SLOSpec:
    """Service-level objectives the traffic report is judged against.
    ``None`` disables a bound.  Latency bounds are milliseconds;
    ``max_availability_gap`` is a count (production target: 0)."""

    p50_ms: float | None = None
    p99_ms: float | None = None
    p999_ms: float | None = None
    min_cache_hit_rate: float | None = None
    max_availability_gap: int | None = 0

    def evaluate(self, report: "TrafficReport") -> dict:
        """{"ok": bool, "violations": [human-readable strings]}."""
        v: list[str] = []
        for name, bound in (("p50_ms", self.p50_ms), ("p99_ms", self.p99_ms),
                            ("p999_ms", self.p999_ms)):
            got = getattr(report, name)
            if bound is not None and got > bound:
                v.append(f"{name} {got:.3f} > SLO {bound:.3f}")
        if (self.min_cache_hit_rate is not None
                and report.cache_hit_rate < self.min_cache_hit_rate):
            v.append(f"cache_hit_rate {report.cache_hit_rate:.3f} < "
                     f"SLO {self.min_cache_hit_rate:.3f}")
        if (self.max_availability_gap is not None
                and report.availability_gap > self.max_availability_gap):
            v.append(f"availability_gap {report.availability_gap} > "
                     f"SLO {self.max_availability_gap}")
        return {"ok": not v, "violations": v}

    def to_dict(self) -> dict:
        return {"p50_ms": self.p50_ms, "p99_ms": self.p99_ms,
                "p999_ms": self.p999_ms,
                "min_cache_hit_rate": self.min_cache_hit_rate,
                "max_availability_gap": self.max_availability_gap}


@dataclass
class TrafficReport:
    """Everything one traffic run measured.  ``to_dict`` is the
    ``BENCH_engine.json["traffic"]`` payload shape."""

    num_events: int = 0
    num_queries: int = 0
    num_ingests: int = 0
    num_deletes: int = 0
    duration_s: float = 0.0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    p999_ms: float = 0.0
    mean_ms: float = 0.0
    max_ms: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_hit_rate: float = 0.0
    availability_gap: int = 0     # queries that errored / went unanswered
    freezes: int = 0              # completed tier swaps during the run
    tier_epoch: int = 0
    qps: float = 0.0
    latencies_s: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.float64), repr=False)

    def to_dict(self) -> dict:
        return {k: (float(v) if isinstance(v, float) else int(v))
                for k, v in (
                    ("num_events", self.num_events),
                    ("num_queries", self.num_queries),
                    ("num_ingests", self.num_ingests),
                    ("num_deletes", self.num_deletes),
                    ("duration_s", self.duration_s),
                    ("p50_ms", self.p50_ms), ("p99_ms", self.p99_ms),
                    ("p999_ms", self.p999_ms), ("mean_ms", self.mean_ms),
                    ("max_ms", self.max_ms),
                    ("cache_hits", self.cache_hits),
                    ("cache_misses", self.cache_misses),
                    ("cache_hit_rate", self.cache_hit_rate),
                    ("availability_gap", self.availability_gap),
                    ("freezes", self.freezes),
                    ("tier_epoch", self.tier_epoch),
                    ("qps", self.qps))}


def run_traffic(engine, schedule: list[Event], docs, *, max_batch: int = 32,
                cache_size: int = 256, clock=None, pace: bool = False,
                ingest_batch: int = 1,
                service: QueryService | None = None) -> TrafficReport:
    """Drive ``engine`` through ``schedule``; returns the measured report.

    ``docs`` is the ingest corpus — event ``doc`` indexes wrap around it.
    ``clock`` defaults to ``time.perf_counter``; pass a :class:`FakeClock`
    for deterministic reports.  ``pace=True`` sleeps until each event's
    scheduled arrival (real-time replay); the default runs as fast as the
    engine allows, which keeps benches quick while the open-loop latency
    rule above still charges any backlog to the system.

    Driver policy: pending queries are flushed BEFORE each ingest — they
    were submitted first, and completing them first keeps their latency
    from absorbing unrelated ingest cost.  (Immediate access never needs
    the opposite order: a query must only see documents ingested before its
    submission.)

    ``ingest_batch > 1`` coalesces consecutive ingest events into one
    ``QueryService.ingest_batch`` call (the batched write path).  Buffered
    documents are ALWAYS ingested before the next query submission or
    delete — every event that could observe them still sees exactly the
    documents scheduled before it, so answers (and cache behavior per
    engine version reached) are schedule-equivalent to the unbatched run.
    """
    clock = clock or time.perf_counter
    svc = service or QueryService(engine, max_batch=max_batch,
                                  cache_size=cache_size)
    lat: list[float] = []
    gap = 0
    pending: list[tuple] = []   # (ticket, effective_arrival)
    t_run0 = clock()

    def drain(batch) -> None:
        nonlocal gap
        if not batch:
            return
        done = clock()
        by_ticket = {id(t): a for t, a in pending}
        for t in batch:
            arr = by_ticket.pop(id(t), None)
            if arr is None:
                continue
            if t.result is None:
                gap += 1
            else:
                lat.append(max(done - arr, 0.0))
        pending[:] = [(t, a) for t, a in pending if id(t) in by_ticket]

    n_q = n_i = n_d = 0
    ingested: list[int] = []    # ingest ordinal -> real docid
    ibuf: list = []             # coalesced ingest docs awaiting submission

    def flush_ingests() -> None:
        nonlocal gap
        if not ibuf:
            return
        n = len(ibuf)
        try:
            ingested.extend(svc.ingest_batch(list(ibuf)))
        except Exception:
            gap += n
            ingested.extend([-1] * n)   # keep later ordinals aligned
        ibuf.clear()

    for ev in schedule:
        sched = t_run0 + ev.at_s
        if pace:
            delay = sched - clock()
            if delay > 0:
                time.sleep(delay)
        if ev.kind == "ingest":
            n_i += 1
            if ingest_batch > 1:
                ibuf.append(docs[ev.doc % len(docs)])
                if len(ibuf) >= ingest_batch:
                    drain(svc.flush())
                    flush_ingests()
                continue
            drain(svc.flush())
            try:
                ingested.append(svc.ingest(docs[ev.doc % len(docs)]))
            except Exception:
                gap += 1
                ingested.append(-1)     # keep later ordinals aligned
        elif ev.kind == "delete":
            # the target docid may still be in the coalescing buffer, and a
            # delete must observe every document scheduled before it
            flush_ingests()
            # svc.delete flushes pending itself (they must see the doc
            # alive); flushing here first lets drain() account latencies
            drain(svc.flush())
            n_d += 1
            try:
                svc.delete(ingested[ev.doc])
            except Exception:
                gap += 1
        else:
            # this query must see every ingest event scheduled before it
            flush_ingests()
            n_q += 1
            now = clock()
            try:
                t = svc.submit(ev.query)
            except Exception:
                gap += 1
                continue
            # open-loop: behind schedule -> charge queueing from the
            # scheduled arrival; ahead of schedule -> service time only
            pending.append((t, min(sched, now)))
            if t.done:          # submit auto-flushed a full batch
                drain([p for p, _ in pending if p.done])
    flush_ingests()
    drain(svc.flush())
    drain([p for p, _ in pending])  # anything left unanswered counts as gap
    t_run1 = clock()

    rep = TrafficReport(num_events=len(schedule), num_queries=n_q,
                        num_ingests=n_i, num_deletes=n_d,
                        duration_s=t_run1 - t_run0,
                        availability_gap=gap)
    if lat:
        a = np.asarray(lat, np.float64)
        rep.latencies_s = a
        p50, p99, p999 = np.quantile(a, [0.5, 0.99, 0.999])
        rep.p50_ms = float(p50 * 1e3)
        rep.p99_ms = float(p99 * 1e3)
        rep.p999_ms = float(p999 * 1e3)
        rep.mean_ms = float(a.mean() * 1e3)
        rep.max_ms = float(a.max() * 1e3)
    cs = svc.cache_stats()
    rep.cache_hits = cs["hits"]
    rep.cache_misses = cs["misses"]
    rep.cache_hit_rate = cs["hit_rate"]
    stats = engine.stats()
    rep.freezes = stats.freezes
    rep.tier_epoch = stats.tier_epoch
    if rep.duration_s > 0:
        rep.qps = n_q / rep.duration_s
    return rep


__all__ = ["FakeClock", "SLOSpec", "TrafficReport", "run_traffic"]
