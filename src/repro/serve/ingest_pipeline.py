"""Pipelined write path: bounded per-shard writer queues with the
immediate-access barrier at query fan-out (ROADMAP: paper-scale ingest).

The synchronous ingest path pays tokenization, routing, and the BlockStore
append on one thread per call.  This module splits an ingest into the three
stages the paper's ~2 GB/min claim presumes (and Asadi & Lin's pipelined
in-memory indexer makes explicit):

  1. **prepare** — tokenization/term-byte aggregation
     (:func:`~repro.core.prepare.prepare_batch`): pure, runs on the
     SUBMITTING thread, never on a writer;
  2. **route** — global docid assignment + fleet statistics
     (:meth:`~repro.core.sharded_index.ShardedEngine.route_batch`): cheap
     dict arithmetic, also on the submitting thread, so fleet counters keep
     exactly one writer;
  3. **append** — the per-shard batched BlockStore append
     (``Engine.add_documents``): each shard's bounded queue is drained by
     its own writer thread, so round-robin writers run independently and a
     fleet ingests at shard-parallel speed.

**The immediate-access barrier moves to query fan-out.**  ``submit``
returns docids immediately (assignment is deterministic arithmetic); the
paper's contract — a query sees every document submitted before it — is
enforced by whoever executes queries: capture :meth:`ticket` at query
submission and :meth:`wait` on it before fanning out
(``QueryService.flush`` does both).  A ticket is the per-shard
high-water-mark vector of submitted documents; ``wait`` blocks until every
shard's applied count reaches its mark.  Ingest throughput therefore never
pays a per-document visibility sync — only a query that actually arrives
pays, and only for documents submitted before it.

**Single-writer discipline.**  Each shard engine is written by exactly one
thread — its queue's drainer (the router never touches shard engines, and
each drain applies that shard's version bumps for the whole batch,
including the ``extra`` bumps for fleet documents the shard does not own).
The front door may touch engines directly (delete/update/collate) only
after :meth:`drain` — which is exactly what ``QueryService`` does.  The
queues are bounded: a submitter that outruns the writers blocks, so memory
stays flat under ingest storms.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

from ..core.prepare import prepare_batch


@dataclass(frozen=True)
class IngestTicket:
    """Per-shard high-water marks (documents submitted up to a moment).

    ``marks[s]`` counts every fleet document routed through shard ``s``'s
    queue — sub-batch applies plus non-owned version bumps advance it by
    the full batch size, so all marks agree and any one of them is the
    total submitted-document count."""

    marks: tuple[int, ...]


class _ShardWriter:
    """One bounded queue + drainer thread for one shard engine."""

    def __init__(self, engine, max_queue: int):
        self.engine = engine
        self._q = queue.Queue(maxsize=max_queue)
        self._cv = threading.Condition()
        self._submitted = 0     # writer_only — the submitting front door
        self._completed = 0     # guarded_by: _cv
        self._error = None      # guarded_by: _cv
        self._thread = None

    def start(self) -> None:
        def drain():
            while True:
                item = self._q.get()
                if item is None:
                    return
                batch, extra = item
                n = len(batch) + extra
                try:
                    if batch:
                        self.engine.add_documents(batch)
                    if extra:
                        # fleet documents this shard does not own still move
                        # its scoring state (N, f_t, avgdl) — bump here, on
                        # the one thread that writes this engine's version
                        self.engine.version += extra
                except BaseException as exc:  # propagate to wait()/close()
                    with self._cv:
                        self._error = exc
                        self._cv.notify_all()
                    return
                with self._cv:
                    self._completed += n
                    self._cv.notify_all()
        self._thread = threading.Thread(
            target=drain, daemon=True, name=f"ingest-writer")
        self._thread.start()

    def submit(self, batch, extra: int) -> int:
        """Enqueue one (sub-batch, extra-bump) item; returns the new
        high-water mark.  Blocks when the bounded queue is full."""
        self._submitted += len(batch) + extra
        self._q.put((batch, extra))
        return self._submitted

    @property
    def mark(self) -> int:
        return self._submitted

    def wait(self, mark: int) -> None:
        """Block until ``mark`` documents have been applied (the barrier).
        Re-raises a writer-thread failure rather than hanging on it."""
        with self._cv:
            while self._completed < mark:
                if self._error is not None:
                    raise RuntimeError(
                        "ingest writer thread failed") from self._error
                self._cv.wait(timeout=0.5)
            if self._error is not None:
                raise RuntimeError(
                    "ingest writer thread failed") from self._error

    def stop(self) -> None:
        if self._thread is None:
            return
        self._q.put(None)
        self._thread.join()
        self._thread = None


class IngestPipeline:
    """Bounded, pipelined batch ingest over an ``Engine`` or
    ``ShardedEngine`` (anything with ``add_documents``; a fleet's
    ``route_batch`` unlocks per-shard parallelism).

    While a pipeline is attached, ALL ingest must flow through
    :meth:`submit` (docid assignment is pipeline-side for a single engine),
    and any direct engine mutation (delete/update/collate/snapshot) must be
    preceded by :meth:`drain` — ``QueryService`` enforces both.  Use as a
    context manager, or :meth:`close` explicitly; writers are daemon
    threads, so a leaked pipeline cannot wedge interpreter exit.

    ``max_queue`` bounds each shard queue in BATCH items: a submitter more
    than ``max_queue`` batches ahead of a writer blocks until the writer
    catches up (bounded memory under storms).
    """

    def __init__(self, engine, max_queue: int = 8):
        self.engine = engine
        self._route = getattr(engine, "route_batch", None)
        engines = getattr(engine, "engines", None) \
            if self._route is not None else None
        self._writers = [_ShardWriter(e, max_queue)
                         for e in (engines if engines is not None
                                   else [engine])]
        self._word = (engine.word_level if engines is not None
                      else engine.index.word_level)
        # single-engine docid assignment happens HERE (the writer applies
        # later); seeded from the engine, advanced per submit — valid
        # precisely while every ingest flows through the pipeline
        self._next_docid = (engine.num_docs if engines is not None
                            else engine.index.num_docs)  # writer_only
        for w in self._writers:
            w.start()

    # -- submit / barrier ------------------------------------------------

    def submit(self, docs) -> list[int]:
        """Stage 1+2 on the calling thread (tokenize, route, assign
        docids), enqueue stage 3 per shard; returns the assigned global
        docids immediately.  Submitting thread only (the front door)."""
        prepared = prepare_batch(docs, self._word)
        if self._route is not None:
            gids, per_shard, extra = self._route(prepared)
            for s, w in enumerate(self._writers):
                w.submit(per_shard[s], extra[s])
            return gids
        base = self._next_docid
        self._next_docid = base + len(prepared)
        self._writers[0].submit(prepared, 0)
        return list(range(base + 1, base + len(prepared) + 1))

    def ticket(self) -> IngestTicket:
        """The current per-shard high-water marks: a query submitted NOW
        must wait on exactly this ticket before it executes."""
        return IngestTicket(tuple(w.mark for w in self._writers))

    def wait(self, ticket: IngestTicket) -> None:
        """The immediate-access barrier: block until every shard has
        applied the documents submitted before ``ticket`` was taken."""
        for w, m in zip(self._writers, ticket.marks):
            w.wait(m)

    def drain(self) -> None:
        """Wait for everything submitted so far (= ``wait(ticket())``).
        After this returns — and until the next ``submit`` — no writer
        thread touches any engine, so the front door may mutate engines
        directly (delete/update/collate/snapshot)."""
        self.wait(self.ticket())

    def in_flight(self) -> bool:
        """True if any submitted batch has not been fully applied yet."""
        for w in self._writers:
            with w._cv:
                if w._completed < w._submitted:
                    return True
        return False

    # -- lifecycle -------------------------------------------------------

    def close(self) -> None:
        """Drain and stop the writer threads (idempotent)."""
        try:
            self.drain()
        finally:
            for w in self._writers:
                w.stop()

    def __enter__(self) -> "IngestPipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["IngestPipeline", "IngestTicket"]
