"""Deterministic Zipf workload schedules for the traffic harness.

Realistic serving load is nothing like the benches' 32 uniform queries:
query popularity and term choice are both heavily Zipf-skewed (Asadi & Lin:
skew, not uniform sampling, is what exposes tail behaviour in incremental
in-memory indexes), arrivals come in bursts, and ingest interleaves with
querying.  This module generates exactly that — as a pure function of a
:class:`WorkloadSpec` and its seed.

Schedule generation is deliberately HERMETIC: no wall clock, no global RNG,
no ambient state — every event time comes from ``numpy``'s seeded
``default_rng``.  The ``repro.analysis`` schedule-purity lint enforces the
import surface (no ``time``/``random``/``datetime``), and
tests/test_traffic.py pins seed determinism end to end: same seed →
identical schedule and identical percentile report.

Workload shape:

  * a **distinct-query pool** is drawn first (``num_distinct_queries``
    queries; terms Zipf-picked over the frequency-ranked vocabulary, modes
    cycled from ``modes``); each query event then samples the pool under a
    Zipf popularity law — the repetition that makes result caching mean
    something;
  * **mixed stream**: each event is an ingest with probability
    ``ingest_fraction`` (documents are consumed in corpus order), else a
    query;
  * **bursty (on/off) arrivals**: the arrival process alternates ON bursts
    (exponential inter-arrivals at ``rate_hz``) and OFF lulls
    (``off_rate_hz``), with geometric burst/lull lengths — the classic
    two-state MMPP shape.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..engine.types import POSITIONAL_MODES, Query


@dataclass(frozen=True)
class WorkloadSpec:
    """Everything that determines a schedule, seed included.

    ``modes`` must fit the target engine: positional modes (phrase /
    proximity / bm25_prox) need a word-level engine.  ``rate_hz`` /
    ``off_rate_hz`` are the ON-burst and OFF-lull arrival rates;
    ``mean_burst`` / ``mean_off`` the mean event counts per state.
    """

    seed: int = 0
    num_events: int = 2000
    ingest_fraction: float = 0.2
    # fraction of events that tombstone a random still-live prior ingest
    # (0.0 = the historical ingest+query mix; a delete event with nothing
    # yet deletable degrades to a query, keeping the stream seed-pure)
    delete_fraction: float = 0.0
    num_distinct_queries: int = 64
    query_zipf_s: float = 1.07
    term_zipf_s: float = 1.07
    max_terms: int = 3
    modes: tuple[str, ...] = ("conjunctive", "ranked_tfidf", "bm25")
    k: int = 10
    window: int = 8
    rate_hz: float = 2000.0
    off_rate_hz: float = 200.0
    mean_burst: float = 50.0
    mean_off: float = 20.0

    def __post_init__(self):
        if not 0.0 <= self.ingest_fraction <= 1.0:
            raise ValueError("ingest_fraction must be in [0, 1]")
        if not 0.0 <= self.delete_fraction <= 1.0:
            raise ValueError("delete_fraction must be in [0, 1]")
        if self.ingest_fraction + self.delete_fraction > 1.0:
            raise ValueError("ingest_fraction + delete_fraction must "
                             "not exceed 1")
        if self.num_distinct_queries < 1 or self.num_events < 1:
            raise ValueError("need >= 1 distinct query and >= 1 event")
        if min(self.rate_hz, self.off_rate_hz) <= 0:
            raise ValueError("arrival rates must be positive")
        if min(self.mean_burst, self.mean_off) < 1.0:
            raise ValueError("mean burst/off lengths must be >= 1 event")


@dataclass(frozen=True)
class Event:
    """One scheduled arrival: a query (with its Query value), an ingest
    (``doc`` indexes the driver's corpus, assigned in arrival order), or a
    delete (``doc`` is the INGEST ORDINAL of the victim — the driver maps
    it to the real docid it got back from that ingest)."""

    at_s: float
    kind: str                   # "query" | "ingest" | "delete"
    query: Query | None = None
    doc: int | None = None


def _zipf_probs(n: int, s: float) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** s
    return p / p.sum()


def build_query_pool(spec: WorkloadSpec, vocab: list[str],
                     rng: np.random.Generator) -> list[Query]:
    """The distinct-query population: terms Zipf-drawn (without replacement
    per query) over the vocabulary in rank order — pass ``vocab`` sorted by
    descending collection frequency for the realistic head-heavy mix."""
    tp = _zipf_probs(len(vocab), spec.term_zipf_s)
    pool = []
    for i in range(spec.num_distinct_queries):
        mode = spec.modes[i % len(spec.modes)]
        nt = int(rng.integers(1, spec.max_terms + 1))
        if mode in POSITIONAL_MODES and mode != "bm25_prox":
            nt = max(nt, 2)  # 1-term phrase/proximity is degenerate
        picks = rng.choice(len(vocab), size=min(nt, len(vocab)),
                           replace=False, p=tp)
        pool.append(Query(
            terms=tuple(str(vocab[j]) for j in picks), mode=mode, k=spec.k,
            window=spec.window if mode == "proximity" else None))
    return pool


def generate_schedule(spec: WorkloadSpec, vocab: list[str]) -> list[Event]:
    """The full deterministic event schedule for ``spec``: ``num_events``
    arrivals with non-decreasing ``at_s``, mixed ingest/query, bursty
    on/off inter-arrival times.  Pure in the seed — calling twice with the
    same spec yields identical events."""
    rng = np.random.default_rng(spec.seed)
    pool = build_query_pool(spec, vocab, rng)
    qp = _zipf_probs(len(pool), spec.query_zipf_s)
    events: list[Event] = []
    t = 0.0
    doc_counter = 0
    alive: list[int] = []       # ingest ordinals not yet scheduled deleted
    on = True
    left = int(rng.geometric(1.0 / spec.mean_burst))
    while len(events) < spec.num_events:
        if left <= 0:
            on = not on
            mean = spec.mean_burst if on else spec.mean_off
            left = int(rng.geometric(1.0 / mean))
            continue
        rate = spec.rate_hz if on else spec.off_rate_hz
        t += float(rng.exponential(1.0 / rate))
        left -= 1
        r = float(rng.random())
        if r < spec.ingest_fraction:
            events.append(Event(at_s=t, kind="ingest", doc=doc_counter))
            alive.append(doc_counter)
            doc_counter += 1
        elif r < spec.ingest_fraction + spec.delete_fraction and alive:
            # victim uniform over still-live prior ingests; each ordinal is
            # deleted at most once (double deletes are an error downstream)
            pick = int(rng.integers(len(alive)))
            events.append(Event(at_s=t, kind="delete", doc=alive.pop(pick)))
        else:
            q = pool[int(rng.choice(len(pool), p=qp))]
            events.append(Event(at_s=t, kind="query", query=q))
    return events


__all__ = ["WorkloadSpec", "Event", "build_query_pool", "generate_schedule"]
