from .kv_cache import PagedKVCache, triangle_page_schedule  # noqa: F401
from .query_service import QueryService, Ticket  # noqa: F401
from .traffic import (  # noqa: F401
    FakeClock,
    SLOSpec,
    TrafficReport,
    run_traffic,
)
from .workload import (  # noqa: F401
    Event,
    WorkloadSpec,
    build_query_pool,
    generate_schedule,
)
