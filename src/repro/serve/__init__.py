from .kv_cache import PagedKVCache, triangle_page_schedule  # noqa: F401
