from .kv_cache import PagedKVCache, triangle_page_schedule  # noqa: F401
from .query_service import QueryService, Ticket  # noqa: F401
