"""Jitted public entry point for the fused decode→score→top-k kernel.

:func:`fused_query` answers a whole query batch against one or more
device-resident images (typically the frozen :class:`DeviceIndex` plus the
post-freeze :class:`DeltaIndex`) in a single launch per (mode, k) group:

  1. *prep/gather* (XLA): per image, every query's live terms' chain
     blocks are packed term-major into a (Q, PB_i, B) *part* whose slots
     carry the owning term's segment id, docid-chaining bases and idf
     weight — the uniform slot layout lets frozen and delta chains run
     through identical segmented arithmetic (see ``ref.fused_tile``).
     Each image keeps its OWN packed capacity PB_i (``max_blocks`` is a
     per-image tuple, sized by the caller to the batch's longest per-query
     block total): the delta suffix is typically a handful of blocks, and
     packing means nobody pays for the vocabulary's longest chain;
  2. *fused compute*: decode → docids → score → top-k in one kernel
     (``flavor="pallas"``) or as the same math inline (``flavor="ref"``,
     the oracle the kernel is byte-compared against).

Both flavours are jitted end-to-end; shapes are bucketed by the caller
(vocab/doc/block capacities round to powers of two), so steady-state
serving reuses compiled programs across refreshes.

Merging images inside the launch is exact: frozen and delta docid spaces
are disjoint (docids are ordinal; docs ≤ freeze-N live wholly in the
frozen image) and both sides weight postings with the same global f_t.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...core.blockstore import H
from ...core.device_index import DeltaIndex
from .kernel import DEFAULT_TQ, fused_query_kernel
from .ref import BM25_B, BM25_K1, fused_tile

#: Modes the fused kernel serves (positional modes need word positions,
#: which device images do not model).
FUSED_MODES = ("conjunctive", "ranked_tfidf", "bm25")


def _prep_image(image, qterms, qmask, Ns, max_blocks: int, mode: str):
    """Pack one image's chain blocks: (Q, PB, B) slots + per-slot metadata.

    Each query's live terms' actual chain blocks are packed term-major into
    PB = ``max_blocks`` slots (the caller sizes PB to the batch's longest
    per-query block total, NOT to T × the longest chain in the vocabulary —
    a ~4–8× decode saving at bench scale).  Every slot carries its owning
    term's segment id, chaining bases and idf weight, so the tile can run
    segmented scans along the slot axis.
    """
    Q, T = qterms.shape
    PB = max_blocks
    B = image.blocks.shape[1]
    flat = qterms.reshape(-1)
    slot = image.term_slot[flat].reshape(Q, T)
    nblk = jnp.where(qmask, image.term_nblk[flat].reshape(Q, T), 0)
    skip = image.term_skip[flat].reshape(Q, T)
    nx = image.term_nx[flat].reshape(Q, T)
    # term-major packing: slot s of query q belongs to the last term whose
    # exclusive block-offset is <= s (empty terms yield no slots)
    off = jnp.cumsum(nblk, axis=1) - nblk              # exclusive prefix
    total = off[:, -1] + nblk[:, -1]
    s = jnp.arange(PB, dtype=jnp.int32)[None, :]
    t_of = (s[:, :, None] >= off[:, None, :]).sum(axis=2) - 1  # (Q, PB)
    within = s - jnp.take_along_axis(off, t_of, axis=1)
    valid = s < total[:, None]
    slot_s = jnp.take_along_axis(slot, t_of, axis=1)
    nblk_s = jnp.take_along_axis(nblk, t_of, axis=1)
    bidx = jnp.where(valid, slot_s + within, 0)
    gat = image.blocks[bidx.reshape(-1)].reshape(Q, PB, B)
    is_head = within == 0
    is_tail = within == nblk_s - 1
    start = jnp.where(is_head, jnp.take_along_axis(skip, t_of, axis=1), H)
    end = jnp.where(is_tail, jnp.take_along_axis(nx, t_of, axis=1), B)
    end = jnp.where(valid, end, 0)
    seg = jnp.where(valid, t_of, T)                    # pad slots: own seg
    if isinstance(image, DeltaIndex):
        lastd0 = image.term_lastd0[flat].reshape(Q, T)
        dnum0 = image.term_dnum0[flat].reshape(Q, T)
        lastd0_s = jnp.take_along_axis(lastd0, t_of, axis=1)
        dnum0_s = jnp.take_along_axis(dnum0, t_of, axis=1)
    else:
        # frozen segments: absolute chains — the -1 sentinel makes the tile
        # use the head block's own first gap as the b-gap base (pure cumsum)
        lastd0_s = jnp.zeros((Q, PB), jnp.int32)
        dnum0_s = jnp.full((Q, PB), -1, jnp.int32)
    if mode == "conjunctive":
        widf_s = jnp.zeros((Q, PB), jnp.float32)
    else:
        ft = jnp.maximum(image.term_ft[flat], 1).astype(jnp.float32)
        if mode == "bm25":
            widf = jnp.log1p((Ns - ft + 0.5) / (ft + 0.5))
        else:
            widf = jnp.log1p(Ns / ft)
        widf = (widf * qmask.reshape(-1)).reshape(Q, T)
        widf_s = jnp.where(valid, jnp.take_along_axis(widf, t_of, axis=1),
                           0.0)
    return (gat, start, end, seg, lastd0_s, dnum0_s, widf_s)


@partial(jax.jit, static_argnames=("mode", "k", "max_blocks", "flavor",
                                   "interpret", "tq"))
def fused_query(images, qterms, qmask, *, mode: str = "ranked_tfidf",
                k: int = 10, max_blocks: int | tuple = 64,
                doclens: jnp.ndarray | None = None,
                n_stat: jnp.ndarray | None = None,
                avg_stat: jnp.ndarray | None = None,
                alive: jnp.ndarray | None = None,
                flavor: str = "ref", interpret: bool = True,
                tq: int = DEFAULT_TQ):
    """One fused launch answering ``qterms``/``qmask`` against ``images``.

    Args:
      images: tuple of :class:`DeviceIndex`/:class:`DeltaIndex` sharing one
        docid capacity (``num_docs``) and vocab padding — the engine's
        resident (frozen, delta) pair.
      qterms: (Q, T) i32 padded term ids; qmask: (Q, T) bool.
      mode: one of :data:`FUSED_MODES`.
      max_blocks: per-image PACKED block capacity (slots per query, not
        per term) — a tuple aligned with ``images`` (an int is broadcast
        to every image); must cover the batch's largest per-query total
        block count in that image.
      doclens: (cap+1,) f32 document lengths (bm25 only).
      n_stat / avg_stat: dynamic collection statistics (fleet-exact idf /
        avgdl); default to the image capacity / local doclens mean.
      alive: optional (ceil((cap+1)/32),) uint32 packed little-endian
        liveness bitmask (bit ``d`` clear at tombstoned docids and index
        0) — None skips masking entirely, keeping the no-delete path
        byte-identical to its pre-deletion compilation.
      flavor: "pallas" (the kernel) or "ref" (same math inline).

    Returns ``matches (Q, cap+1) bool`` for conjunctive, else
    ``(top_d (Q, kk) i32, top_s (Q, kk) f32)`` in canonical order
    (descending score, ties by ascending docid).
    """
    if mode not in FUSED_MODES:
        raise ValueError(f"unsupported fused mode {mode!r}")
    head = images[0]
    cap = head.num_docs
    F = head.F
    if isinstance(max_blocks, int):
        max_blocks = (max_blocks,) * len(images)
    Ns = (jnp.float32(cap) if n_stat is None
          else n_stat.astype(jnp.float32))
    parts = tuple(_prep_image(img, qterms, qmask, Ns, mb, mode)
                  for img, mb in zip(images, max_blocks))
    nterms = qmask.sum(axis=1).astype(jnp.int32)
    if mode == "bm25":
        avgdl = (jnp.maximum(doclens[1:].sum() / Ns, 1e-9)
                 if avg_stat is None
                 else jnp.maximum(avg_stat.astype(jnp.float32), 1e-9))
        norm = jnp.stack([jnp.float32(BM25_K1 * (1.0 - BM25_B)),
                          BM25_K1 * BM25_B / avgdl])
        dl = doclens.astype(jnp.float32)
    else:
        norm = jnp.zeros(2, jnp.float32)
        dl = jnp.zeros(1, jnp.float32)
    alive_f = None if alive is None else alive.astype(jnp.uint32)
    if flavor == "pallas":
        return fused_query_kernel(parts, nterms, dl, norm, mode=mode, k=k,
                                  F=F, cap=cap, tq=tq, interpret=interpret,
                                  alive=alive_f)
    return fused_tile(parts, nterms, dl, norm, mode=mode, k=k, F=F, cap=cap,
                      alive=alive_f)


from .. import registry  # noqa: E402

registry.register(registry.KernelSpec(
    name="fused_query", fn=fused_query, modes=FUSED_MODES,
    description="single-launch decode→score→top-k over resident "
                "frozen+delta images, query-major grid",
    extras={"fused_modes": FUSED_MODES}))
