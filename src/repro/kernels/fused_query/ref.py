"""Shared tile math for the fused decode→score→top-k query kernel.

One function — :func:`fused_tile` — implements the whole per-query pipeline
(Double-VByte decode, docid reconstruction across frozen+delta chain rows,
weight accumulation, top-k / conjunctive matching) as straight-line jnp over
fixed shapes.  Both flavours of the public op execute EXACTLY this function:

* the reference flavour calls it once over the full query batch;
* the Pallas flavour calls it inside a ``pallas_call`` body, one grid step
  per ``tq`` queries (kernel.py).

Because the arithmetic is identical (same ops, same shapes up to the leading
query-tile dimension, reductions only along per-query axes), the two
flavours produce byte-identical results — the differential tests assert
exact float equality, not tolerances.

Decode here is *scan-free*: the escape-pairing automaton of Algorithm 2
(``c_{i+1} = escape_i & ~c_i``) has the closed form

    consumed(i)  ⇔  the run of consecutive raw-escape values immediately
                    before value i has odd length,

because a raw non-escape value (``value % F != 0``) always resets the
automaton and a run of raw escapes alternates primary/consumed.  The run
length is ``(rank_i - 1) - rank_of_last_non_escape_before_i``, both
computable with one cumsum and one cummax over byte positions — no
``lax.scan``/``fori_loop``, so the whole decode is a handful of log-step
vector ops (exactly what the VPU wants).  All shifts are ``pad``+``slice``
(measured ~3× cheaper than the roll/iota/where idiom on XLA:CPU — the roll
materializes a wrapped copy plus a mask per level; the pad shifts in the
fill value directly).

The tile consumes a tuple of per-image *parts* — (frozen, delta), each with
its own *packed* block pool: instead of a (T, MB) grid padded to the
longest chain in the vocabulary (which decodes mostly empty slots — a
per-term cap wastes ~4–8× at bench scale), prep packs each query's actual
chain blocks term-major into PB = pow2(Σ_t nblk_t) slots, each slot
carrying its term's segment id, docid-chaining bases and idf weight.
Chaining then runs as *segmented* log-step scans along the slot axis
(contiguous segments make plain Hillis–Steele with a same-segment guard
exact).  Row bases ``lastd0``/``dnum0`` are (0, -1) for frozen segments
(the -1 sentinel means "use the head block's first gap", reducing to the
absolute cumsum of leading b-gaps) and the delta's captured tail state for
delta segments (first value = d-gap from ``lastd0``, later blocks chain
b-gaps from ``dnum0`` — see ``core.device_index.DeltaIndex``).

Aggregation is a *dense scatter over the docid capacity*: every decoded
posting adds its weight (or hit count) into a (TQ, cap+1) accumulator, and
top-k runs over that axis — docids are the top-k indices themselves, and
equal scores tie-break toward the smaller index, which IS the canonical
(score desc, docid asc) order.  Frozen and delta docid spaces are disjoint,
so accumulating both parts into one array is exact.  This replaces an
earlier argsort + segmented-scan sparse path: cap+1 is far smaller than the
padded posting count R·MB·B, and a scatter-add is linear where the sort is
O(P log P) — measured ~5× cheaper end-to-end on CPU at bench scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BM25_K1 = 0.9
BM25_B = 0.4


def _shift_right(x: jnp.ndarray, shift: int, axis: int,
                 fill) -> jnp.ndarray:
    """Shift ``x`` right along ``axis``, filling the head with ``fill``
    (pad+slice: one fused op per level, no wrapped copy, no mask)."""
    n = x.shape[axis]
    cfg = [(0, 0, 0)] * x.ndim
    cfg[axis] = (shift, 0, 0)
    return jax.lax.pad(jax.lax.slice_in_dim(x, 0, n - shift, axis=axis),
                       jnp.asarray(fill, x.dtype), cfg)


def _cummax(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Unrolled Hillis–Steele inclusive running maximum along ``axis``."""
    n = x.shape[axis]
    lo = jnp.iinfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.integer) \
        else -jnp.inf
    shift = 1
    while shift < n:
        x = jnp.maximum(x, _shift_right(x, shift, axis, lo))
        shift *= 2
    return x


def _cumsum(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Unrolled Hillis–Steele inclusive prefix sum along ``axis``."""
    n = x.shape[axis]
    shift = 1
    while shift < n:
        x = x + _shift_right(x, shift, axis, 0)
        shift *= 2
    return x


def _seg_cumsum(x: jnp.ndarray, seg: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Segmented inclusive prefix sum along ``axis``: resets wherever the
    segment id changes.  Exact for CONTIGUOUS segments: after the level-s
    step, position i holds the sum of its last s same-segment predecessors,
    and the same-segment guard keeps windows disjoint across levels."""
    n = x.shape[axis]
    shift = 1
    while shift < n:
        same = seg == _shift_right(seg, shift, axis, -1)
        x = x + jnp.where(same, _shift_right(x, shift, axis, 0), 0)
        shift *= 2
    return x


def _seg_cummax(x: jnp.ndarray, seg: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Segmented inclusive running maximum along ``axis`` (same guard)."""
    n = x.shape[axis]
    lo = jnp.iinfo(x.dtype).min
    shift = 1
    while shift < n:
        same = seg == _shift_right(seg, shift, axis, -1)
        x = jnp.maximum(x, jnp.where(same, _shift_right(x, shift, axis, lo),
                                     lo))
        shift *= 2
    return x


def _hold_last_right(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Nearest non-zero at-or-right of each position (log-step hold-last)."""
    n = x.shape[axis]
    rev = jnp.flip(x, axis=axis)
    shift = 1
    while shift < n:
        rev = jnp.where(rev > 0, rev, _shift_right(rev, shift, axis, 0))
        shift *= 2
    return jnp.flip(rev, axis=axis)


def decode_blocks_parallel(blocks: jnp.ndarray, start: jnp.ndarray,
                           end: jnp.ndarray, F: int):
    """Scan-free Double-VByte block decode (same contract as
    ``core.device_index.decode_blocks``: (NB, B) blocks → (g, f, valid)).

    Steps 1–4 match the existing decoders (terminator flags, prev-terminator
    cummax, payload shift/cumsum); step 5 (escape pairing) uses the
    run-length-parity closed form instead of a sequential automaton.
    """
    b = blocks.astype(jnp.int32)
    NB, B = b.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (NB, B), 1)
    start = start.reshape(NB, 1)
    end = end.reshape(NB, 1)
    inside = (pos >= start) & (pos < end)
    term = ((b & 0x80) == 0) & inside
    prev_term = _cummax(jnp.where(term, pos, -1), axis=1)
    code_start = jnp.maximum(_shift_right(prev_term, 1, 1, -1) + 1, start)
    pos_in_code = jnp.clip(pos - code_start, 0, 4)
    payload = jnp.where(inside, (b & 0x7F) << (7 * pos_in_code), 0)
    csum = _cumsum(payload, axis=1)
    prev_csum = _cummax(
        jnp.where(term, csum, jnp.iinfo(jnp.int32).min), axis=1)
    prev_csum = jnp.maximum(_shift_right(prev_csum, 1, 1, 0), 0)
    value = jnp.where(term, csum - prev_csum, 0)
    is_value = term & (value > 0)
    mod = value % F
    # --- Algorithm 2 unfold, run-length-parity form -----------------------
    # rank of each value among the row's values (1-based, at value positions)
    rank = _cumsum(is_value.astype(jnp.int32), axis=1)
    non_esc = is_value & (mod != 0)
    # rank of the last raw NON-escape value strictly before this position
    last_ne = _cummax(jnp.where(non_esc, rank, 0), axis=1)
    last_ne = jnp.maximum(_shift_right(last_ne, 1, 1, 0), 0)
    # values (last_ne, rank-1] are all raw escapes; odd run ⇒ consumed
    consumed = is_value & (((rank - 1 - last_ne) & 1) == 1)
    primary = is_value & ~consumed
    g = jnp.where(primary, jnp.where(mod > 0, 1 + value // F, value // F), 0)
    f = jnp.where(primary & (mod > 0), mod, 0)
    # a consumed value holds F + v - 1, patched onto its primary (the
    # immediately preceding value): nearest consumed-value to the right
    fpatch = _hold_last_right(jnp.where(consumed, F + value - 1, 0), axis=1)
    f = jnp.where(primary & (f == 0), fpatch, f)
    return g, f, primary


def _part_postings(part, F: int):
    """Decode one packed image part into per-posting (docid, f, valid).

    ``part`` is (gat, start, end, seg, lastd0, dnum0, widf): gat (TQ, PB, B)
    packed chain blocks (term-major per query), seg (TQ, PB) the owning
    term's segment id (≥ T for empty pad slots), lastd0/dnum0/widf
    (TQ, PB) the owning term's chaining bases and idf weight per slot.
    """
    gat, start, end, seg, lastd0, dnum0, widf = part
    TQ, PB, B = gat.shape
    g, f, valid = decode_blocks_parallel(
        gat.reshape(TQ * PB, B), start.reshape(-1), end.reshape(-1), F)
    g = g.reshape(TQ, PB, B)
    f = f.reshape(TQ, PB, B)
    valid = valid.reshape(TQ, PB, B)
    # ---- docid reconstruction (uniform frozen/delta chaining) ------------
    gv = jnp.where(valid, g, 0)
    within = _cumsum(gv, axis=2)
    vcum = _cumsum(valid.astype(jnp.int32), axis=2)
    first_gap = jnp.max(jnp.where(vcum == 1, gv, 0), axis=2)   # (TQ, PB)
    # chain arithmetic per term segment: the head block's first docid is
    # lastd0 + its first gap; later blocks sit at dnum_eff + the running
    # sum of first gaps (head's excluded), dnum_eff resolving the frozen
    # -1 sentinel to the head block's own first gap
    is_head = seg != _shift_right(seg, 1, 1, -1)
    fg_head = jnp.maximum(_seg_cummax(
        jnp.where(is_head, first_gap, jnp.iinfo(jnp.int32).min), seg,
        axis=1), 0)
    s_cum = _seg_cumsum(first_gap, seg, axis=1)
    dnum_eff = jnp.where(dnum0 < 0, fg_head, dnum0)
    block_first = jnp.where(is_head, lastd0 + first_gap,
                            dnum_eff + (s_cum - fg_head))
    docid = block_first[:, :, None] + (within - first_gap[:, :, None])
    docid = jnp.where(valid, docid, 0)                 # (TQ, PB, B)
    return docid, f, valid, widf


def _scatter_add(acc: jnp.ndarray, docs: jnp.ndarray,
                 vals: jnp.ndarray) -> jnp.ndarray:
    """Per-query dense scatter-add into the (TQ, cap+1) accumulator."""
    return jax.vmap(lambda a, d, v: a.at[d].add(v))(acc, docs, vals)


def _unpack_alive(alive: jnp.ndarray, cap: int) -> jnp.ndarray:
    """(words,) uint32 little-endian liveness bitmask → (cap+1,) bool.

    Bit ``d`` of the mask (word ``d >> 5``, bit ``d & 31``) is document
    ``d``'s liveness.  Packed storage keeps the device-resident mask at
    1 bit/docid instead of the 32 bits/docid a dense f32 mask cost — the
    unpack is a gather + shift over an iota, fused into the surrounding
    program, so no dense mask ever lands in HBM."""
    idx = jnp.arange(cap + 1, dtype=jnp.int32)
    return ((alive[idx >> 5] >> (idx & 31).astype(jnp.uint32)) & 1) != 0


def fused_tile(parts, nterms, doclens, bm25_norm, *, mode: str, k: int,
               F: int, cap: int, alive=None):
    """Decode → docids → score → select for a tile of queries.

    Args:
      parts: per-image tuples (gat, start, end, seg, lastd0, dnum0, widf) —
        gat (TQ, PB_i, B) uint8 packed chain blocks (per-image packed
        capacity), start/end (TQ, PB_i) i32 payload byte bounds
        (end 0 = empty slot), seg (TQ, PB_i) i32 owning-term segment ids,
        lastd0/dnum0 (TQ, PB_i) i32 docid-chaining bases (dnum0 -1 ⇒
        frozen absolute chain), widf (TQ, PB_i) f32 idf weights
        (0 for pad slots).
      nterms: (TQ,) i32 — live terms per query (conjunctive only).
      doclens: (cap+1,) f32 — document lengths (bm25 only, else shape (1,)).
      bm25_norm: (2,) f32 — (k1*(1-b), k1*b/avgdl) (bm25 only).
      mode: "conjunctive" | "ranked_tfidf" | "bm25".
      k, F, cap: static top-k size, fold threshold, docid capacity.
      alive: optional (ceil((cap+1)/32),) uint32 packed little-endian
        liveness bitmask (bit ``d`` clear at tombstoned docids and at
        index 0) — dead documents' postings still decode (they live in
        the uploaded images until the next freeze compacts them away) but
        are masked out of the accumulator before selection, so the fused
        path matches the host path under deletes.

    Returns ``matches (TQ, cap+1) bool`` for conjunctive, else
    ``(top_d (TQ, kk) i32, top_s (TQ, kk) f32)`` with kk = min(k, cap+1),
    descending score, ties broken by ascending docid (canonical order).
    """
    TQ = parts[0][0].shape[0]
    if mode == "conjunctive":
        hits = jnp.zeros((TQ, cap + 1), jnp.int32)
        for part in parts:
            docid, _f, valid, _w = _part_postings(part, F)
            hits = _scatter_add(hits, docid.reshape(TQ, -1),
                                valid.reshape(TQ, -1).astype(jnp.int32))
        matches = (hits == nterms[:, None]) & (nterms[:, None] > 0)
        if alive is not None:
            matches = matches & _unpack_alive(alive, cap)[None, :]
        return matches.at[:, 0].set(False)
    score = jnp.zeros((TQ, cap + 1), jnp.float32)
    for part in parts:
        docid, f, valid, widf = _part_postings(part, F)
        fv = jnp.where(valid, f, 0).astype(jnp.float32)
        if mode == "bm25":
            dl = doclens[docid]                        # (TQ, PB, B)
            tf = (fv * (BM25_K1 + 1.0)) / (
                fv + bm25_norm[0] + bm25_norm[1] * dl)
            w = tf * widf[:, :, None]
        else:
            w = jnp.log1p(fv) * widf[:, :, None]
        w = jnp.where(valid, w, 0.0)
        score = _scatter_add(score, docid.reshape(TQ, -1),
                             w.reshape(TQ, -1))
    if alive is not None:
        # mask by select, not multiply: a fully-deleted term's padded idf
        # could be ±inf, and inf * 0 would poison the accumulator with nan
        score = jnp.where(_unpack_alive(alive, cap)[None, :], score, 0.0)
    # docids are the accumulator indices: top_k ties prefer the smaller
    # index, i.e. the smaller docid — canonical order for free.  Absent
    # docids hold exactly 0.0 and every real match scores > 0 (idf > 0),
    # so the caller's s > 0 filter drops them.
    top_s, top_d = jax.lax.top_k(score, min(k, cap + 1))
    return top_d.astype(jnp.int32), top_s
