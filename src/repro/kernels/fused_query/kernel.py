"""Pallas kernel: fused decode→score→top-k over a query-major grid.

One ``pallas_call`` serves a whole query batch: the grid walks the batch
``tq`` queries per step, and each step runs the complete pipeline of
:func:`..fused_query.ref.fused_tile` — chain-block decode, docid
reconstruction, dense weight accumulation over the docid capacity, and
top-k selection (or conjunctive bitmap matching) — without materializing
any intermediate back to HBM.  This replaces the previous four-op chain
(``dvbyte_decode`` → ``intersect``/``retrieval_dot`` → ``topk_score``),
whose per-op round trips dominated the device path's latency.

The kernel body *is* the reference implementation: it loads the tile's
refs and calls ``ref.fused_tile`` verbatim, so the Pallas flavour is
byte-identical to the reference flavour by construction (asserted by the
differential tests).  Everything inside is log-step vector ops plus one
per-query scatter-add — no scans, no dynamic shapes — which maps onto the
VPU and, in interpret mode, onto XLA:CPU's vector units.

Each resident image arrives as its own *part* (seven arrays, flattened
into the positional ref list) so the frozen and delta tiles keep their own
packed block capacities — the grid still tiles all of them by the same
``tq`` query rows per step.  ``doclens`` (a full docid-capacity lookup
table) and the two BM25 normalization scalars are broadcast to every step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import fused_tile

DEFAULT_TQ = 8  # queries per grid step


def _tile_kernel(*refs, n_parts: int, mode: str, k: int, F: int, cap: int,
                 has_alive: bool = False):
    n_in = 7 * n_parts + 3 + (1 if has_alive else 0)
    ins, outs = refs[:n_in], refs[n_in:]
    parts = tuple(tuple(r[...] for r in ins[7 * i:7 * i + 7])
                  for i in range(n_parts))
    tail = [r[...] for r in ins[7 * n_parts:]]
    nterms, doclens, norm = tail[0], tail[1], tail[2]
    alive = tail[3] if has_alive else None
    out = fused_tile(parts, nterms, doclens, norm,
                     mode=mode, k=k, F=F, cap=cap, alive=alive)
    if mode == "conjunctive":
        outs[0][...] = out
    else:
        outs[0][...], outs[1][...] = out


def _pad_q(a: jnp.ndarray, pad: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))


def fused_query_kernel(parts, nterms, doclens, bm25_norm, *, mode: str,
                       k: int, F: int, cap: int, tq: int = DEFAULT_TQ,
                       interpret: bool = True, alive=None):
    """Launch the fused kernel over per-image packed part tuples.

    ``parts`` is a tuple of (gat, start, end, seg, lastd0, dnum0, widf)
    per image, each gat shaped (Q, PB_i, B) with its own packed block
    capacity.  Q is padded up to a multiple of ``tq`` (padded rows have
    ``end == 0`` everywhere, so they decode to nothing).  ``alive`` is the
    optional (cap+1,) liveness mask, broadcast to every grid step like the
    doclens table.  Returns what :func:`ref.fused_tile` returns, sliced
    back to Q rows.
    """
    Q = parts[0][0].shape[0]
    tq = min(tq, Q)
    pad = (tq - Q % tq) % tq
    if pad:
        parts = tuple(tuple(_pad_q(a, pad) for a in part) for part in parts)
        nterms = _pad_q(nterms, pad)
    Qp = Q + pad
    grid = (Qp // tq,)
    in_specs = []
    for part in parts:
        _, PB, B = part[0].shape
        in_specs += [pl.BlockSpec((tq, PB, B), lambda i: (i, 0, 0))]
        in_specs += [pl.BlockSpec((tq, PB), lambda i: (i, 0))] * 6
    DL = doclens.shape[0]
    in_specs += [
        pl.BlockSpec((tq,), lambda i: (i,)),
        pl.BlockSpec((DL,), lambda i: (0,)),      # broadcast lookup table
        pl.BlockSpec((2,), lambda i: (0,)),       # broadcast bm25 norms
    ]
    args = tuple(a for part in parts for a in part) + (nterms, doclens,
                                                       bm25_norm)
    if alive is not None:
        in_specs += [pl.BlockSpec((alive.shape[0],), lambda i: (0,))]
        args = args + (alive,)                    # broadcast liveness mask
    kern = functools.partial(_tile_kernel, n_parts=len(parts), mode=mode,
                             k=k, F=F, cap=cap, has_alive=alive is not None)
    if mode == "conjunctive":
        matches = pl.pallas_call(
            kern, grid=grid, in_specs=in_specs,
            out_specs=pl.BlockSpec((tq, cap + 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Qp, cap + 1), jnp.bool_),
            interpret=interpret,
        )(*args)
        return matches[:Q]
    kk = min(k, cap + 1)
    top_d, top_s = pl.pallas_call(
        kern, grid=grid, in_specs=in_specs,
        out_specs=[pl.BlockSpec((tq, kk), lambda i: (i, 0)),
                   pl.BlockSpec((tq, kk), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Qp, kk), jnp.int32),
                   jax.ShapeDtypeStruct((Qp, kk), jnp.float32)],
        interpret=interpret,
    )(*args)
    return top_d[:Q], top_s[:Q]
