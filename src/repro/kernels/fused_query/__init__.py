"""Fused decode→score→top-k query kernel (see ops.py)."""

from .ops import FUSED_MODES, fused_query  # noqa: F401
