"""Jitted public entry point for the intersection kernel."""

from __future__ import annotations

from functools import partial

import jax

from .. import registry
from .kernel import DEFAULT_TILE, intersect_kernel


@partial(jax.jit, static_argnames=("tile_a", "tile_b", "interpret"))
def intersect_sorted(a, b, tile_a: int = DEFAULT_TILE,
                     tile_b: int = DEFAULT_TILE, interpret: bool = True):
    """Membership flags of sorted int32 list ``a`` in sorted list ``b``."""
    return intersect_kernel(a, b, tile_a=tile_a, tile_b=tile_b,
                            interpret=interpret)


registry.register(registry.KernelSpec(
    name="intersect", fn=intersect_sorted, modes=("conjunctive",),
    description="tiled sorted-list membership with range-disjoint tile skip "
                "(the seek_GEQ block bypass on TPU)",
    extras={"pad": int(jax.numpy.iinfo(jax.numpy.int32).max)}))
