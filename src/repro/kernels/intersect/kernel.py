"""Pallas TPU kernel: sorted-list membership (conjunctive AND core).

TPU adaptation of the paper's ``seek_GEQ`` conjunctive evaluation (§3.6):
instead of a pointer-chasing cursor, both docid lists are tiled, and the
(a-tile × b-tile) grid skips any pair whose docid ranges are disjoint — the
direct analogue of "touching only the b-gap and n_ptr during the scan":
a skipped tile is a block whose postings are never decoded or compared.

For overlapping tile pairs the membership test is a dense broadcast compare
(VPU), i.e. the same work a SIMD galloping intersection does per segment.

Inputs are int32 docid vectors sorted ascending, padded with INT_MAX.
Output: for every element of ``a``, whether it occurs in ``b``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD = jnp.iinfo(jnp.int32).max
DEFAULT_TILE = 512


def _intersect_tile(a_ref, b_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (TA,)
    b = b_ref[...]  # (TB,)
    # range-disjointness skip (the seek_GEQ block bypass): tiles are sorted,
    # so if max(a) < min(b) or min(a) > max(b) nothing can match.
    overlap = (a[-1] >= b[0]) & (a[0] <= b[-1]) & (a[0] != PAD)

    @pl.when(overlap)
    def _work():
        hit = (a[:, None] == b[None, :]).any(axis=1)
        o_ref[...] = o_ref[...] | hit


def intersect_kernel(a: jnp.ndarray, b: jnp.ndarray,
                     tile_a: int = DEFAULT_TILE, tile_b: int = DEFAULT_TILE,
                     interpret: bool = True) -> jnp.ndarray:
    """flags[i] = a[i] ∈ b, for sorted, PAD-padded int32 vectors."""
    na, nb = a.shape[0], b.shape[0]
    pa = (-na) % tile_a
    pb = (-nb) % tile_b
    a = jnp.pad(a, (0, pa), constant_values=PAD)
    b = jnp.pad(b, (0, pb), constant_values=PAD)
    grid = (a.shape[0] // tile_a, b.shape[0] // tile_b)
    out = pl.pallas_call(
        _intersect_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_a,), lambda i, j: (i,)),
            pl.BlockSpec((tile_b,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_a,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0],), jnp.bool_),
        interpret=interpret,
    )(a, b)
    return out[:na]
