from .ops import intersect_sorted  # noqa: F401
