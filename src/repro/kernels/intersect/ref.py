"""Pure-jnp oracle for the sorted-intersection kernel."""

from __future__ import annotations

import jax.numpy as jnp

PAD = jnp.iinfo(jnp.int32).max


def intersect_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """flags[i] = a[i] ∈ b via searchsorted (sorted b, PAD-padded)."""
    idx = jnp.searchsorted(b, a)
    idx = jnp.clip(idx, 0, b.shape[0] - 1)
    return (b[idx] == a) & (a != PAD)
