"""Pure-jnp oracle for the score-accumulation kernel."""

from __future__ import annotations

import jax.numpy as jnp


def score_ref(docids: jnp.ndarray, weights: jnp.ndarray,
              n_docs: int) -> jnp.ndarray:
    out = jnp.zeros(n_docs, jnp.float32).at[docids].add(weights)
    return out.at[0].set(0.0)  # docid 0 is the padding bucket
