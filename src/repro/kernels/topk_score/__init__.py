from .ops import score_accumulate  # noqa: F401
