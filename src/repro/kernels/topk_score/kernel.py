"""Pallas TPU kernel: postings score accumulation (TF×IDF scatter).

The disjunctive top-k path (paper §4.6) reduces to: given M decoded postings
(docid, weight), build the dense score vector over the docid space, then
top-k.  A CPU implementation scatter-adds through the heap; scatter is the
wrong shape for a systolic TPU, so we reformulate accumulation as a masked
matmul — for each docid-space tile T: scores[T] = w · (docids == iota(T)),
an (1×M_tile)·(M_tile×N_tile) MXU contraction per grid cell.  Postings whose
docid range misses the tile are skipped (same block-skip idea as intersect).

This trades FLOPs for perfect memory coalescing — the classic TPU bargain —
and is exactly how one-hot embedding updates are lowered on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_M = 1024
DEFAULT_TILE_N = 1024


def _score_tile(d_ref, w_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    d = d_ref[...]          # (TM,) int32 docids (0 = padding)
    w = w_ref[...]          # (TM,) f32 weights
    n0 = i * o_ref.shape[0]
    # skip when this posting tile cannot touch this docid tile
    lo = n0
    hi = n0 + o_ref.shape[0]
    overlap = (jnp.max(d) >= lo) & (jnp.min(jnp.where(d > 0, d, 2**30)) < hi)

    @pl.when(overlap)
    def _work():
        n_iota = n0 + jax.lax.broadcasted_iota(jnp.int32, (o_ref.shape[0],), 0)
        onehot = (d[:, None] == n_iota[None, :]).astype(jnp.float32)
        o_ref[...] += w @ onehot  # (TM,) @ (TM, TN) -> (TN,)


def score_kernel(docids: jnp.ndarray, weights: jnp.ndarray, n_docs: int,
                 tile_m: int = DEFAULT_TILE_M, tile_n: int = DEFAULT_TILE_N,
                 interpret: bool = True) -> jnp.ndarray:
    """Dense scores over docid space [0, n_docs): scatter-add of weights."""
    M = docids.shape[0]
    pm = (-M) % tile_m
    docids = jnp.pad(docids, (0, pm))           # pad docid 0 = ignored
    weights = jnp.pad(weights, (0, pm))
    Np = n_docs + ((-n_docs) % tile_n)
    grid = (Np // tile_n, docids.shape[0] // tile_m)
    out = pl.pallas_call(
        _score_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m,), lambda i, j: (j,)),
            pl.BlockSpec((tile_m,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), jnp.float32),
        interpret=interpret,
    )(docids, weights)
    # docid 0 is the padding bucket: zero it before use
    out = out.at[0].set(0.0)
    return out[:n_docs]
