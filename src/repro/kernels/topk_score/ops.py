"""Jitted public entry point for the score-accumulation kernel."""

from __future__ import annotations

from functools import partial

import jax

from .. import registry
from .kernel import DEFAULT_TILE_M, DEFAULT_TILE_N, score_kernel


@partial(jax.jit, static_argnames=("n_docs", "tile_m", "tile_n", "interpret"))
def score_accumulate(docids, weights, n_docs: int,
                     tile_m: int = DEFAULT_TILE_M,
                     tile_n: int = DEFAULT_TILE_N, interpret: bool = True):
    """Dense TF×IDF score vector from decoded postings (docid 0 = padding)."""
    return score_kernel(docids, weights, n_docs, tile_m=tile_m,
                        tile_n=tile_n, interpret=interpret)


registry.register(registry.KernelSpec(
    name="topk_score", fn=score_accumulate,
    modes=("ranked_tfidf", "bm25"),
    description="masked-matmul scatter-add of posting weights into the dense "
                "docid score vector (MXU-shaped accumulation)"))
