from .ops import dvbyte_decode_blocks  # noqa: F401
