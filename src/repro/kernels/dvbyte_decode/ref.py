"""Pure-jnp oracle for the Double-VByte block-decode kernel.

This simply re-exports the device engine's reference implementation
(repro.core.device_index.decode_blocks): the kernel must produce bit-identical
(g, f, valid) triples for any block content the block store can emit.
"""

from repro.core.device_index import decode_blocks as decode_blocks_ref

__all__ = ["decode_blocks_ref"]
