"""Pallas TPU kernel: parallel Double-VByte block decode.

TPU adaptation of the paper's byte-sequential decoder (§2.2/§3.4): a VMEM
tile of TB blocks × B bytes is decoded entirely in parallel on the VPU.

Per 8-bit lane:                               per-tile cost
  1. terminator flag       t = (b & 0x80)==0         1 cmp
  2. code starts           prev-terminator cummax    log2(B) shifted maxima
  3. payload shift         (b&0x7F) << 7*(pos-start) 1 shift
  4. value at terminator   cumsum difference         log2(B) shifted adds
  5. Algorithm 2 unfold    escape-pairing automaton  fori_loop over B lanes
                           (vectorized across the TB block rows)

Step 5 is the only sequential part and runs once per byte *position*, not per
byte — all blocks in the tile advance together, so the loop body is a fully
dense (TB,)-wide vector op.  This mirrors how SIMD varint decoders (e.g.
stream-vbyte) hoist the data-dependent control flow into masks.

The cummax/cumsum are implemented as unrolled log-step Hillis–Steele scans
(B is a compile-time constant, typically 64) because they vectorize on the
VPU without needing lax.associative_scan inside the kernel.

Block geometry (start = first payload byte, end = one-past-last) arrives as
two i32 vectors; everything outside [start, end) is masked, and the null
sentinel (§2.2) masks unused tail bytes automatically because a decoded
value of 0 cannot otherwise occur.

Outputs mirror the pure-jnp oracle ``ref.decode_blocks_ref``: (g, f, valid)
of shape (NB, B) — one potential posting per byte position.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256  # blocks per grid step: 256*64 B in + 3*256*64*4 B out


def _cummax(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Unrolled Hillis–Steele inclusive running maximum along ``axis``."""
    n = x.shape[axis]
    shift = 1
    while shift < n:
        shifted = jnp.roll(x, shift, axis=axis)
        # zero out the wrapped-around prefix
        idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
        shifted = jnp.where(idx >= shift, shifted, jnp.iinfo(jnp.int32).min)
        x = jnp.maximum(x, shifted)
        shift *= 2
    return x


def _cumsum(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Unrolled Hillis–Steele inclusive prefix sum along ``axis``."""
    n = x.shape[axis]
    shift = 1
    while shift < n:
        shifted = jnp.roll(x, shift, axis=axis)
        idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
        shifted = jnp.where(idx >= shift, shifted, 0)
        x = x + shifted
        shift *= 2
    return x


def _decode_tile(b_ref, start_ref, end_ref, g_ref, f_ref, v_ref, *, F: int):
    b = b_ref[...].astype(jnp.int32)           # (TB, B)
    TB, B = b.shape
    start = start_ref[...].reshape(TB, 1)
    end = end_ref[...].reshape(TB, 1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (TB, B), 1)
    inside = (pos >= start) & (pos < end)
    term = ((b & 0x80) == 0) & inside
    # code start = previous terminator + 1 (clamped to the payload start)
    prev_term = _cummax(jnp.where(term, pos, -1), axis=1)
    code_start = jnp.maximum(
        jnp.where(pos > 0,
                  jnp.roll(prev_term, 1, axis=1), -1) + 1, start)
    pos_in_code = jnp.clip(pos - code_start, 0, 4)
    payload = jnp.where(inside, (b & 0x7F) << (7 * pos_in_code), 0)
    csum = _cumsum(payload, axis=1)
    # csum at (code_start - 1), via gather-free trick: since code_start-1 is
    # the previous terminator position, propagate csum-at-terminator forward.
    prev_csum = _cummax(  # runs of zeros take the last terminator's csum
        jnp.where(term, csum, jnp.iinfo(jnp.int32).min), axis=1)
    prev_csum = jnp.where(pos > 0, jnp.roll(prev_csum, 1, axis=1), 0)
    prev_csum = jnp.maximum(prev_csum, 0)  # head of row: nothing before
    value = jnp.where(term, csum - prev_csum, 0)
    is_value = term & (value > 0)
    mod = value % F

    # --- Algorithm 2 escape-pairing automaton over byte positions ---------
    # Pass 1 marks primaries/consumed columns; pass 2 (below, gather-free)
    # propagates each consumed escape value leftward onto its primary.
    prev_esc = jnp.zeros((TB,), jnp.bool_)
    g = jnp.zeros((TB, B), jnp.int32)
    f = jnp.zeros((TB, B), jnp.int32)
    prim = jnp.zeros((TB, B), jnp.bool_)
    cons = jnp.zeros((TB, B), jnp.bool_)

    def body2(i, carry):
        prev_esc, g, f, prim, cons = carry
        isv = is_value[:, i]
        v = value[:, i]
        m = mod[:, i]
        consumed = isv & prev_esc
        primary = isv & ~consumed
        esc_now = primary & (m == 0)
        gi = jnp.where(m > 0, 1 + v // F, v // F)
        fi = jnp.where(m > 0, m, 0)
        g = g.at[:, i].set(jnp.where(primary, gi, 0))
        f = f.at[:, i].set(jnp.where(primary, fi, 0))
        prim = prim.at[:, i].set(primary)
        cons = cons.at[:, i].set(consumed)
        return (jnp.where(isv, esc_now, prev_esc), g, f, prim, cons)

    _, g, f, prim, cons = jax.lax.fori_loop(
        0, B, body2, (prev_esc, g, f, prim, cons))
    # leftward propagation of each consumed value to its escape primary:
    # fpatch candidates live at consumed positions; reverse-cummax by column
    # index propagates the *nearest following* consumed value to the primary.
    fval = jnp.where(cons, F + value - 1, 0)
    # reverse scan: nearest non-zero to the right, log-step "hold last"
    rev = jnp.flip(fval, axis=1)
    run = rev
    shift = 1
    while shift < B:
        shifted = jnp.roll(run, shift, axis=1)
        idx = jax.lax.broadcasted_iota(jnp.int32, run.shape, 1)
        shifted = jnp.where(idx >= shift, shifted, 0)
        run = jnp.where(run > 0, run, shifted)
        shift *= 2
    nxt = jnp.flip(run, axis=1)
    f = jnp.where(prim & (f == 0), nxt, f)
    g_ref[...] = g
    f_ref[...] = f
    v_ref[...] = prim


def dvbyte_decode_kernel(blocks: jnp.ndarray, start: jnp.ndarray,
                         end: jnp.ndarray, F: int,
                         tile: int = DEFAULT_TILE,
                         interpret: bool = True):
    """pallas_call wrapper: decode (NB, B) blocks, tiled TB rows at a time."""
    NB, B = blocks.shape
    if NB % tile != 0:
        pad = tile - NB % tile
        blocks = jnp.pad(blocks, ((0, pad), (0, 0)))
        start = jnp.pad(start, (0, pad))
        end = jnp.pad(end, (0, pad))
    NBp = blocks.shape[0]
    grid = (NBp // tile,)
    kern = functools.partial(_decode_tile, F=F)
    g, f, v = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, B), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile, B), lambda i: (i, 0)),
            pl.BlockSpec((tile, B), lambda i: (i, 0)),
            pl.BlockSpec((tile, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((NBp, B), jnp.int32),
            jax.ShapeDtypeStruct((NBp, B), jnp.int32),
            jax.ShapeDtypeStruct((NBp, B), jnp.bool_),
        ],
        interpret=interpret,
    )(blocks, start, end)
    return g[:NB], f[:NB], v[:NB]
