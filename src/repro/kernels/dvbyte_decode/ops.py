"""Jitted public entry point for the Double-VByte decode kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import DEFAULT_TILE, dvbyte_decode_kernel


@partial(jax.jit, static_argnames=("F", "tile", "interpret"))
def dvbyte_decode_blocks(blocks, start, end, F: int = 4,
                         tile: int = DEFAULT_TILE, interpret: bool = True):
    """Decode a batch of B-byte Double-VByte blocks on TPU.

    Drop-in replacement for ``repro.core.device_index.decode_blocks`` (pass
    it as ``decode_fn`` to ``query_step``).  ``interpret=True`` executes the
    kernel body in Python on CPU; on a real TPU pass ``interpret=False``.
    """
    return dvbyte_decode_kernel(blocks, start, end, F, tile=tile,
                                interpret=interpret)


def as_decode_fn(F: int = 4, tile: int = DEFAULT_TILE,
                 interpret: bool = True):
    """Adapter matching the ``decode_fn(blocks, start, end, F)`` signature."""

    def fn(blocks, start, end, F_):
        return dvbyte_decode_kernel(blocks, start, end, F_, tile=tile,
                                    interpret=interpret)

    return fn


from .. import registry  # noqa: E402

registry.register(registry.KernelSpec(
    name="dvbyte_decode", fn=dvbyte_decode_blocks,
    modes=("conjunctive", "ranked_tfidf", "bm25"),
    description="VMEM-tiled Double-VByte block decode; plug into "
                "device_index.query_step via decode_fn",
    extras={"as_decode_fn": as_decode_fn}))
