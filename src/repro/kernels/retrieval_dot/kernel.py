"""Pallas TPU kernel: two-tower candidate scoring (retrieval_cand shape).

Scores a batch of query embeddings against a large candidate table:
``scores = Q @ C^T`` with Q (q, d) and C (n, d), n up to 10^6.  This is the
MXU-native realization of the recsys ``retrieval_cand`` cell — a straight
tiled matmul with f32 accumulation over the contraction dimension, VMEM
blocks sized to the 128-lane MXU.

Grid: (q_tiles, n_tiles, d_tiles); the d dimension accumulates in-place in
the output block (revisited across the innermost grid axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 512
TILE_D = 128


def _dot_tile(q_ref, c_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)   # (TQ, TD)
    c = c_ref[...].astype(jnp.float32)   # (TN, TD)
    o_ref[...] += jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def retrieval_dot_kernel(q: jnp.ndarray, cand: jnp.ndarray,
                         tile_q: int = TILE_Q, tile_n: int = TILE_N,
                         tile_d: int = TILE_D,
                         interpret: bool = True) -> jnp.ndarray:
    """scores (q, n) = q @ cand^T, tiled for VMEM/MXU."""
    Q, D = q.shape
    N, D2 = cand.shape
    assert D == D2
    pq, pn, pd = (-Q) % tile_q, (-N) % tile_n, (-D) % tile_d
    q = jnp.pad(q, ((0, pq), (0, pd)))
    cand = jnp.pad(cand, ((0, pn), (0, pd)))
    grid = (q.shape[0] // tile_q, cand.shape[0] // tile_n,
            q.shape[1] // tile_d)
    out = pl.pallas_call(
        _dot_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_d), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], cand.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(q, cand)
    return out[:Q, :N]
