"""Pure-jnp oracle for the retrieval-dot kernel."""

from __future__ import annotations

import jax.numpy as jnp


def retrieval_dot_ref(q: jnp.ndarray, cand: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("qd,nd->qn", q.astype(jnp.float32),
                      cand.astype(jnp.float32))
