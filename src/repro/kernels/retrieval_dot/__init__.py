from .ops import candidate_scores  # noqa: F401
