"""Jitted public entry point for the retrieval-dot kernel."""

from __future__ import annotations

from functools import partial

import jax

from .. import registry
from .kernel import TILE_D, TILE_N, TILE_Q, retrieval_dot_kernel


@partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_d", "interpret"))
def candidate_scores(q, cand, tile_q: int = TILE_Q, tile_n: int = TILE_N,
                     tile_d: int = TILE_D, interpret: bool = True):
    """Two-tower scores (q, n) = q @ cand^T (f32 accumulation)."""
    return retrieval_dot_kernel(q, cand, tile_q=tile_q, tile_n=tile_n,
                                tile_d=tile_d, interpret=interpret)


registry.register(registry.KernelSpec(
    name="retrieval_dot", fn=candidate_scores, modes=(),
    description="dense two-tower candidate scoring; outside the term-query "
                "path (hybrid reranking hook)"))
