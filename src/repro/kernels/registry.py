"""Kernel registry: uniform discovery of the Pallas ops for the query engine.

Every ``kernels/<name>/ops.py`` registers a :class:`KernelSpec` describing
its public entry point and which engine query modes it accelerates; the
engine's ``PallasBackend`` routes through :func:`get` instead of importing
kernel modules directly, so adding a kernel is a one-line registration and
backends discover capabilities (e.g. "which ops can serve 'conjunctive'?")
without hard-coding module paths.

Specs are registered at ops-module import; :func:`get` imports the module
lazily on first use so merely constructing an engine never pays kernel
import cost.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Callable

# kernel name -> module that registers it (lazy import target)
_OPS_MODULES = {
    "intersect": "repro.kernels.intersect.ops",
    "topk_score": "repro.kernels.topk_score.ops",
    "dvbyte_decode": "repro.kernels.dvbyte_decode.ops",
    "retrieval_dot": "repro.kernels.retrieval_dot.ops",
    "fused_query": "repro.kernels.fused_query.ops",
}

_REGISTRY: dict[str, "KernelSpec"] = {}


@dataclass(frozen=True)
class KernelSpec:
    """One registered kernel entry point.

    ``modes`` names the engine query modes the op accelerates (empty for ops
    outside the term-query path, e.g. dense two-tower scoring); ``interpret``
    notes whether the default entry point runs the Pallas body in interpret
    mode (CPU-safe) unless overridden.
    """

    name: str
    fn: Callable
    modes: tuple[str, ...] = ()
    description: str = ""
    extras: dict = field(default_factory=dict)


def register(spec: KernelSpec) -> KernelSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    """Spec for ``name``, importing its ops module on first use."""
    if name not in _REGISTRY:
        mod = _OPS_MODULES.get(name)
        if mod is None:
            raise KeyError(f"unknown kernel {name!r}; "
                           f"known: {sorted(_OPS_MODULES)}")
        importlib.import_module(mod)
    return _REGISTRY[name]


def supporting(mode: str) -> list[KernelSpec]:
    """All registered kernels accelerating engine query ``mode``."""
    for name in _OPS_MODULES:
        get(name)
    return [s for s in _REGISTRY.values() if mode in s.modes]


def default_interpret() -> bool:
    """True when Pallas bodies should run in interpret mode (no TPU)."""
    import jax
    return jax.default_backend() not in ("tpu",)
