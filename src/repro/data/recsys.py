"""Recsys data substrate: synthetic Criteo-like batches + table specs.

DLRM table sizes follow the MLPerf Criteo-1TB configuration (row counts
capped at 40M, 26 sparse fields); sampling is deterministic per step for
fault-tolerant replay, power-law over rows (real CTR id traffic is heavily
skewed, which is what makes the embedding lookup the hot path).
"""

from __future__ import annotations

import numpy as np

# MLPerf DLRM (Criteo 1TB, day-sharded) per-field row counts, 40M cap.
CRITEO_TABLE_ROWS = [
    40_000_000, 39_060, 17_295, 7_424, 20_265, 3, 7_122, 1_543, 63,
    40_000_000, 3_067_956, 405_282, 10, 2_209, 11_938, 155, 4, 976, 14,
    40_000_000, 40_000_000, 40_000_000, 590_152, 12_973, 108, 36,
]
N_DENSE = 13
N_SPARSE = 26


class RecsysBatches:
    """Deterministic synthetic (dense, sparse ids, label) batches."""

    def __init__(self, batch: int, table_rows=None, n_dense: int = N_DENSE,
                 seed: int = 0, hist_len: int = 0):
        self.batch = batch
        self.table_rows = list(table_rows or CRITEO_TABLE_ROWS)
        self.n_dense = n_dense
        self.seed = seed
        self.hist_len = hist_len

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed << 32) ^ step)
        dense = rng.lognormal(0.0, 1.0,
                              (self.batch, self.n_dense)).astype(np.float32)
        sparse = np.stack([
            (rng.zipf(1.2, self.batch).astype(np.int64) - 1) % rows
            for rows in self.table_rows], axis=1).astype(np.int32)
        label = (rng.random(self.batch) < 0.25).astype(np.float32)
        out = {"dense": dense, "sparse": sparse, "label": label}
        if self.hist_len:
            out["history"] = rng.integers(
                0, self.table_rows[0],
                (self.batch, self.hist_len)).astype(np.int32)
            out["hist_mask"] = (rng.random(
                (self.batch, self.hist_len)) < 0.8).astype(np.float32)
            out["target"] = rng.integers(
                0, self.table_rows[0], self.batch).astype(np.int32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
