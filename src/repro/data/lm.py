"""Token pipeline for LM training/serving drivers.

Deterministic synthetic token streams (seeded per step index) so that a
restarted worker regenerates exactly the batch it crashed on — the data-side
half of fault-tolerant training (see repro.train.trainer).  Real-corpus
ingestion reuses data.docstream + a hash vocabulary.
"""

from __future__ import annotations

import numpy as np


class TokenBatches:
    """Infinite deterministic (tokens, labels) batches keyed by step."""

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed << 32) ^ step)
        toks = rng.integers(0, self.vocab,
                            (self.batch, self.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def text_to_tokens(terms: list[str], vocab: int) -> np.ndarray:
    """Hash terms into a fixed id space (driver for docstream corpora)."""
    import zlib
    return np.asarray([zlib.crc32(t.encode()) % vocab for t in terms],
                      dtype=np.int32)
