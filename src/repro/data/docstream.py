"""Docstream format and tokenizer (paper §4.1).

"A docstream represents documents as single lines of text, with the first
element a document identifier, and the remainder ... an ordered set of terms."
Pre-processing faithfully mirrors the paper: sequences of non-alphabetic
characters become single spaces; uppercase folds to lowercase; long terms are
broken after each group of 20 consecutive alphabetic characters.  No
stemming, no stopping.
"""

from __future__ import annotations

import re
from typing import Iterable, Iterator

_NON_ALPHA = re.compile(r"[^a-zA-Z]+")
MAX_TERM = 20


def tokenize(text: str) -> list[str]:
    """Paper §4.1 pre-processing: alpha runs, lowercased, 20-char chunks."""
    out: list[str] = []
    for run in _NON_ALPHA.split(text):
        if not run:
            continue
        run = run.lower()
        for i in range(0, len(run), MAX_TERM):
            out.append(run[i:i + MAX_TERM])
    return out


def parse_docstream(lines: Iterable[str]) -> Iterator[tuple[str, list[str]]]:
    """Yield (doc_id, terms) from docstream lines."""
    for line in lines:
        parts = line.strip().split()
        if not parts:
            continue
        yield parts[0], parts[1:]


def to_docstream_line(doc_id: str, terms: list[str]) -> str:
    return " ".join([doc_id, *terms])
