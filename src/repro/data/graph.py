"""Graph substrate: CSR storage, synthetic graphs, and a real neighbor
sampler (the minibatch_lg cell requires fanout sampling, per the brief).

The sampler is host-side numpy over CSR (as in every production GNN system —
DGL/PyG do exactly this on CPU workers), emitting fixed-shape padded blocks
that the jitted model consumes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,) int64
    indices: np.ndarray  # (E,) int32 — neighbor ids
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])


def synthetic_power_law(n_nodes: int, avg_degree: int,
                        seed: int = 0) -> CSRGraph:
    """Preferential-attachment-flavoured random graph in CSR."""
    rng = np.random.default_rng(seed)
    m = n_nodes * avg_degree
    # power-law destination popularity
    pop = rng.zipf(1.5, size=m).astype(np.int64) % n_nodes
    src = rng.integers(0, n_nodes, m)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], pop[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32),
                    n_nodes=n_nodes)


@dataclass
class SampledBlock:
    """One layer of a sampled computation block (fixed/padded shapes)."""

    src: np.ndarray    # (E_pad,) int32 — positions into prev layer's nodes
    dst: np.ndarray    # (E_pad,) int32 — positions into this layer's seeds
    mask: np.ndarray   # (E_pad,) bool
    nodes: np.ndarray  # (N_pad,) int32 — global node ids of the layer input


def neighbor_sample(graph: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                    rng: np.random.Generator) -> list[SampledBlock]:
    """GraphSAGE-style layered fanout sampling.

    Returns one block per layer, outermost first; block L maps its sampled
    frontier (src) onto the previous frontier (dst).  Shapes are padded to
    len(seeds_at_layer) * fanout so downstream jit never re-traces.
    """
    blocks: list[SampledBlock] = []
    frontier = seeds.astype(np.int64)
    for fan in fanouts:
        n_seed = len(frontier)
        e_pad = n_seed * fan
        src_g = np.zeros(e_pad, np.int64)    # global sampled neighbor ids
        dst_l = np.repeat(np.arange(n_seed, dtype=np.int32), fan)
        mask = np.zeros(e_pad, bool)
        for i, v in enumerate(frontier):
            lo, hi = int(graph.indptr[v]), int(graph.indptr[v + 1])
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fan, deg)
            pick = rng.choice(deg, size=take, replace=deg < fan)
            src_g[i * fan: i * fan + take] = graph.indices[lo + pick]
            mask[i * fan: i * fan + take] = True
        # unique-ify the new frontier: frontier nodes first, then neighbors
        uniq, inv = np.unique(src_g[mask], return_inverse=True)
        layer_nodes = np.concatenate([frontier, uniq])
        src_l = np.zeros(e_pad, np.int32)
        src_l[mask] = (inv + n_seed).astype(np.int32)
        blocks.append(SampledBlock(src=src_l, dst=dst_l, mask=mask,
                                   nodes=layer_nodes.astype(np.int32)))
        frontier = layer_nodes.astype(np.int64)
    return blocks


def pad_block(block: SampledBlock, n_pad: int) -> SampledBlock:
    nodes = np.zeros(n_pad, np.int32)
    nodes[: len(block.nodes)] = block.nodes
    return SampledBlock(src=block.src, dst=block.dst, mask=block.mask,
                        nodes=nodes)


def edges_coo(graph: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    """CSR -> (src, dst) COO int32 arrays."""
    src = np.repeat(np.arange(graph.n_nodes, dtype=np.int32),
                    np.diff(graph.indptr).astype(np.int64))
    return src, graph.indices.astype(np.int32)
