"""Synthetic Zipfian docstream generator calibrated to paper Table 5.

WSJ1/Robust04/Wikipedia are not redistributable offline, so compression and
throughput experiments run on synthetic streams with matched statistics:

  * term frequencies Zipf(s≈1.07) over a large vocabulary universe — giving
    the paper's "very high fraction of low f values, many small g values,
    larger g accompanied by low f" joint distribution that Double-VByte
    exploits (§3.5);
  * document lengths log-normal with mean ≈ `words_per_doc` (WSJ1: 434);
  * vocabulary growth follows Heaps' law automatically (sampling without
    universe exhaustion).

Generation is vectorized numpy and streams documents, so gigabyte-scale
collections never materialize in memory at once.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass
class CorpusSpec:
    n_docs: int = 10_000
    words_per_doc: float = 434.5          # WSJ1 (Table 5)
    zipf_s: float = 1.07
    universe: int = 500_000               # vocabulary universe size
    seed: int = 0

    def scaled(self, n_docs: int) -> "CorpusSpec":
        return CorpusSpec(n_docs=n_docs, words_per_doc=self.words_per_doc,
                          zipf_s=self.zipf_s, universe=self.universe,
                          seed=self.seed)


WSJ1_LIKE = CorpusSpec(n_docs=98_732, words_per_doc=434.5)
ROBUST04_LIKE = CorpusSpec(n_docs=528_155, words_per_doc=527.3)
WIKIPEDIA_LIKE = CorpusSpec(n_docs=6_477_362, words_per_doc=377.4,
                            universe=5_000_000)


def _term_name(i: int) -> str:
    # compact deterministic term strings, ~7 chars average like English
    return np.base_repr(i + 31, 36).lower()


class SyntheticCorpus:
    """Streaming synthetic docstream."""

    def __init__(self, spec: CorpusSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        ranks = np.arange(1, spec.universe + 1, dtype=np.float64)
        p = ranks ** (-spec.zipf_s)
        self._probs = p / p.sum()
        # Document-length log-normal tuned so the mean matches the spec
        self._len_mu = np.log(spec.words_per_doc) - 0.125
        self._len_sigma = 0.5

    def doc_terms(self) -> Iterator[list[str]]:
        """Yield documents as term lists (term ids rendered to strings)."""
        for ids in self.doc_term_ids():
            yield [_term_name(int(i)) for i in ids]

    def doc_term_ids(self) -> Iterator[np.ndarray]:
        spec = self.spec
        batch = 256  # draw lengths in batches for speed
        emitted = 0
        while emitted < spec.n_docs:
            take = min(batch, spec.n_docs - emitted)
            lens = np.maximum(
                2, self.rng.lognormal(self._len_mu, self._len_sigma,
                                      take)).astype(np.int64)
            total = int(lens.sum())
            draws = self.rng.choice(spec.universe, size=total, p=self._probs)
            off = 0
            for L in lens:
                yield draws[off:off + int(L)]
                off += int(L)
            emitted += take

    def stats_estimate(self) -> dict:
        return {"n_docs": self.spec.n_docs,
                "words_per_doc": self.spec.words_per_doc,
                "universe": self.spec.universe}
