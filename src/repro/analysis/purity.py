"""Kernel purity lint for ``kernels/*/ref.py`` and ``kernels/*/kernel.py``.

A kernel body must be a pure trace: host synchronization or host-side
control flow on traced values either crashes under ``jit``/``pallas_call``
or — worse — silently bakes one traced value into the compiled program.
This lint rejects, inside ref/kernel modules:

* host syncs: ``jax.device_get`` / ``device_get``, ``.item()``,
  ``.block_until_ready()``, and ``float(x)`` / ``int(x)`` / ``bool(x)``
  applied to a traced value;
* Python branching (``if`` / ``while`` / ternary / comprehension filters)
  whose test involves a traced value;
* ``time`` / ``random`` / ``numpy.random`` — kernels must be
  deterministic functions of their inputs.

Traced-ness is inferred conservatively but in the repo's idiom: parameters
annotated ``int`` / ``bool`` / ``str`` / ``float`` are static
configuration; unannotated (or array-annotated) parameters are traced;
``.shape`` / ``.ndim`` / ``.dtype`` / ``len()`` of anything are static;
arithmetic/comparisons of statics stay static; any other call result is
traced.  Branching on statics (tile math, mode strings, unrolled
``while shift < n`` scans) is the normal metaprogramming idiom and passes.
"""

from __future__ import annotations

import ast

from .report import Finding

CHECK = "kernel-purity"
SCHEDULE_CHECK = "schedule-purity"

_STATIC_ANNOTATIONS = {"int", "bool", "str", "float"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}
_STATIC_CALLS = {"len", "range", "min", "max", "abs", "sum", "isinstance",
                 "tuple", "list", "sorted", "enumerate", "zip", "divmod",
                 "getattr", "hasattr", "type", "repr", "str",
                 # host-side dtype/shape predicates (jnp.issubdtype & co)
                 "issubdtype", "result_type", "finfo", "iinfo", "cdiv"}
_CAST_CALLS = {"float", "int", "bool"}
_FORBIDDEN_MODULES = {"time", "random", "numpy.random"}


def _annotation_name(node: ast.expr | None) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


class _FunctionChecker:
    def __init__(self, rel: str, qualname: str,
                 module_static: set[str]):
        self.rel = rel
        self.qualname = qualname
        self.static: set[str] = set(module_static)
        self.traced: set[str] = set()
        self.findings: list[Finding] = []

    # -- static-value inference -------------------------------------------

    def bind_params(self, fn: ast.FunctionDef) -> None:
        args = list(fn.args.posonlyargs) + list(fn.args.args) \
            + list(fn.args.kwonlyargs)
        for a in args:
            if _annotation_name(a.annotation) in _STATIC_ANNOTATIONS:
                self.static.add(a.arg)
            else:
                self.traced.add(a.arg)
        if fn.args.vararg:
            self.traced.add(fn.args.vararg.arg)
        if fn.args.kwarg:
            self.traced.add(fn.args.kwarg.arg)

    def is_static(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            if node.id in self.traced:
                return False
            # statics, module constants, imported helpers: all host values
            return True
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True
            return self.is_static(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_static(node.value)
        if isinstance(node, (ast.BinOp,)):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # ``x is None`` / ``x is not None`` is host-static: a tracer is
            # never None, so None-ness is fixed at trace time (the
            # optional-input idiom, e.g. the fused kernel's alive mask)
            if (all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)
                    and all(isinstance(c, ast.Constant) and c.value is None
                            for c in node.comparators)):
                return True
            return self.is_static(node.left) and \
                all(self.is_static(c) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self.is_static(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self.is_static(node.test) and self.is_static(node.body)
                    and self.is_static(node.orelse))
        if isinstance(node, ast.Call):
            fname = None
            if isinstance(node.func, ast.Name):
                fname = node.func.id
            elif isinstance(node.func, ast.Attribute):
                fname = node.func.attr
            if fname in _STATIC_CALLS or fname in _CAST_CALLS:
                return all(self.is_static(a) for a in node.args)
            return False            # jnp/pl/unknown calls produce tracers
        if isinstance(node, ast.Starred):
            return self.is_static(node.value)
        return False

    def assign(self, target: ast.expr, static: bool) -> None:
        if isinstance(target, ast.Name):
            (self.static if static else self.traced).add(target.id)
            (self.traced if static else self.static).discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self.assign(el, static)

    # -- the walk ----------------------------------------------------------

    def report(self, line: int, tag: str, msg: str) -> None:
        self.findings.append(Finding(CHECK, self.rel, line,
                                     f"{self.qualname}.{tag}", msg))

    def check_test(self, test: ast.expr, construct: str) -> None:
        if not self.is_static(test):
            src = ast.unparse(test)
            self.report(test.lineno, construct,
                        f"Python {construct} on a traced value "
                        f"({src!r}) in {self.qualname} — branch decisions "
                        f"must be static (shapes, modes, tile config)")

    def walk(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self.walk_stmt(stmt)

    def walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return                  # nested defs are checked as own scopes
        if isinstance(stmt, ast.Assign):
            static = self.is_static(stmt.value)
            self.visit_expr(stmt.value)
            for t in stmt.targets:
                self.assign(t, static)
            return
        if isinstance(stmt, ast.AugAssign):
            self.visit_expr(stmt.value)
            if isinstance(stmt.target, ast.Name):
                static = stmt.target.id in self.static \
                    and self.is_static(stmt.value)
                self.assign(stmt.target, static)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                static = self.is_static(stmt.value)
                self.visit_expr(stmt.value)
                self.assign(stmt.target, static)
            return
        if isinstance(stmt, ast.If) or isinstance(stmt, ast.While):
            kind = "if" if isinstance(stmt, ast.If) else "while"
            self.check_test(stmt.test, kind)
            self.visit_expr(stmt.test)
            self.walk(stmt.body)
            self.walk(stmt.orelse)
            return
        if isinstance(stmt, ast.For):
            self.visit_expr(stmt.iter)
            self.assign(stmt.target, self.is_static(stmt.iter))
            self.walk(stmt.body)
            self.walk(stmt.orelse)
            return
        for _f, value in ast.iter_fields(stmt):
            if isinstance(value, ast.expr):
                self.visit_expr(value)
            elif isinstance(value, list):
                if value and isinstance(value[0], ast.stmt):
                    self.walk(value)
                else:
                    for v in value:
                        if isinstance(v, ast.expr):
                            self.visit_expr(v)
                        elif isinstance(v, ast.excepthandler):
                            self.walk(v.body)

    def visit_expr(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.IfExp):
                self.check_test(node.test, "ternary")
            elif isinstance(node, ast.comprehension):
                for cond in node.ifs:
                    self.check_test(cond, "comprehension-if")
            elif isinstance(node, ast.Call):
                self.check_call(node)

    def check_call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            if func.attr == "item":
                self.report(node.lineno, "item",
                            f".item() in {self.qualname} is a host sync — "
                            f"it blocks on the device value")
            elif func.attr == "block_until_ready":
                self.report(node.lineno, "block_until_ready",
                            f".block_until_ready() in {self.qualname} is a "
                            f"host sync")
            elif func.attr == "device_get":
                self.report(node.lineno, "device_get",
                            f"jax.device_get in {self.qualname} pulls the "
                            f"value to host mid-kernel")
        elif isinstance(func, ast.Name):
            if func.id == "device_get":
                self.report(node.lineno, "device_get",
                            f"device_get in {self.qualname} pulls the value "
                            f"to host mid-kernel")
            elif func.id in _CAST_CALLS and node.args \
                    and not self.is_static(node.args[0]):
                src = ast.unparse(node.args[0])
                self.report(node.lineno, func.id,
                            f"{func.id}() applied to traced value "
                            f"({src!r}) in {self.qualname} forces a host "
                            f"sync (concretization)")


def _module_static_names(tree: ast.Module) -> set[str]:
    """Module-level constant names (DEFAULT_TILE & co) are static."""
    out = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            out.add(node.target.id)
    return out


def _scan_imports(tree: ast.Module, rel: str, *, check: str,
                  forbidden: set[str], roots: set[str],
                  context: str) -> list[Finding]:
    """Flag imports of nondeterminism sources (clock / ambient RNG)."""
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in forbidden or a.name.split(".")[0] in roots:
                    findings.append(Finding(
                        check, rel, node.lineno, f"import.{a.name}",
                        f"import of '{a.name}' in a {context}"))
        elif isinstance(node, ast.ImportFrom) and node.module:
            root = node.module.split(".")[0]
            if node.module in forbidden or root in roots:
                findings.append(Finding(
                    check, rel, node.lineno, f"import.{node.module}",
                    f"import from '{node.module}' in a {context}"))
    return findings


def check_schedule_module(source: str, rel: str) -> list[Finding]:
    """Determinism lint for workload-schedule generators (serve/workload):
    the schedule must be a pure function of its seed, so the module may not
    import any clock or ambient-RNG source (``time`` / ``random`` /
    ``datetime`` / ``numpy.random`` — seeded ``np.random.default_rng`` via
    the ``numpy`` namespace is the sanctioned idiom).  Import-surface only:
    the kernel lint's per-function traced-value inference would
    false-positive all over ordinary host code, and banning the imports is
    what actually guards against `time`-based nondeterminism."""
    tree = ast.parse(source)
    return _scan_imports(
        tree, rel, check=SCHEDULE_CHECK,
        forbidden=set(_FORBIDDEN_MODULES) | {"datetime"},
        roots={"time", "random", "datetime"},
        context="schedule-generator module — workload schedules must be "
                "pure functions of their seed (no clock, no ambient RNG)")


def check_module(source: str, rel: str) -> list[Finding]:
    tree = ast.parse(source)
    findings = _scan_imports(
        tree, rel, check=CHECK, forbidden=set(_FORBIDDEN_MODULES),
        roots={"time", "random"},
        context="kernel module — kernel flavours must be deterministic "
                "and clock-free")
    module_static = _module_static_names(tree)

    seen: set[int] = set()

    def check_fn(fn: ast.FunctionDef, prefix: str) -> None:
        if id(fn) in seen:
            return
        seen.add(id(fn))
        qual = f"{prefix}{fn.name}"
        chk = _FunctionChecker(rel, qual, module_static)
        chk.bind_params(fn)
        chk.walk(fn.body)
        findings.extend(chk.findings)
        for node in ast.walk(fn):
            if isinstance(node, ast.FunctionDef) and node is not fn:
                check_fn(node, f"{qual}.")

    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            check_fn(node, "")
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef):
                    check_fn(sub, f"{node.name}.")
    return findings


def run(files: list[tuple[str, str]]) -> list[Finding]:
    findings = []
    for path, rel in files:
        with open(path, encoding="utf-8") as fh:
            findings.extend(check_module(fh.read(), rel))
    return findings


__all__ = ["run", "check_module", "check_schedule_module", "CHECK",
           "SCHEDULE_CHECK"]
