"""repro.analysis — static invariant checker + runtime concurrency sanitizer.

Run the static pass over the repo::

    python -m repro.analysis [--root DIR] [--allowlist FILE] [--json]

Checks (see each module's docstring for the full contract):

* :mod:`.locks`     — lock-discipline lint over the annotated concurrent
  modules (``guarded_by`` / ``requires`` / ``published`` / ``writer_only``
  / ``gil_shared``, see :mod:`.annotations`);
* :mod:`.protocol`  — cursor-protocol conformance for every class exposing
  ``next``/``seek_geq``, and kernel-package layout/registry/signature
  conformance;
* :mod:`.purity`    — kernel purity (no host syncs, no branching on traced
  values, no clocks/randomness) for ``kernels/*/{ref,kernel}.py``.

Runtime companions:

* :class:`.contracts.ContractCursor` — contract-asserting cursor proxy
  used by the differential tests;
* :class:`.sanitizer.Sanitizer` — instrumented locks (lock-order
  inversion detection) + Eraser-style field race detection, enabled by
  ``pytest --sanitize`` / ``REPRO_SANITIZE=1``.

Exit status of the CLI is non-zero iff unsuppressed findings (or stale
allowlist entries) exist; reviewed exceptions live in
``analysis_allowlist.txt`` at the repo root, one stable ident per line.
"""

from . import annotations, locks, protocol, purity
from .contracts import ContractCursor, ContractViolation, wrap
from .report import Allowlist, Finding, apply_allowlist
from .sanitizer import Sanitizer, env_enabled

__all__ = [
    "annotations", "locks", "protocol", "purity",
    "ContractCursor", "ContractViolation", "wrap",
    "Allowlist", "Finding", "apply_allowlist",
    "Sanitizer", "env_enabled",
]
