"""Runtime concurrency sanitizer: instrumented locks + lightweight races.

Two detectors, both deterministic (no timing dependence):

* **Lock-order inversions** — every instrumented lock acquisition records
  ``held -> acquired`` edges in a global acquisition graph; an edge that
  closes a cycle is a potential deadlock and is reported immediately, even
  if the schedules never actually overlapped (the classic lock-order
  discipline: cycles are bugs whether or not they deadlocked today).

* **Field races (Eraser-style lockset)** — :meth:`Sanitizer.shadow`
  intercepts chosen attributes of an object and refines, per field, the
  set of instrumented locks held on *every* access once a second live
  thread touches it.  A write with an empty candidate lockset is reported
  as a write/write or write/read race.  A thread that terminated before
  the next access happens-before it (its writes are visible after
  ``join``), so post-``join`` reads do not false-positive.

Enablement: ``Sanitizer.enable()`` monkeypatches ``threading.Lock`` /
``RLock`` / ``Condition`` so locks created by ``repro``/test modules are
instrumented while stdlib internals (queues, thread pools) keep the real
primitives.  Tests opt in via ``pytest --sanitize`` or ``REPRO_SANITIZE=1``
(see ``tests/conftest.py``); the CI ``analysis`` job runs the lifecycle and
sharded stress tests this way.  ``# published`` fields (see
:mod:`repro.analysis.annotations`) are deliberately lock-free and must NOT
be shadowed — shadow the fields whose protection is a lock.
"""

from __future__ import annotations

import os
import sys
import threading
from dataclasses import dataclass, field

from .report import Finding

CHECK = "sanitizer"

_REAL = {
    "Lock": threading.Lock,
    "RLock": threading.RLock,
    "Condition": threading.Condition,
}

ENV_FLAG = "REPRO_SANITIZE"


def _callsite(skip_module: str) -> str:
    f = sys._getframe(2)
    while f is not None and f.f_globals.get("__name__") == skip_module:
        f = f.f_back
    if f is None:
        return "<unknown>"
    return f"{os.path.basename(f.f_code.co_filename)}:{f.f_lineno}"


class _SanLock:
    """Instrumented non-reentrant/reentrant lock reporting to a Sanitizer."""

    def __init__(self, san: "Sanitizer", raw, label: str,
                 reentrant: bool = False):
        self._san = san
        self._raw = raw
        self.label = label
        self._reentrant = reentrant
        self._owner: int | None = None
        self._count = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = threading.get_ident()
        if self._reentrant and self._owner == me:
            ok = self._raw.acquire(blocking, timeout)
            if ok:
                self._count += 1
            return ok
        self._san._before_acquire(self)
        ok = self._raw.acquire(blocking, timeout)
        if ok:
            self._san._on_acquired(self)
            if self._reentrant:
                self._owner, self._count = me, 1
        return ok

    def release(self) -> None:
        if self._reentrant and self._owner == threading.get_ident():
            self._count -= 1
            if self._count > 0:
                self._raw.release()
                return
            self._owner = None
        self._raw.release()
        self._san._on_release(self)

    def locked(self) -> bool:
        return self._raw.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<SanLock {self.label}>"


@dataclass
class _FieldState:
    owner: threading.Thread
    shared: bool = False
    lockset: set[int] = field(default_factory=set)
    written_shared: bool = False
    reported: bool = False


class Sanitizer:
    """One sanitizer instance: its own lock registry, graph, and findings."""

    def __init__(self, name: str = "sanitizer"):
        self.name = name
        self.findings: list[Finding] = []
        self._mu = _REAL["Lock"]()
        self._graph: dict[int, set[int]] = {}
        self._labels: dict[int, str] = {}
        self._reported_cycles: set[frozenset] = set()
        self._held = threading.local()
        self._fields: dict[tuple[int, str], _FieldState] = {}
        self._field_labels: dict[tuple[int, str], str] = {}
        self._shadow_cache: dict[tuple, type] = {}
        self._enabled = False

    # ------------------------------------------------------------------
    # lock construction
    # ------------------------------------------------------------------

    def lock(self, label: str | None = None) -> _SanLock:
        lk = _SanLock(self, _REAL["Lock"](),
                      label or _callsite(__name__))
        self._labels[id(lk)] = lk.label
        return lk

    def rlock(self, label: str | None = None) -> _SanLock:
        lk = _SanLock(self, _REAL["RLock"](),
                      label or _callsite(__name__), reentrant=True)
        self._labels[id(lk)] = lk.label
        return lk

    def condition(self, label: str | None = None):
        """A real Condition over an instrumented (non-reentrant) lock:
        ``with``/``wait``/``notify`` all route through the hooks."""
        return _REAL["Condition"](self.lock(label))

    # ------------------------------------------------------------------
    # lock-order graph
    # ------------------------------------------------------------------

    def _held_list(self) -> list:
        if not hasattr(self._held, "locks"):
            self._held.locks = []
        return self._held.locks

    def _before_acquire(self, lock: _SanLock) -> None:
        held = self._held_list()
        if any(h is lock for h in held):
            return                  # owned-probe / re-acquire, not an edge
        if not held:
            return
        nid = id(lock)
        with self._mu:
            for h in held:
                hid = id(h)
                self._graph.setdefault(hid, set()).add(nid)
                cycle = self._find_path(nid, hid)
                if cycle is not None:
                    key = frozenset([hid, nid])
                    if key not in self._reported_cycles:
                        self._reported_cycles.add(key)
                        names = " -> ".join(
                            self._labels.get(x, "?") for x in cycle + [nid])
                        self.findings.append(Finding(
                            CHECK, _callsite(__name__).split(":")[0], 0,
                            f"lock-order.{h.label}~{lock.label}",
                            f"lock-order inversion: acquiring "
                            f"'{lock.label}' while holding '{h.label}' "
                            f"closes the cycle {names} (thread "
                            f"{threading.current_thread().name}, at "
                            f"{_callsite(__name__)})"))

    def _find_path(self, src: int, dst: int) -> list[int] | None:
        """DFS path src -> dst in the acquisition graph (ids)."""
        stack = [(src, [src])]
        seen = set()
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            if node in seen:
                continue
            seen.add(node)
            for nxt in self._graph.get(node, ()):
                stack.append((nxt, path + [nxt]))
        return None

    def _on_acquired(self, lock: _SanLock) -> None:
        self._held_list().append(lock)

    def _on_release(self, lock: _SanLock) -> None:
        held = self._held_list()
        for i in range(len(held) - 1, -1, -1):
            if held[i] is lock:
                del held[i]
                break

    # ------------------------------------------------------------------
    # field race detection (Eraser lockset)
    # ------------------------------------------------------------------

    def shadow(self, obj, *fields: str, label: str | None = None):
        """Intercept ``fields`` of ``obj`` (in place) for race detection."""
        cls = obj.__class__
        key = (cls, tuple(sorted(fields)))
        shadow_cls = self._shadow_cache.get(key)
        if shadow_cls is None:
            ns = {"__san_shadowed__": True}
            for f in fields:
                ns[f] = self._make_property(f)
            shadow_cls = type(f"Sanitized{cls.__name__}", (cls,), ns)
            self._shadow_cache[key] = shadow_cls
        base = label or type(obj).__name__
        for f in fields:
            slot = f"_san_{f}"
            if f in obj.__dict__:
                obj.__dict__[slot] = obj.__dict__.pop(f)
            self._field_labels[(id(obj), f)] = f"{base}.{f}"
        obj.__class__ = shadow_cls
        return obj

    def _make_property(self, fname: str):
        slot = f"_san_{fname}"
        san = self

        def getter(obj):
            san._on_field_access(obj, fname, is_write=False)
            try:
                return obj.__dict__[slot]
            except KeyError:
                raise AttributeError(fname) from None

        def setter(obj, value):
            san._on_field_access(obj, fname, is_write=True)
            obj.__dict__[slot] = value

        return property(getter, setter)

    def _on_field_access(self, obj, fname: str, is_write: bool) -> None:
        key = (id(obj), fname)
        me = threading.current_thread()
        held = {id(lk) for lk in self._held_list()}
        with self._mu:
            st = self._fields.get(key)
            if st is None:
                self._fields[key] = _FieldState(owner=me)
                return
            if not st.shared:
                if st.owner is me:
                    return
                if not st.owner.is_alive():
                    # the previous owner terminated before this access:
                    # termination happens-before, ownership transfers
                    st.owner = me
                    return
                st.shared = True
                st.lockset = set(held)
                st.written_shared = is_write
            else:
                st.lockset &= held
                st.written_shared |= is_write
            if st.written_shared and not st.lockset and not st.reported:
                st.reported = True
                lbl = self._field_labels.get(key, fname)
                kind = "write" if is_write else "read"
                self.findings.append(Finding(
                    CHECK, _callsite(__name__).split(":")[0], 0,
                    f"race.{lbl}",
                    f"data race on {lbl}: {kind} by thread '{me.name}' "
                    f"with empty candidate lockset — concurrent threads "
                    f"access this field with no common lock (at "
                    f"{_callsite(__name__)})"))

    # ------------------------------------------------------------------
    # threading patch (env-flag / --sanitize enablement)
    # ------------------------------------------------------------------

    def _instrument_caller(self) -> bool:
        mod = sys._getframe(2).f_globals.get("__name__", "")
        return (mod.startswith("repro") or mod.startswith("tests")
                or mod.startswith("test_") or mod == "conftest")

    def enable(self) -> "Sanitizer":
        """Patch ``threading.Lock/RLock/Condition`` so locks created by
        repro/test code are instrumented; stdlib callers get the real
        primitives.  Idempotent; pair with :meth:`disable`."""
        if self._enabled:
            return self
        san = self

        def make_lock(*a, **kw):
            if san._instrument_caller():
                return san.lock(label=_callsite(__name__))
            return _REAL["Lock"](*a, **kw)

        def make_rlock(*a, **kw):
            if san._instrument_caller():
                return san.rlock(label=_callsite(__name__))
            return _REAL["RLock"](*a, **kw)

        def make_condition(lock=None, *a, **kw):
            if lock is None and san._instrument_caller():
                return san.condition(label=_callsite(__name__))
            return _REAL["Condition"](lock, *a, **kw)

        threading.Lock = make_lock
        threading.RLock = make_rlock
        threading.Condition = make_condition
        self._enabled = True
        return self

    def disable(self) -> None:
        if not self._enabled:
            return
        threading.Lock = _REAL["Lock"]
        threading.RLock = _REAL["RLock"]
        threading.Condition = _REAL["Condition"]
        self._enabled = False

    def __enter__(self) -> "Sanitizer":
        return self.enable()

    def __exit__(self, *exc) -> None:
        self.disable()

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def report(self) -> str:
        if not self.findings:
            return f"{self.name}: clean"
        return "\n".join(str(f) for f in self.findings)


def env_enabled() -> bool:
    return os.environ.get(ENV_FLAG, "") not in ("", "0")


__all__ = ["Sanitizer", "CHECK", "ENV_FLAG", "env_enabled"]
