"""Runtime contract wrapper for the cursor protocol.

:class:`ContractCursor` wraps any cursor implementation and asserts, on
every call, the behavioral half of the protocol the static pass
(:mod:`repro.analysis.protocol`) can only check structurally:

* ``next`` never moves ``docid`` backwards (strictly forward for doc-level
  cursors; word-level occurrence streams may repeat a docid across
  occurrences, so equality is allowed with ``strict=False``);
* ``seek_geq(target)`` lands on ``docid >= target`` or exhausts, never
  moves backwards, and never lands strictly between the pre-call position
  and ``target`` (the postcondition the chained tiered cursors rely on);
* ``positions()`` returns strictly increasing positive word positions;
* no ``next``/``seek_geq`` after exhaustion.

The differential tests wrap every implementation (dynamic, static, both
codecs, chained) in this class, so a protocol regression fails loudly at
the violating call instead of surfacing as a wrong result set downstream.
"""

from __future__ import annotations


class ContractViolation(AssertionError):
    """A cursor broke the protocol contract at runtime."""


class ContractCursor:
    """Transparent contract-checking proxy around a cursor.

    ``strict=True`` additionally requires strictly increasing docids from
    ``next`` (doc-level cursors); word-level occurrence cursors keep the
    default non-decreasing contract.
    """

    def __init__(self, inner, *, strict: bool = False, label: str = ""):
        self.inner = inner
        self.strict = strict
        self.label = label or type(inner).__name__
        self.calls = 0

    # -- delegated state ---------------------------------------------------

    @property
    def docid(self):
        return self.inner.docid

    @property
    def payload(self):
        return self.inner.payload

    @property
    def exhausted(self):
        return self.inner.exhausted

    def _fail(self, msg: str) -> None:
        raise ContractViolation(f"[{self.label}] {msg}")

    def _snapshot(self):
        return None if self.inner.exhausted else self.inner.docid

    # -- checked protocol --------------------------------------------------

    def next(self):
        before = self._snapshot()
        if before is None:
            self._fail("next() called on an exhausted cursor")
        out = self.inner.next()
        self.calls += 1
        if not self.inner.exhausted:
            d = self.inner.docid
            if d < before:
                self._fail(f"next() moved docid backwards: "
                           f"{before} -> {d}")
            if self.strict and d == before:
                self._fail(f"next() repeated docid {d} on a "
                           f"doc-level cursor")
        return out

    def seek_geq(self, target):
        before = self._snapshot()
        out = self.inner.seek_geq(target)
        self.calls += 1
        if not self.inner.exhausted:
            d = self.inner.docid
            if d < target:
                self._fail(f"seek_geq({target}) landed on docid {d} "
                           f"< target (postcondition: exhausted or "
                           f"docid >= target)")
            if before is not None and d < before:
                self._fail(f"seek_geq({target}) moved docid backwards: "
                           f"{before} -> {d}")
        elif before is not None and before >= target:
            self._fail(f"seek_geq({target}) exhausted a cursor already "
                       f"positioned at docid {before} >= target")
        return out

    def positions(self):
        pos = self.inner.positions()
        seq = list(pos)
        if any(p <= 0 for p in seq):
            self._fail(f"positions() returned a non-positive word "
                       f"position: {seq}")
        if any(b <= a for a, b in zip(seq, seq[1:])):
            self._fail(f"positions() not strictly increasing: {seq}")
        return pos

    def __getattr__(self, name):
        return getattr(self.inner, name)


def wrap(cursor, *, strict: bool = False, label: str = ""):
    """Wrap ``cursor`` unless it already is a :class:`ContractCursor`."""
    if isinstance(cursor, ContractCursor):
        return cursor
    return ContractCursor(cursor, strict=strict, label=label)


__all__ = ["ContractCursor", "ContractViolation", "wrap"]
