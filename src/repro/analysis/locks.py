"""Lock-discipline lint (AST, Clang-thread-safety style) for the engine's
concurrent modules.

What it enforces, per :mod:`repro.analysis.annotations`:

* ``guarded_by`` fields are only touched inside ``with self.<lock>:`` or in
  methods annotated ``requires: <lock>`` (``__init__`` is exempt — the
  object is not shared yet);
* ``requires``-annotated methods are only called (as ``self.m()``, within
  the module) where the lock is held;
* ``published`` fields follow the single-writer lock-free publication
  protocol: one plain reference assignment per function (no multi-field
  publications, which are not atomic), at most one load per function (two
  loads can straddle a concurrent swap — a torn read), and no
  read-modify-write from a background thread;
* ``writer_only`` fields are never touched from a thread-target closure or
  a pool lambda;
* ``gil_shared`` container fields are never rebound outside ``__init__``;
* unannotated fields are not *written* from more than one thread
  entry-point (writer methods vs. ``threading.Thread`` target closures vs.
  thread-pool lambdas) — shared mutation must be annotated to state its
  protection, or fixed.

Thread roles are inferred syntactically: a nested function passed as
``threading.Thread(target=...)`` runs on a background thread; a callable
passed to ``<pool>.map``/``<pool>.submit`` runs on a pool thread;
everything else runs on the caller (writer) thread.  The analysis is
module-local and flow-insensitive beyond ``with``-scope tracking — it is a
lint for this repo's one-writer architecture, not a general race prover.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from . import annotations as ann_mod
from .report import Finding

ROLE_WRITER = "writer"
ROLE_THREAD = "thread-target"
ROLE_POOL = "pool"

CHECK = "lock-discipline"


@dataclass
class _Scope:
    cls: str
    func: str                       # dotted for nested: "freeze.work"
    role: str
    node: ast.AST                   # FunctionDef or Lambda
    held0: frozenset[str] = frozenset()


@dataclass
class _Access:
    field: str
    line: int
    is_store: bool
    is_aug: bool
    held: frozenset[str]


@dataclass
class _ScopeResult:
    scope: _Scope
    accesses: list[_Access] = field(default_factory=list)
    self_calls: list[tuple[str, int, frozenset]] = field(default_factory=list)


def _is_self_attr(node: ast.AST) -> str | None:
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _thread_target_names(fn: ast.AST) -> set[str]:
    """Names of nested defs passed as ``threading.Thread(target=...)``."""
    out = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        callee = node.func
        is_thread = (isinstance(callee, ast.Attribute)
                     and callee.attr == "Thread") or \
                    (isinstance(callee, ast.Name) and callee.id == "Thread")
        if not is_thread:
            continue
        for kw in node.keywords:
            if kw.arg == "target" and isinstance(kw.value, ast.Name):
                out.add(kw.value.id)
    return out


def _pool_callables(fn: ast.AST) -> tuple[set[int], set[str]]:
    """(lambda node ids, nested-def names) handed to ``.map``/``.submit``."""
    lambda_ids: set[int] = set()
    names: set[str] = set()
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("map", "submit") and node.args):
            continue
        head = node.args[0]
        if isinstance(head, ast.Lambda):
            lambda_ids.add(id(head))
        elif isinstance(head, ast.Name):
            names.add(head.id)
    return lambda_ids, names


class _Walker:
    """One function scope: track ``with self.<lock>`` nesting, record every
    ``self.<attr>`` access with the lock set held at that point."""

    def __init__(self, result: _ScopeResult, pool_lambda_ids: set[int]):
        self.res = result
        self.pool_lambda_ids = pool_lambda_ids

    # -- statements --------------------------------------------------------

    def walk_body(self, body: list[ast.stmt], held: frozenset[str]) -> None:
        for stmt in body:
            self.walk_stmt(stmt, held)

    def walk_stmt(self, stmt: ast.stmt, held: frozenset[str]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return                      # nested defs are separate scopes
        if isinstance(stmt, ast.With):
            new_held = set(held)
            for item in stmt.items:
                self.walk_expr(item.context_expr, held)
                lock = _is_self_attr(item.context_expr)
                if lock is not None:
                    new_held.add(lock)
            self.walk_body(stmt.body, frozenset(new_held))
            return
        if isinstance(stmt, ast.Assign):
            self.walk_expr(stmt.value, held)
            for t in stmt.targets:
                self._store_target(t, held, aug=False)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self.walk_expr(stmt.value, held)
            self._store_target(stmt.target, held, aug=False)
            return
        if isinstance(stmt, ast.AugAssign):
            self.walk_expr(stmt.value, held)
            self._store_target(stmt.target, held, aug=True)
            return
        # generic recursion: visit child expressions, then child bodies
        for fld, value in ast.iter_fields(stmt):
            if isinstance(value, ast.expr):
                self.walk_expr(value, held)
            elif isinstance(value, list):
                if value and isinstance(value[0], ast.stmt):
                    self.walk_body(value, held)
                else:
                    for v in value:
                        if isinstance(v, ast.expr):
                            self.walk_expr(v, held)
                        elif isinstance(v, ast.excepthandler):
                            self.walk_body(v.body, held)

    def _store_target(self, target: ast.expr, held: frozenset[str],
                      aug: bool) -> None:
        name = _is_self_attr(target)
        if name is not None:
            self.res.accesses.append(_Access(name, target.lineno, True, aug,
                                             held))
            if aug:     # augmented store is also a load
                self.res.accesses.append(_Access(name, target.lineno, False,
                                                 True, held))
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._store_target(el, held, aug)
            return
        self.walk_expr(target, held)    # self.a[i] = x loads self.a

    # -- expressions -------------------------------------------------------

    def walk_expr(self, expr: ast.expr, held: frozenset[str]) -> None:
        if isinstance(expr, ast.Lambda):
            if id(expr) in self.pool_lambda_ids:
                return                  # separate pool-role scope
            self.walk_expr(expr.body, held)
            return
        name = _is_self_attr(expr)
        if name is not None:
            self.res.accesses.append(
                _Access(name, expr.lineno, False, False, held))
            self.walk_expr(expr.value, held)
            return
        if (isinstance(expr, ast.Call)
                and (callee := _is_self_attr(expr.func)) is not None):
            self.res.self_calls.append((callee, expr.lineno, held))
        for child in ast.iter_child_nodes(expr):
            if isinstance(child, ast.expr):
                self.walk_expr(child, held)
            elif isinstance(child, ast.comprehension):
                self.walk_expr(child.iter, held)
                self.walk_expr(child.target, held)
                for cond in child.ifs:
                    self.walk_expr(cond, held)


def _collect_scopes(cls: ast.ClassDef,
                    ann: ann_mod.ModuleAnnotations) -> list[_ScopeResult]:
    out = []
    for method in cls.body:
        if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        targets = _thread_target_names(method)
        pool_lambdas, pool_names = _pool_callables(method)
        held0 = frozenset(ann.requires.get((cls.name, method.name), set()))
        scopes = [_Scope(cls.name, method.name, ROLE_WRITER, method, held0)]
        for node in ast.walk(method):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not method:
                role = ROLE_THREAD if node.name in targets else (
                    ROLE_POOL if node.name in pool_names else ROLE_WRITER)
                scopes.append(_Scope(cls.name, f"{method.name}.{node.name}",
                                     role, node))
            elif isinstance(node, ast.Lambda) and id(node) in pool_lambdas:
                scopes.append(_Scope(cls.name, f"{method.name}.<lambda>",
                                     ROLE_POOL, node))
        for scope in scopes:
            res = _ScopeResult(scope)
            walker = _Walker(res, pool_lambdas)
            body = scope.node.body
            if isinstance(body, list):
                walker.walk_body(body, scope.held0)
            else:                       # Lambda body is a single expression
                walker.walk_expr(body, scope.held0)
            out.append(res)
    return out


def check_module(path: str, source: str, relpath: str) -> list[Finding]:
    ann = ann_mod.parse(source)
    tree = ast.parse(source)
    findings: list[Finding] = []
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        results = _collect_scopes(cls, ann)
        # field -> {role: first store line} across all non-__init__ scopes
        store_roles: dict[str, dict[str, int]] = {}
        for res in results:
            sc = res.scope
            in_init = sc.func.split(".")[0] == "__init__"
            loads: dict[str, list[int]] = {}
            stores: dict[str, list[int]] = {}
            for acc in res.accesses:
                kind = ann.field_kind(cls.name, acc.field)
                if acc.is_store:
                    stores.setdefault(acc.field, []).append(acc.line)
                    if kind is None and not in_init:
                        store_roles.setdefault(acc.field, {}) \
                            .setdefault(sc.role, acc.line)
                else:
                    loads.setdefault(acc.field, []).append(acc.line)
                if kind is None or in_init:
                    continue
                sym = f"{cls.name}.{sc.func}.{acc.field}"
                if kind == ann_mod.GUARDED_BY:
                    lock = ann.guards[(cls.name, acc.field)]
                    if lock not in acc.held:
                        verb = "write" if acc.is_store else "read"
                        findings.append(Finding(
                            CHECK, relpath, acc.line, sym,
                            f"{verb} of '{acc.field}' (guarded_by {lock}) "
                            f"outside 'with self.{lock}:' in "
                            f"{cls.name}.{sc.func}"))
                elif kind == ann_mod.WRITER_ONLY \
                        and sc.role != ROLE_WRITER:
                    findings.append(Finding(
                        CHECK, relpath, acc.line, sym,
                        f"writer_only field '{acc.field}' touched from a "
                        f"{sc.role} scope {cls.name}.{sc.func}"))
                elif kind == ann_mod.GIL_SHARED and acc.is_store:
                    findings.append(Finding(
                        CHECK, relpath, acc.line, sym,
                        f"gil_shared container '{acc.field}' rebound outside "
                        f"__init__ in {cls.name}.{sc.func} (readers hold the "
                        f"old reference)"))
                elif kind == ann_mod.PUBLISHED and acc.is_store \
                        and acc.is_aug and sc.role != ROLE_WRITER:
                    findings.append(Finding(
                        CHECK, relpath, acc.line, sym,
                        f"read-modify-write of published field '{acc.field}' "
                        f"from a {sc.role} scope {cls.name}.{sc.func} — not "
                        f"atomic against the writer thread"))
            if in_init:
                continue
            # published-protocol rules, per scope
            pub_stored = sorted(
                f for f in stores
                if (cls.name, f) in ann.published)
            if len(pub_stored) > 1:
                line = max(stores[f][0] for f in pub_stored)
                findings.append(Finding(
                    CHECK, relpath, line,
                    f"{cls.name}.{sc.func}.{'+'.join(pub_stored)}",
                    f"non-atomic publication: {cls.name}.{sc.func} stores "
                    f"{len(pub_stored)} published fields "
                    f"({', '.join(pub_stored)}) — a reader between the "
                    f"stores sees them inconsistent; publish ONE immutable "
                    f"object by a single reference assignment"))
            for f, lns in stores.items():
                if (cls.name, f) in ann.published and len(lns) > 1:
                    findings.append(Finding(
                        CHECK, relpath, lns[1], f"{cls.name}.{sc.func}.{f}",
                        f"published field '{f}' stored {len(lns)} times in "
                        f"{cls.name}.{sc.func} — publication must be a "
                        f"single assignment"))
            for f, lns in loads.items():
                if (cls.name, f) in ann.published and len(lns) > 1:
                    findings.append(Finding(
                        CHECK, relpath, lns[1], f"{cls.name}.{sc.func}.{f}",
                        f"torn read: published field '{f}' loaded "
                        f"{len(lns)}x in {cls.name}.{sc.func} — a concurrent "
                        f"swap between loads yields mixed state; snapshot it "
                        f"once into a local"))
            # requires-annotated self-calls need the lock at the call site
            for callee, line, held in res.self_calls:
                need = ann.requires.get((cls.name, callee), set())
                missing = sorted(need - held)
                if missing:
                    findings.append(Finding(
                        CHECK, relpath, line,
                        f"{cls.name}.{sc.func}.{callee}()",
                        f"call to {cls.name}.{callee}() (requires "
                        f"{', '.join(missing)}) without holding the lock in "
                        f"{cls.name}.{sc.func}"))
        for f, roles in store_roles.items():
            if len(roles) > 1:
                line = min(roles.values())
                findings.append(Finding(
                    CHECK, relpath, line, f"{cls.name}.{f}",
                    f"unannotated field '{cls.name}.{f}' written from "
                    f"multiple thread entry-points ({', '.join(sorted(roles))})"
                    f" — annotate its protection (guarded_by/published) or "
                    f"serialize the writers"))
    return findings


def run(files: list[tuple[str, str]]) -> list[Finding]:
    """files: (absolute path, repo-relative path) pairs."""
    findings = []
    for path, rel in files:
        with open(path, encoding="utf-8") as fh:
            findings.extend(check_module(path, fh.read(), rel))
    return findings


__all__ = ["run", "check_module", "CHECK"]
