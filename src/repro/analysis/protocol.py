"""Protocol conformance: the cursor protocol and the kernel-package layout.

Cursor protocol (see ``core/query.py``): every class exposing BOTH ``next``
and ``seek_geq`` is a cursor and must provide

* ``next(self)`` — no further parameters;
* ``seek_geq(self, target)`` — exactly one parameter;
* ``docid`` and ``exhausted`` — as methods/properties or fields assigned
  in ``__init__``;
* positional cursors (word-level: class name contains ``Word``) must also
  provide ``positions``, and any ``positions`` must be ``positions(self)``.

The runtime half of the contract (docid monotonicity, the ``seek_geq``
postcondition ``exhausted or docid >= target``) is asserted by
:class:`repro.analysis.contracts.ContractCursor`, which the differential
tests wrap around every implementation.

Kernel packages (``src/repro/kernels/<name>/``): each must ship the three
modules ``ref.py`` / ``kernel.py`` / ``ops.py``, be registered in
``kernels/registry.py``'s ``_OPS_MODULES``, and keep the ref↔kernel entry
points call-compatible — the kernel's positional parameters must extend the
reference's (same names, same order; extras defaulted) and accept every
reference keyword, so the two flavours stay interchangeable behind one ops
dispatcher.  Pairing: ``<stem>_ref`` ↔ ``<stem>_kernel`` by name, else the
unique public function of each module, else the reference function that
``ops.py`` imports.  Signatures are compared with :mod:`inspect` (the ref
may legitimately be a re-export).
"""

from __future__ import annotations

import ast
import importlib
import inspect
import os

from .report import Finding

CHECK = "protocol"


# --------------------------------------------------------------------------
# cursor conformance
# --------------------------------------------------------------------------


def _class_member_names(cls: ast.ClassDef) -> set[str]:
    names = set()
    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
            if node.name == "__init__":
                for sub in ast.walk(node):
                    if (isinstance(sub, ast.Attribute)
                            and isinstance(sub.ctx, ast.Store)
                            and isinstance(sub.value, ast.Name)
                            and sub.value.id == "self"):
                        names.add(sub.attr)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
    # __slots__ entries count as members
    for node in cls.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__slots__":
                    if isinstance(node.value, (ast.Tuple, ast.List)):
                        for el in node.value.elts:
                            if isinstance(el, ast.Constant):
                                names.add(str(el.value))
    return names


def _method(cls: ast.ClassDef, name: str) -> ast.FunctionDef | None:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _n_params(fn: ast.FunctionDef) -> int:
    a = fn.args
    return len(a.posonlyargs) + len(a.args)


def check_cursors(files: list[tuple[str, str]]) -> list[Finding]:
    findings = []
    for path, rel in files:
        with open(path, encoding="utf-8") as fh:
            tree = ast.parse(fh.read())
        for cls in [n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)]:
            nxt, seek = _method(cls, "next"), _method(cls, "seek_geq")
            if nxt is None or seek is None:
                continue
            members = _class_member_names(cls)

            def report(line, part, msg):
                findings.append(Finding(CHECK, rel, line,
                                        f"{cls.name}.{part}", msg))

            if _n_params(nxt) != 1 or nxt.args.vararg or nxt.args.kwonlyargs:
                report(nxt.lineno, "next",
                       f"cursor {cls.name}.next must take no parameters "
                       f"beyond self")
            if _n_params(seek) != 2 or seek.args.vararg:
                report(seek.lineno, "seek_geq",
                       f"cursor {cls.name}.seek_geq must take exactly one "
                       f"parameter (target) beyond self")
            for required in ("docid", "exhausted"):
                if required not in members:
                    report(cls.lineno, required,
                           f"cursor {cls.name} exposes next/seek_geq but "
                           f"has no '{required}'")
            pos = _method(cls, "positions")
            if "Word" in cls.name and pos is None \
                    and "positions" not in members:
                report(cls.lineno, "positions",
                       f"positional cursor {cls.name} (word-level) must "
                       f"implement positions()")
            if pos is not None and (_n_params(pos) != 1 or pos.args.vararg):
                report(pos.lineno, "positions",
                       f"{cls.name}.positions must take no parameters "
                       f"beyond self")
    return findings


# --------------------------------------------------------------------------
# kernel-package conformance
# --------------------------------------------------------------------------


def _registered_kernels(registry_path: str) -> set[str]:
    with open(registry_path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read())
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "_OPS_MODULES" \
                        and isinstance(node.value, ast.Dict):
                    return {k.value for k in node.value.keys
                            if isinstance(k, ast.Constant)}
    return set()


def _public_functions(mod) -> dict[str, object]:
    out = {}
    for name in dir(mod):
        if name.startswith("_"):
            continue
        fn = getattr(mod, name)
        if inspect.isfunction(fn):
            out[name] = fn
    return out


def _ops_ref_imports(ops_path: str) -> list[str]:
    with open(ops_path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read())
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "ref":
            out.extend(a.name for a in node.names)
    return out


def _pair_flavors(name: str, pkg_dir: str, ref_mod, kern_mod
                  ) -> list[tuple[object, object]]:
    refs = _public_functions(ref_mod)
    kerns = {n: f for n, f in _public_functions(kern_mod).items()
             if n.endswith("_kernel")}
    pairs, used_refs = [], set()
    for kname, kfn in sorted(kerns.items()):
        stem = kname[: -len("_kernel")]
        if f"{stem}_ref" in refs:
            pairs.append((refs[f"{stem}_ref"], kfn))
            used_refs.add(f"{stem}_ref")
    unpaired_k = [kfn for kname, kfn in sorted(kerns.items())
                  if not any(p[1] is kfn for p in pairs)]
    ref_suffixed = [n for n in refs if n.endswith("_ref")
                    and n not in used_refs]
    if len(unpaired_k) == 1:
        if len(ref_suffixed) == 1:
            pairs.append((refs[ref_suffixed[0]], unpaired_k[0]))
        else:
            # fall back to the reference entry point ops.py dispatches to
            imported = [n for n in _ops_ref_imports(
                os.path.join(pkg_dir, "ops.py"))
                if n in refs]
            if len(imported) == 1:
                pairs.append((refs[imported[0]], unpaired_k[0]))
    return pairs


def _signature_findings(name: str, rel: str, ref_fn, kern_fn
                        ) -> list[Finding]:
    findings = []
    rsig = inspect.signature(ref_fn)
    ksig = inspect.signature(kern_fn)
    P = inspect.Parameter
    rpos = [p for p in rsig.parameters.values()
            if p.kind in (P.POSITIONAL_ONLY, P.POSITIONAL_OR_KEYWORD)]
    kpos = [p for p in ksig.parameters.values()
            if p.kind in (P.POSITIONAL_ONLY, P.POSITIONAL_OR_KEYWORD)]
    sym = f"{name}.{ref_fn.__name__}~{kern_fn.__name__}"
    line = kern_fn.__code__.co_firstlineno

    def bad(msg):
        findings.append(Finding(CHECK, rel, line, sym, msg))

    if [p.name for p in kpos[:len(rpos)]] != [p.name for p in rpos]:
        bad(f"kernel {kern_fn.__name__}{ksig} positional parameters do not "
            f"extend ref {ref_fn.__name__}{rsig} (same names, same order)")
        return findings
    for extra in kpos[len(rpos):]:
        if extra.default is P.empty:
            bad(f"kernel-only parameter '{extra.name}' of "
                f"{kern_fn.__name__} must have a default (callers pass "
                f"ref-shaped arguments)")
    kaccept = {p.name for p in ksig.parameters.values()
               if p.kind in (P.POSITIONAL_OR_KEYWORD, P.KEYWORD_ONLY)}
    for p in rsig.parameters.values():
        if p.kind == P.KEYWORD_ONLY and p.name not in kaccept:
            bad(f"ref keyword '{p.name}' not accepted by "
                f"{kern_fn.__name__} — flavours are not interchangeable")
    return findings


def check_kernels(kernels_dir: str, repo_root: str) -> list[Finding]:
    findings = []
    registry_path = os.path.join(kernels_dir, "registry.py")
    registered = _registered_kernels(registry_path)
    reg_rel = os.path.relpath(registry_path, repo_root)
    packages = sorted(
        d for d in os.listdir(kernels_dir)
        if os.path.isdir(os.path.join(kernels_dir, d))
        and os.path.exists(os.path.join(kernels_dir, d, "__init__.py"))
        and not d.startswith("_"))
    for name in packages:
        pkg = os.path.join(kernels_dir, name)
        rel = os.path.relpath(pkg, repo_root)
        missing = [m for m in ("ref.py", "kernel.py", "ops.py")
                   if not os.path.exists(os.path.join(pkg, m))]
        if missing:
            findings.append(Finding(
                CHECK, rel, 1, f"{name}.layout",
                f"kernel package '{name}' is missing {', '.join(missing)} "
                f"(every kernel ships ref/kernel/ops)"))
            continue
        if name not in registered:
            findings.append(Finding(
                CHECK, reg_rel, 1, f"{name}.registry",
                f"kernel package '{name}' is not registered in "
                f"_OPS_MODULES — its flavours are unreachable through "
                f"the registry"))
        ref_mod = importlib.import_module(f"repro.kernels.{name}.ref")
        kern_mod = importlib.import_module(f"repro.kernels.{name}.kernel")
        pairs = _pair_flavors(name, pkg, ref_mod, kern_mod)
        if not pairs:
            findings.append(Finding(
                CHECK, os.path.join(rel, "kernel.py"), 1, f"{name}.pairing",
                f"could not pair a public *_kernel entry point of '{name}' "
                f"with its reference flavour"))
        for ref_fn, kern_fn in pairs:
            findings.extend(_signature_findings(
                name, os.path.join(rel, "kernel.py"), ref_fn, kern_fn))
    for name in sorted(registered):
        if name not in packages:
            findings.append(Finding(
                CHECK, reg_rel, 1, f"{name}.registry",
                f"_OPS_MODULES registers '{name}' but "
                f"src/repro/kernels/{name}/ does not exist"))
    return findings


__all__ = ["check_cursors", "check_kernels", "CHECK"]
