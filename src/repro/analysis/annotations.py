"""Parser for the repo's thread-safety annotation comments.

The concurrent modules annotate their shared state in source comments, in
the spirit of Clang's thread-safety attributes (there is no runtime cost
and no import-order coupling — the lint reads the source, not the objects):

``self.x = ...  # guarded_by: _lock``
    every load/store of ``self.x`` outside ``__init__`` must happen inside
    ``with self._lock:`` (or in a method annotated ``# requires: _lock``);

``def m(self):  # requires: _lock``
    callers must hold ``self._lock``; the lint checks ``self.m()`` call
    sites within the module and treats the lock as held inside ``m``;

``self.x = ...  # published``
    a lock-free single-writer publication field: it may be (re)assigned by
    exactly one plain ``self.x = value`` per function (multi-field or
    multi-step publications are not atomic), and any reader must load it
    at most once per function (a second load can observe a different
    reference — a torn read);

``self.x = ...  # writer_only``
    touched only by the single front-door writer thread: any access from a
    background-thread closure (a ``threading.Thread`` target) or a
    thread-pool lambda is a violation;

``self.x = ...  # gil_shared``
    a container mutated in place under the GIL and read concurrently: the
    *reference* must never be rebound outside ``__init__`` (readers hold
    the reference; rebinding would split the fleet's view).

Annotations live on the line of the assignment (or anywhere within a
multi-line assignment statement); ``# requires:`` may sit on the ``def``
line or on the line directly above the method (above its decorators).
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

_ANN_RE = re.compile(
    r"#\s*(guarded_by|requires|published|writer_only|gil_shared)\b"
    r"\s*:?\s*([A-Za-z0-9_,\s]*)")

GUARDED_BY = "guarded_by"
REQUIRES = "requires"
PUBLISHED = "published"
WRITER_ONLY = "writer_only"
GIL_SHARED = "gil_shared"


@dataclass
class ModuleAnnotations:
    """Per-class annotation tables for one source file."""

    # (class, field) -> lock name
    guards: dict[tuple[str, str], str] = field(default_factory=dict)
    published: set[tuple[str, str]] = field(default_factory=set)
    writer_only: set[tuple[str, str]] = field(default_factory=set)
    gil_shared: set[tuple[str, str]] = field(default_factory=set)
    # (class, method) -> set of lock names
    requires: dict[tuple[str, str], set[str]] = field(default_factory=dict)

    def field_kind(self, cls: str, name: str) -> str | None:
        if (cls, name) in self.guards:
            return GUARDED_BY
        if (cls, name) in self.published:
            return PUBLISHED
        if (cls, name) in self.writer_only:
            return WRITER_ONLY
        if (cls, name) in self.gil_shared:
            return GIL_SHARED
        return None

    @property
    def empty(self) -> bool:
        return not (self.guards or self.published or self.writer_only
                    or self.gil_shared or self.requires)


def _line_annotations(source: str) -> dict[int, tuple[str, str]]:
    """line number -> (kind, argument) for every annotation comment."""
    out: dict[int, tuple[str, str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        if "#" not in line:
            continue
        m = _ANN_RE.search(line)
        if m:
            out[i] = (m.group(1), m.group(2).strip())
    return out


def _self_targets(stmt: ast.stmt) -> list[str]:
    """Attribute names assigned via ``self.<name> = ...`` in a statement."""
    targets: list[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        targets = [stmt.target]
    out = []
    for t in targets:
        if (isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name)
                and t.value.id == "self"):
            out.append(t.attr)
    return out


def parse(source: str) -> ModuleAnnotations:
    ann = ModuleAnnotations()
    lines = _line_annotations(source)
    if not lines:
        return ann
    tree = ast.parse(source)
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        for node in ast.walk(cls):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                decos = node.decorator_list
                head = decos[0].lineno if decos else node.lineno
                for ln in (node.lineno, head - 1):
                    kind_arg = lines.get(ln)
                    if kind_arg and kind_arg[0] == REQUIRES:
                        locks = {s.strip() for s in kind_arg[1].split(",")
                                 if s.strip()}
                        ann.requires.setdefault(
                            (cls.name, node.name), set()).update(locks)
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                span = range(node.lineno, (node.end_lineno or node.lineno) + 1)
                kind_arg = next((lines[ln] for ln in span if ln in lines),
                                None)
                if kind_arg is None:
                    continue
                kind, arg = kind_arg
                for name in _self_targets(node):
                    key = (cls.name, name)
                    if kind == GUARDED_BY and arg:
                        ann.guards[key] = arg.split(",")[0].strip()
                    elif kind == PUBLISHED:
                        ann.published.add(key)
                    elif kind == WRITER_ONLY:
                        ann.writer_only.add(key)
                    elif kind == GIL_SHARED:
                        ann.gil_shared.add(key)
    return ann


__all__ = ["ModuleAnnotations", "parse", "GUARDED_BY", "REQUIRES",
           "PUBLISHED", "WRITER_ONLY", "GIL_SHARED"]
