"""CLI driver: ``python -m repro.analysis``.

Gathers the repo's own layout (the five concurrent modules for the
lock-discipline lint, every ``src/repro`` module for the cursor scan, the
``kernels/`` tree for layout+purity), applies the allowlist, prints one
line per finding (``path:line: [check] message  (ident)``) and exits
non-zero if anything unsuppressed remains — including stale allowlist
entries, so reviewed exceptions cannot outlive the code they excused.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from . import locks, protocol, purity
from .report import Allowlist, Finding, apply_allowlist

# The modules with cross-thread state; the lock lint runs ONLY where the
# annotation discipline is in force (everything else is single-threaded).
CONCURRENT_MODULES = [
    "src/repro/core/lifecycle.py",
    "src/repro/engine/engine.py",
    "src/repro/engine/device_backend.py",
    "src/repro/serve/query_service.py",
    "src/repro/serve/ingest_pipeline.py",
    "src/repro/core/sharded_index.py",
]

# Workload-schedule generators: must be pure functions of their seed (the
# traffic harness's determinism contract) — import-surface lint only.
SCHEDULE_MODULES = [
    "src/repro/serve/workload.py",
]

DEFAULT_ALLOWLIST = "analysis_allowlist.txt"


def _repo_root() -> str:
    # src/repro/analysis/__main__.py -> repo root is four levels up
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _python_files(root: str, subdir: str) -> list[tuple[str, str]]:
    out = []
    base = os.path.join(root, subdir)
    for dirpath, _dirnames, filenames in os.walk(base):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                out.append((path, os.path.relpath(path, root)))
    return sorted(out, key=lambda p: p[1])


def collect_findings(root: str) -> list[Finding]:
    findings: list[Finding] = []

    lock_files = [(os.path.join(root, rel), rel)
                  for rel in CONCURRENT_MODULES
                  if os.path.exists(os.path.join(root, rel))]
    findings.extend(locks.run(lock_files))

    src_files = _python_files(root, os.path.join("src", "repro"))
    cursor_files = [(p, rel) for p, rel in src_files
                    if os.sep + "analysis" + os.sep not in p]
    findings.extend(protocol.check_cursors(cursor_files))

    kernels_dir = os.path.join(root, "src", "repro", "kernels")
    if os.path.isdir(kernels_dir):
        findings.extend(protocol.check_kernels(kernels_dir, root))
        flavor_files = [
            (p, rel) for p, rel in _python_files(
                root, os.path.join("src", "repro", "kernels"))
            if os.path.basename(p) in ("ref.py", "kernel.py")]
        findings.extend(purity.run(flavor_files))

    for rel in SCHEDULE_MODULES:
        path = os.path.join(root, rel)
        if os.path.exists(path):
            with open(path, encoding="utf-8") as fh:
                findings.extend(purity.check_schedule_module(fh.read(), rel))

    return sorted(findings, key=lambda f: (f.path, f.line, f.symbol))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checker for the tiered engine")
    ap.add_argument("--root", default=_repo_root(),
                    help="repo root (default: inferred from the package)")
    ap.add_argument("--allowlist", default=None,
                    help=f"allowlist file (default: <root>/"
                         f"{DEFAULT_ALLOWLIST} if present)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON records")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root)
    allow_path = args.allowlist or os.path.join(root, DEFAULT_ALLOWLIST)
    allowlist = Allowlist.load(allow_path) \
        if os.path.exists(allow_path) else None

    findings = collect_findings(root)
    reported = apply_allowlist(findings, allowlist)
    suppressed = len(findings) - len(reported)
    stale = allowlist.stale() if allowlist else []

    if args.json:
        print(json.dumps({
            "findings": [{"check": f.check, "path": f.path, "line": f.line,
                          "symbol": f.symbol, "ident": f.ident,
                          "message": f.message} for f in reported],
            "suppressed": suppressed,
            "stale_allowlist": stale,
        }, indent=2))
    else:
        for f in reported:
            print(f)
        for ident in stale:
            print(f"stale allowlist entry (matched nothing): {ident}")
        tail = f"{len(reported)} finding(s)"
        if suppressed:
            tail += f", {suppressed} suppressed by allowlist"
        if stale:
            tail += f", {len(stale)} stale allowlist entr(y/ies)"
        print(f"repro.analysis: {tail}")

    return 1 if (reported or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
