"""Findings, stable identifiers, and the reviewed-exception allowlist.

Every check in :mod:`repro.analysis` reports :class:`Finding` records.  A
finding carries two addresses:

* ``path:line`` — where a human looks (printed, asserted by the tests);
* ``ident``     — a *stable* identifier (``check:file:symbol``) that does
  NOT include the line number, so an allowlist entry survives unrelated
  edits above it.  The allowlist file holds one ident per line
  (``#`` comments allowed); entries that match nothing are reported as
  stale so reviewed exceptions cannot silently outlive their reason.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    check: str          # e.g. "lock-discipline", "protocol", "kernel-purity"
    path: str           # repo-relative file path
    line: int
    symbol: str         # stable symbol, e.g. "FreezeManager.suffix_size.tier"
    message: str

    @property
    def ident(self) -> str:
        return f"{self.check}:{self.path}:{self.symbol}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.check}] {self.message}" \
               f"  ({self.ident})"


@dataclass
class Allowlist:
    """Reviewed exceptions: idents suppressed from the report."""

    entries: set[str] = field(default_factory=set)
    used: set[str] = field(default_factory=set)

    @classmethod
    def load(cls, path) -> "Allowlist":
        entries = set()
        with open(path, encoding="utf-8") as fh:
            for raw in fh:
                line = raw.split("#", 1)[0].strip()
                if line:
                    entries.add(line)
        return cls(entries=entries)

    def suppresses(self, finding: Finding) -> bool:
        if finding.ident in self.entries:
            self.used.add(finding.ident)
            return True
        return False

    def stale(self) -> list[str]:
        """Entries that matched no finding (the exception no longer exists)."""
        return sorted(self.entries - self.used)


def apply_allowlist(findings: list[Finding],
                    allowlist: Allowlist | None) -> list[Finding]:
    if allowlist is None:
        return list(findings)
    return [f for f in findings if not allowlist.suppresses(f)]


__all__ = ["Finding", "Allowlist", "apply_allowlist"]
