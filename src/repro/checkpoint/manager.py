"""Fault-tolerant checkpointing (save/restore with atomic publish).

Design points for thousand-node deployments, realized at library scale:

  * **atomicity** — a checkpoint directory is staged under ``.tmp-<step>``
    and published with a single ``os.rename`` (POSIX-atomic), so a crash
    mid-save can never corrupt the restore point;
  * **async save** — array host-transfer happens on the caller thread (cheap
    device->host copy), serialization runs on a background thread so the
    training step loop is not blocked (overlap of checkpoint I/O and
    compute);
  * **manifest** — pytree structure + dtypes/shapes in ``manifest.json``;
    every leaf is one ``.npy`` (sharded arrays are gathered host-side here;
    a multi-host deployment would write per-process shards keyed by
    ``process_index``, same layout);
  * **retention** — keep the newest ``keep`` checkpoints, never deleting the
    newest complete one (``keep=0`` degenerates to "newest only");
  * **restore** — ``latest_step()`` + ``restore(step)`` rebuilds the exact
    pytree; the trainer resumes from (step+1) and the deterministic data
    pipeline replays the right batch (see repro.data.lm).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        if keep < 0:
            raise ValueError(f"keep must be >= 0, got {keep}")
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._seq = 0  # per-save staging-dir discriminator

    # -- save -------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = True) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(x) for x in leaves]
        spec = {"treedef": str(treedef),
                "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                           for a in host],
                "step": step}
        # An in-flight async save must finish before the next save stages:
        # otherwise two threads race in the staging area and the publish
        # order (newest wins) is undefined.  The staging dir is additionally
        # unique per save within this process; cross-process leftovers are
        # swept by _gc at the next publish.
        self.wait()
        self._seq += 1
        tmp = os.path.join(self.dir, f".tmp-{step}-{self._seq}")

        def work():
            final = os.path.join(self.dir, f"step-{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, a in enumerate(host):
                np.save(os.path.join(tmp, f"leaf-{i}.npy"), a)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(spec, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        # the newest complete checkpoint is never deleted, even at keep=0
        steps = self.all_steps()
        drop = steps[:-self.keep] if self.keep > 0 else steps[:-1]
        for s in drop:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:010d}"),
                          ignore_errors=True)
        # sweep staging dirs orphaned by a crashed predecessor.  Running
        # here — we just published, so we are the directory's single writer
        # and saves are serialized through wait(), leaving no live staging
        # of our own — rather than in __init__ keeps restore-only instances
        # from ever deleting an active writer's in-flight staging dir.
        for name in os.listdir(self.dir):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- restore ----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like=None):
        """Rebuild the pytree saved at ``step``.

        ``like`` (an example pytree) supplies the treedef; leaves are loaded
        in flatten order.  Without ``like`` a flat list is returned.
        """
        path = os.path.join(self.dir, f"step-{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            spec = json.load(f)
        leaves = [np.load(os.path.join(path, f"leaf-{i}.npy"))
                  for i in range(len(spec["leaves"]))]
        if like is None:
            return leaves
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, leaves)
