from .adamw import adamw_init, adamw_update  # noqa: F401
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
