"""Optimizers as pure pytree transforms (no optax dependency).

AdamW with decoupled weight decay and global-norm clipping; row-wise Adagrad
for huge embedding tables (the recsys standard — state is one scalar per row,
1/D the memory of Adam).  All states are plain pytrees so pjit shards them
with the same rules as the parameters (ZeRO-3-style when params are sharded
on the data axis).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    """``state_dtype=bfloat16`` halves moment memory (PaLM-style) — the
    update still runs in f32 (moments are upcast per step)."""
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=state_dtype),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(p, g, m, v):
        sdt = m.dtype
        g = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(sdt), v.astype(sdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm


class RowAdagradState(NamedTuple):
    accum: jnp.ndarray  # (rows,) one scalar per embedding row


def row_adagrad_init(table: jnp.ndarray) -> RowAdagradState:
    return RowAdagradState(accum=jnp.zeros(table.shape[0], jnp.float32))


def row_adagrad_update(table, grad, state: RowAdagradState, lr: float = 0.01,
                       eps: float = 1e-8):
    """Row-wise Adagrad: accumulate mean-square per row (dense grad form)."""
    g2 = jnp.mean(jnp.square(grad.astype(jnp.float32)), axis=-1)
    accum = state.accum + g2
    scale = lr / (jnp.sqrt(accum) + eps)
    new = table.astype(jnp.float32) - scale[:, None] * grad.astype(jnp.float32)
    return new.astype(table.dtype), RowAdagradState(accum=accum)
