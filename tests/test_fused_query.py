"""Differential parity matrix for the fused decode→score→top-k launch.

The fused path has two flavours over ONE resident image pair: the Pallas
kernel (``flavor="pallas"``) and the inline reference (``flavor="ref"``,
the ``device`` backend).  Both run the same ``fused_tile`` math, so the
kernel must be **byte-identical** to the reference — same docids, same f32
score bits, same tie order — while both must agree with the host oracle.
The matrix covers the three fused workloads, doc- and word-level layouts,
a mid-stream freeze swap, and a delta-only query after ingest; plus the
resident-image amortization counters, the delta-compaction policy, and the
measured planner crossover table the benchmark sweep feeds.

Everything here runs on CPU (Pallas interpret mode) — the CI smoke job
selects the file via the ``pallas`` marker.
"""

import json

import numpy as np
import pytest

from repro.core import query as Q
from repro.engine import Engine, PlannerConfig, Query
from repro.engine.device_backend import fused_execute
from repro.engine.planner import CrossoverTable, Planner, TermStats
from repro.serve import QueryService

pytestmark = pytest.mark.pallas

MODES = ("conjunctive", "ranked_tfidf", "bm25")


@pytest.fixture(scope="module")
def zdocs():
    rng = np.random.default_rng(71)
    vocab = [f"w{i}" for i in range(90)]
    probs = 1.0 / np.arange(1, 91) ** 1.1
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(90, size=rng.integers(4, 30),
                                          p=probs)]
            for _ in range(220)]
    return vocab, docs


@pytest.fixture(scope="module")
def eng(zdocs):
    """150 docs collated into the resident frozen image, 70 in the delta:
    every fused launch below merges both images."""
    vocab, docs = zdocs
    e = Engine(B=64, growth="const")
    for d in docs[:150]:
        e.add_document(d)
    e.collate_now()
    for d in docs[150:]:
        e.add_document(d)
    return vocab, e


def _batch(vocab, mode, n=6, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        nt = int(rng.integers(1, 4))
        ts = tuple(vocab[i] for i in rng.choice(50, size=nt, replace=False))
        out.append(Query(terms=ts, mode=mode, k=10))
    return out


def _host_expected(e, query):
    if query.mode == "conjunctive":
        return Q.brute_conjunctive(e.index, query.terms), None
    if query.mode == "ranked_tfidf":
        return Q.ranked_disjunctive_taat(e.index, list(query.terms),
                                         k=query.k)
    return Q.ranked_bm25(e.index, list(query.terms), e.doclens_array(),
                         k=query.k)


# --------------------------------------------------------------------------
# pallas flavour ≡ ref flavour, byte for byte
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_pallas_byte_identical_to_ref(eng, mode):
    """The kernel and the inline reference share ``fused_tile``; nothing in
    the pallas_call plumbing may perturb a single bit of the output."""
    vocab, e = eng
    batch = _batch(vocab, mode, seed=3)
    e.resident.refresh()
    ref = fused_execute(e, e.resident, batch, mode, 10,
                        flavor="ref", interpret=True, name="ref")
    pal = fused_execute(e, e.resident, batch, mode, 10,
                        flavor="pallas", interpret=True, name="pallas")
    for r, p in zip(ref, pal):
        assert r.docids.tolist() == p.docids.tolist()
        if mode != "conjunctive":
            assert r.scores.tobytes() == p.scores.tobytes()


# --------------------------------------------------------------------------
# fused backends vs the host oracle (frozen + delta merged in one launch)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["device", "pallas"])
@pytest.mark.parametrize("mode", MODES)
def test_fused_matches_host_matrix(eng, backend, mode):
    vocab, e = eng
    for q in _batch(vocab, mode, seed=11):
        res = e.execute(Query(terms=q.terms, mode=mode, k=10,
                              backend=backend))
        assert res.backend == backend
        exp_d, exp_s = _host_expected(e, q)
        if mode == "conjunctive":
            assert res.docids.tolist() == exp_d.tolist()
        else:
            assert len(res.scores) == len(exp_s)
            assert np.allclose(np.sort(res.scores), np.sort(exp_s),
                               rtol=1e-5)
            # canonical tie order: score desc, docid asc within equal scores
            s, d = res.scores, res.docids
            assert (np.diff(s) <= 1e-12).all()
            ties = np.isclose(s[1:], s[:-1], rtol=0, atol=0)
            assert (np.diff(d)[ties] > 0).all()


@pytest.mark.parametrize("mode", MODES)
def test_fused_batch_equals_singletons(eng, mode):
    """Batched execution (one launch, query-major grid) returns exactly the
    per-query results — padding rows never leak."""
    vocab, e = eng
    batch = _batch(vocab, mode, n=5, seed=23)
    forced = [Query(terms=q.terms, mode=mode, k=10, backend="pallas")
              for q in batch]
    got = e.execute_many(forced)
    for q, r in zip(forced, got):
        single = e.execute(Query(terms=q.terms, mode=mode, k=10,
                                 backend="pallas"))
        assert r.docids.tolist() == single.docids.tolist()
        if mode != "conjunctive":
            assert r.scores.tobytes() == single.scores.tobytes()


# --------------------------------------------------------------------------
# word-level layouts: fused path refuses, host ≡ tiered still holds
# --------------------------------------------------------------------------


def test_word_level_fused_refuses_and_host_tiered_agree(zdocs):
    from repro.core.lifecycle import FreezePolicy

    vocab, docs = zdocs
    e = Engine(B=64, growth="const", word_level=True,
               tier_policy=FreezePolicy())
    for d in docs[:120]:
        e.add_document(d)
    e.lifecycle.freeze(blocking=True)
    for d in docs[120:150]:
        e.add_document(d)
    q = Query(terms=(vocab[2], vocab[5]), mode="ranked_tfidf", k=10)
    for backend in ("device", "pallas"):
        with pytest.raises(ValueError):
            e.execute(Query(terms=q.terms, mode=q.mode, k=10,
                            backend=backend))
    host = e.execute(Query(terms=q.terms, mode=q.mode, k=10,
                           backend="host"))
    tiered = e.execute(Query(terms=q.terms, mode=q.mode, k=10,
                             backend="tiered"))
    assert host.docids.tolist() == tiered.docids.tolist()
    assert np.allclose(host.scores, tiered.scores, rtol=1e-6)


# --------------------------------------------------------------------------
# lifecycle: freeze swap mid-stream, delta-only suffix, amortization
# --------------------------------------------------------------------------


def test_mid_stream_freeze_swap_stays_correct(zdocs):
    """A second collation mid-stream swaps the resident frozen image; the
    very next fused batch must serve from the new epoch and still match
    the host."""
    vocab, docs = zdocs
    e = Engine(B=64, growth="const")
    for d in docs[:100]:
        e.add_document(d)
    e.collate_now()
    for d in docs[100:140]:
        e.add_document(d)
    batch = _batch(vocab, "bm25", n=4, seed=5)
    forced = [Query(terms=q.terms, mode=q.mode, k=10, backend="pallas")
              for q in batch]
    e.execute_many(forced)
    assert e.resident.frozen_uploads == 1
    e.collate_now()                      # freeze swap: epoch 1 -> 2
    for d in docs[140:160]:
        e.add_document(d)
    got = e.execute_many(forced)
    assert e.resident.frozen_uploads == 2
    assert e.resident.epoch == 2
    for q, r in zip(batch, got):
        exp_d, exp_s = _host_expected(e, q)
        assert len(r.scores) == len(exp_s)
        assert np.allclose(np.sort(r.scores), np.sort(exp_s), rtol=1e-5)


def test_delta_only_query_after_ingest(zdocs):
    """Terms that exist ONLY in the post-freeze suffix are answered from
    the delta image without triggering a collation (immediate access)."""
    vocab, docs = zdocs
    e = Engine(B=64, growth="const")
    for d in docs[:80]:
        e.add_document(d)
    e.collate_now()
    fresh = [e.add_document(["qx1", "qx2", vocab[0]]) for _ in range(3)]
    before = e.stats().collations
    res = e.execute(Query(terms=("qx1", "qx2"), mode="conjunctive",
                          backend="pallas"))
    assert res.docids.tolist() == fresh
    assert e.stats().collations == before, "delta query forced a collation"
    host = Q.brute_conjunctive(e.index, ("qx1", "qx2"))
    assert res.docids.tolist() == host.tolist()


def test_resident_upload_amortized_across_batches(zdocs):
    """One freeze = one upload; every later fused batch (both flavours)
    reuses the resident image and ships only the delta suffix."""
    vocab, docs = zdocs
    e = Engine(B=64, growth="const")
    for d in docs[:100]:
        e.add_document(d)
    e.collate_now()
    for d in docs[100:120]:
        e.add_document(d)
    batch = _batch(vocab, "ranked_tfidf", n=4, seed=9)
    for backend in ("device", "pallas", "device"):
        e.execute_many([Query(terms=q.terms, mode=q.mode, k=10,
                              backend=backend) for q in batch])
    assert e.resident.frozen_uploads == 1
    assert e.stats().resident_uploads == 1
    assert e.resident.batches_served >= 3
    # ingest between batches refreshes the delta, not the frozen upload
    e.add_document([vocab[0], vocab[1]])
    e.execute_many([Query(terms=q.terms, mode=q.mode, k=10,
                          backend="pallas") for q in batch])
    assert e.resident.frozen_uploads == 1
    assert e.resident.batches_served >= 4


# --------------------------------------------------------------------------
# delta-compaction policy (fragmentation threshold)
# --------------------------------------------------------------------------


def test_compaction_policy_triggers_on_fragmented_delta(zdocs):
    """Past the fragmentation threshold an incremental refresh falls back
    to a full collation — the delta path is never the slower option."""
    vocab, docs = zdocs
    e = Engine(B=64, growth="const", delta_compact_frac=0.05,
               delta_compact_min_blocks=4)
    for d in docs[:60]:
        e.add_document(d)
    e.collate_now()
    for d in docs[60:140]:
        e.add_document(d)
    before = e.stats().collations
    res = e.execute(Query(terms=(vocab[0],), mode="ranked_tfidf", k=10,
                          backend="device"))
    assert e.stats().delta_compactions >= 1
    assert e.stats().collations > before
    exp_d, exp_s = _host_expected(e, Query(terms=(vocab[0],),
                                           mode="ranked_tfidf", k=10))
    assert np.allclose(np.sort(res.scores), np.sort(exp_s), rtol=1e-5)


def test_compaction_policy_spares_small_deltas(eng):
    """The absolute block floor keeps small fixtures on the honest
    incremental path: the module fixture's 70-doc delta must NOT compact."""
    vocab, e = eng
    e.execute(Query(terms=(vocab[0],), mode="conjunctive",
                    backend="device"))
    assert e.stats().delta_compactions == 0
    assert e.stats().collations == 1


# --------------------------------------------------------------------------
# measured crossover table -> planner routing
# --------------------------------------------------------------------------


def _rows():
    rows = []
    for size in (300, 1200):
        for batch in (1, 8, 32):
            rows.append({"workload": "bm25", "backend": "host",
                         "size": size, "batch": batch, "us_per_query": 100.0})
            # device wins from batch 8 at EVERY size
            rows.append({"workload": "bm25", "backend": "device",
                         "size": size, "batch": batch,
                         "us_per_query": 150.0 if batch < 8 else 60.0})
            # pallas wins at 32 on ONE size only -> conservative None
            rows.append({"workload": "bm25", "backend": "pallas",
                         "size": size, "batch": batch,
                         "us_per_query": 80.0 if (batch == 32 and
                                                  size == 300) else 140.0})
    return rows


def test_crossover_table_derivation():
    t = CrossoverTable.from_rows(_rows())
    assert t.min_batch["bm25"]["device"] == 8
    assert t.min_batch["bm25"]["pallas"] is None   # must win at every size


def test_planner_routes_by_measured_crossover():
    t = CrossoverTable.from_rows(_rows())
    p = Planner(PlannerConfig(crossover=t, pallas_min_postings=10 ** 9))
    stats = [TermStats(ft=50, nblocks=2)]
    q = Query(terms=("a",), mode="bm25", k=10)
    assert p.plan(q, 8, stats, device_capable=True).backend == "device"
    assert p.plan(q, 1, stats, device_capable=True).backend == "host"
    # a mode the sweep never measured keeps the static default
    q2 = Query(terms=("a",), mode="ranked_tfidf", k=10)
    assert p.plan(q2, 8, stats, device_capable=True).backend == "device"


def test_crossover_from_bench_round_trip(tmp_path):
    path = tmp_path / "bench.json"
    path.write_text(json.dumps({"crossover": {"rows": _rows()}}))
    assert CrossoverTable.from_bench(str(path)).min_batch == \
        CrossoverTable.from_rows(_rows()).min_batch


# --------------------------------------------------------------------------
# serving: whole-batch hand-off with intra-flush dedupe
# --------------------------------------------------------------------------


def test_query_service_hands_whole_batch_deduped(eng):
    vocab, e = eng
    calls = []
    real = e.execute_many

    def counting(queries):
        calls.append(len(queries))
        return real(queries)

    e.execute_many = counting
    try:
        svc = QueryService(e, max_batch=64, cache_size=0)
        q1 = Query(terms=(vocab[0], vocab[1]), mode="bm25", k=10)
        q2 = Query(terms=(vocab[2],), mode="bm25", k=10)
        t = [svc.submit(q) for q in (q1, q2, q1, q1)]
        svc.flush()
        assert calls == [2], "duplicates must collapse into one engine batch"
        assert all(x.done for x in t)
        assert t[0].result.docids.tolist() == t[2].result.docids.tolist()
        assert t[2].result is not t[0].result  # private copies
    finally:
        e.execute_many = real
