"""repro.analysis: static invariant checker + concurrency sanitizer (PR 7).

Each static check is exercised against a seeded fixture module carrying a
known violation (asserted by file:line), the allowlist semantics are pinned
(suppresses exactly one reviewed ident, flags stale entries), the runtime
sanitizer is driven through a seeded lock-order inversion and a seeded
unlocked race (plus the negatives: lock-protected and post-join accesses
stay clean), and the repo itself must come out clean end-to-end.
"""

import textwrap
import threading

import pytest

from repro.analysis import locks, protocol, purity
from repro.analysis.contracts import ContractCursor, ContractViolation, wrap
from repro.analysis.report import Allowlist, apply_allowlist
from repro.analysis.sanitizer import Sanitizer


def _write(tmp_path, name, source):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source).lstrip("\n"), encoding="utf-8")
    return str(p)


# --------------------------------------------------------------------------
# lock-discipline lint
# --------------------------------------------------------------------------

GUARDED_FIXTURE = """
    import threading


    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0              # guarded_by: _lock
            self.m = 0              # guarded_by: _lock

        def good(self):
            with self._lock:
                self.n += 1

        def bad_write(self):
            self.n += 1

        def bad_read(self):
            return self.m
"""


def test_lock_lint_guarded_field_violation(tmp_path):
    path = _write(tmp_path, "guarded_fixture.py", GUARDED_FIXTURE)
    findings = locks.run([(path, "guarded_fixture.py")])
    assert findings, "seeded guarded-field violation not detected"
    # the unlocked accesses are reported with file:line...
    assert {(f.path, f.line) for f in findings} \
        == {("guarded_fixture.py", 15), ("guarded_fixture.py", 18)}
    assert any(f.symbol == "Counter.bad_write.n" for f in findings)
    assert any(f.symbol == "Counter.bad_read.m" for f in findings)
    # ...and the with-lock access in good() is NOT
    assert not any("good" in f.symbol for f in findings)


PUBLISHED_FIXTURE = """
    import threading


    class Manager:
        def __init__(self):
            self._lock = threading.Lock()
            self.tier = None        # published
            self.epoch = 0          # published

        def _swap(self):            # requires: _lock
            self.tier = object()

        def swap_unlocked(self):
            self._swap()

        def torn(self):
            if self.tier is None:
                return 0
            return self.tier

        def publish_two(self, t, e):
            self.tier = t
            self.epoch = e

        def start(self):
            def work():
                self.epoch += 1
            threading.Thread(target=work).start()
"""


def test_lock_lint_published_protocol_and_requires(tmp_path):
    path = _write(tmp_path, "published_fixture.py", PUBLISHED_FIXTURE)
    findings = locks.run([(path, "published_fixture.py")])
    msgs = {f.symbol: f for f in findings}
    # requires-annotated method called without the lock
    assert "Manager.swap_unlocked._swap()" in msgs
    assert msgs["Manager.swap_unlocked._swap()"].line == 14
    # two loads of a published field in one function = torn read
    assert "Manager.torn.tier" in msgs
    # two published fields stored by one function = non-atomic publication
    assert "Manager.publish_two.epoch+tier" in msgs
    # read-modify-write of a published field from a thread target
    assert "Manager.start.work.epoch" in msgs


# --------------------------------------------------------------------------
# cursor protocol conformance
# --------------------------------------------------------------------------

CURSOR_FIXTURE = """
    class BadCursor:
        def __init__(self):
            self.docid = 0

        def next(self, n):
            return n

        def seek_geq(self):
            return False


    class WordPhantomCursor:
        def __init__(self):
            self.docid = 0
            self.exhausted = False

        def next(self):
            return False

        def seek_geq(self, target):
            return False
"""


def test_cursor_protocol_nonconformance(tmp_path):
    path = _write(tmp_path, "cursor_fixture.py", CURSOR_FIXTURE)
    findings = protocol.check_cursors([(path, "cursor_fixture.py")])
    by_symbol = {f.symbol: f for f in findings}
    assert by_symbol["BadCursor.next"].line == 5        # extra parameter
    assert by_symbol["BadCursor.seek_geq"].line == 8    # missing target
    assert "BadCursor.exhausted" in by_symbol           # missing member
    # word-level cursor without positions()
    assert by_symbol["WordPhantomCursor.positions"].line == 12
    assert all(f.path == "cursor_fixture.py" for f in findings)


# --------------------------------------------------------------------------
# kernel purity lint
# --------------------------------------------------------------------------

PURITY_FIXTURE = """
    import time


    def kern(x, n: int):
        if x > 0:
            y = x.item()
        z = float(x)
        while n > 1:
            n -= 1
        return z
"""


def test_kernel_purity_host_sync_and_traced_branch(tmp_path):
    path = _write(tmp_path, "purity_fixture.py", PURITY_FIXTURE)
    findings = purity.run([(path, "purity_fixture.py")])
    lines = {(f.symbol, f.line) for f in findings}
    assert ("import.time", 1) in lines          # clocks are forbidden
    assert ("kern.if", 5) in lines              # Python branch on a tracer
    assert ("kern.item", 6) in lines            # host sync
    assert ("kern.float", 7) in lines           # concretization
    # branching on the STATIC (int-annotated) parameter is the idiom: ok
    assert not any(s == "kern.while" for s, _ in lines)


def test_purity_passes_repo_kernel_idioms(tmp_path):
    ok = """
        TILE = 128


        def kernel(x, n_docs: int, tile: int = 128, mode: str = "c"):
            nb = x.shape[0]
            if nb % tile != 0:
                nb = nb + 1
            if mode == "conjunctive":
                shift = 1
                while shift < n_docs:
                    shift *= 2
            return x
    """
    path = _write(tmp_path, "ok_kernel.py", ok)
    assert purity.run([(path, "ok_kernel.py")]) == []


# --------------------------------------------------------------------------
# allowlist
# --------------------------------------------------------------------------


def test_allowlist_suppresses_exactly_one(tmp_path):
    path = _write(tmp_path, "guarded_fixture.py", GUARDED_FIXTURE)
    findings = locks.run([(path, "guarded_fixture.py")])
    target = next(f for f in findings if f.symbol == "Counter.bad_read.m")
    allow_file = tmp_path / "allow.txt"
    allow_file.write_text(
        f"# reviewed: read is benign in this fixture\n"
        f"{target.ident}\n"
        f"lock-discipline:guarded_fixture.py:Counter.gone.x  # stale\n",
        encoding="utf-8")
    allowlist = Allowlist.load(str(allow_file))
    reported = apply_allowlist(findings, allowlist)
    assert len(reported) == len(findings) - 1
    assert all(f.symbol != "Counter.bad_read.m" for f in reported)
    # idents are line-independent, so the entry survives edits above it
    assert ":18" not in target.ident and "Counter.bad_read.m" in target.ident
    # unmatched entries are stale — they must fail the run, not linger
    assert allowlist.stale() \
        == ["lock-discipline:guarded_fixture.py:Counter.gone.x"]


# --------------------------------------------------------------------------
# the repo itself: the acceptance criterion
# --------------------------------------------------------------------------


def test_static_pass_clean_on_repo():
    from repro.analysis.__main__ import _repo_root, collect_findings
    findings = collect_findings(_repo_root())
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_exit_zero_on_clean_repo(capsys):
    from repro.analysis.__main__ import main
    assert main([]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out


# --------------------------------------------------------------------------
# runtime contract wrapper
# --------------------------------------------------------------------------


class _ListCursor:
    """Minimal well-behaved doc-level cursor over a sorted docid list."""

    def __init__(self, ids):
        self.ids = list(ids)
        self.i = 0

    @property
    def docid(self):
        return self.ids[self.i]

    @property
    def exhausted(self):
        return self.i >= len(self.ids)

    def next(self):
        self.i += 1
        return not self.exhausted

    def seek_geq(self, target):
        while not self.exhausted and self.docid < target:
            self.i += 1
        return not self.exhausted


def test_contract_cursor_passes_well_behaved():
    cur = wrap(_ListCursor([1, 4, 9]), strict=True)
    assert isinstance(cur, ContractCursor)
    assert wrap(cur) is cur                     # idempotent
    assert cur.seek_geq(3) and cur.docid == 4
    assert cur.next() and cur.docid == 9
    assert not cur.seek_geq(10) and cur.exhausted


def test_contract_cursor_catches_violations():
    class LandsShort(_ListCursor):
        def seek_geq(self, target):
            return not self.exhausted           # never advances

    with pytest.raises(ContractViolation, match="seek_geq"):
        wrap(LandsShort([1, 4, 9])).seek_geq(5)

    class GoesBackwards(_ListCursor):
        def next(self):
            self.ids[self.i] -= 2
            return True

    cur = wrap(GoesBackwards([5, 5, 5]))
    with pytest.raises(ContractViolation, match="backwards"):
        cur.next()

    class BadPositions(_ListCursor):
        def positions(self):
            return [3, 3]

    with pytest.raises(ContractViolation, match="increasing"):
        wrap(BadPositions([1])).positions()


# --------------------------------------------------------------------------
# runtime sanitizer: lock-order inversions
# --------------------------------------------------------------------------


def test_sanitizer_detects_seeded_lock_order_inversion():
    """A -> B in one region, B -> A in another: the acquisition graph has a
    cycle, reported deterministically even though nothing deadlocked."""
    san = Sanitizer("inversion")
    a, b = san.lock("A"), san.lock("B")
    with a:
        with b:
            pass
    assert not san.findings                     # one order alone is fine
    with b:
        with a:
            pass
    assert len(san.findings) == 1
    f = san.findings[0]
    assert "lock-order inversion" in f.message
    assert "A" in f.message and "B" in f.message
    # reported once, not per re-occurrence
    with b:
        with a:
            pass
    assert len(san.findings) == 1


def test_sanitizer_inversion_across_threads():
    san = Sanitizer("inversion-mt")
    a, b = san.lock("outer"), san.lock("inner")
    order_ab = threading.Event()

    def t1():
        with a:
            with b:
                order_ab.set()

    def t2():
        order_ab.wait(timeout=10)
        with b:
            with a:
                pass

    ts = [threading.Thread(target=t1), threading.Thread(target=t2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert any("lock-order inversion" in f.message for f in san.findings)


def test_sanitizer_no_false_positive_on_consistent_order():
    san = Sanitizer("consistent")
    a, b = san.lock("A"), san.lock("B")
    for _ in range(3):
        with a:
            with b:
                pass
    with a:
        pass
    with b:
        pass
    assert not san.findings


# --------------------------------------------------------------------------
# runtime sanitizer: lockset race detection
# --------------------------------------------------------------------------


class _Box:
    def __init__(self):
        self.n = 0


def _run_pair(fn):
    start = threading.Barrier(2)
    hold = threading.Barrier(2)     # both threads alive across the window

    def worker():
        start.wait(timeout=10)
        fn()
        hold.wait(timeout=10)

    ts = [threading.Thread(target=worker) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()


def test_sanitizer_detects_unlocked_race():
    san = Sanitizer("race")
    box = san.shadow(_Box(), "n")

    def bump():
        for _ in range(5):
            box.n = box.n + 1

    _run_pair(bump)
    races = [f for f in san.findings if f.symbol.startswith("race.")]
    assert races and "_Box.n" in races[0].symbol


def test_sanitizer_clean_with_common_lock():
    san = Sanitizer("locked")
    box = san.shadow(_Box(), "n")
    guard = san.lock("guard")

    def bump():
        for _ in range(5):
            with guard:
                box.n = box.n + 1

    _run_pair(bump)
    assert not san.findings


def test_sanitizer_thread_termination_happens_before():
    """A join() is a synchronization point: the main thread reading what a
    finished worker wrote is NOT a race."""
    san = Sanitizer("join-hb")
    box = san.shadow(_Box(), "n")

    def fill():
        box.n = 42

    t = threading.Thread(target=fill)
    t.start()
    t.join()
    assert box.n == 42
    assert not san.findings


# --------------------------------------------------------------------------
# sanitizer-instrumented engine stress: clean run + seeded inversion caught
# --------------------------------------------------------------------------


def _stress_docs(n=80):
    import numpy as np
    rng = np.random.default_rng(99)
    vocab = [f"s{i}" for i in range(60)]
    return vocab, [[vocab[i] for i in rng.choice(60, size=12)]
                   for _ in range(n)]


def test_sanitizer_stress_ingest_freeze_query_clean():
    """ingest + background freeze + fan-out queries under full lock
    instrumentation and with the coordinator's slot accounting shadowed:
    the engine's locking must produce zero findings."""
    from repro.core.lifecycle import FreezePolicy
    from repro.core.sharded_index import ShardedEngine
    from repro.engine import Query

    vocab, docs = _stress_docs()
    san = Sanitizer("stress")
    san.enable()
    try:
        se = ShardedEngine(
            num_shards=2, B=64, growth="const",
            tier_policy=FreezePolicy(every_docs=8, background=True),
            max_in_flight=1)
        san.shadow(se.coordinator, "_in_flight", "peak_in_flight",
                   "deferrals", label="FreezeCoordinator")
        for i, d in enumerate(docs):
            se.add_document(d)
            if i % 11 == 5:
                se.execute(Query(terms=(vocab[3], vocab[7]),
                                 mode="conjunctive"))
        se.drain_freezes()
        assert se.coordinator.peak_in_flight >= 1
        se.close()
    finally:
        san.disable()
    assert not san.findings, san.report()


def test_sanitizer_stress_catches_seeded_inversion():
    """The same stress shape, but the test deliberately wraps some ingests
    in (A then B) and some queries in (B then A) — the sanitizer must
    catch the seeded lock-order inversion."""
    from repro.core.lifecycle import FreezePolicy
    from repro.core.sharded_index import ShardedEngine
    from repro.engine import Query

    vocab, docs = _stress_docs(40)
    san = Sanitizer("seeded")
    san.enable()
    try:
        se = ShardedEngine(
            num_shards=2, B=64, growth="const",
            tier_policy=FreezePolicy(every_docs=8, background=True),
            max_in_flight=1)
        ingest_mu = threading.Lock()    # instrumented: created by a test
        stats_mu = threading.Lock()     # module while enable() is active
        for i, d in enumerate(docs):
            if i % 2:
                with ingest_mu:
                    with stats_mu:
                        se.add_document(d)
            else:
                with stats_mu:
                    with ingest_mu:     # inverted order: the seeded bug
                        se.add_document(d)
        se.drain_freezes()
        se.close()
    finally:
        san.disable()
    assert any("lock-order inversion" in f.message for f in san.findings), \
        "seeded inversion went undetected"
