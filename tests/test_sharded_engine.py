"""ShardedEngine as a first-class engine: exact global ranked statistics
(byte-identical to a single-engine oracle), arithmetic round-robin docid
maps, parallel fan-out, coordinated freeze scheduling, and serving-cache
integration (ISSUE 5)."""

import threading

import numpy as np
import pytest

from repro.core import static_index as static_index_mod
from repro.core.lifecycle import FreezeCoordinator, FreezeManager, FreezePolicy
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, Query
from repro.serve import QueryService


@pytest.fixture(scope="module")
def stream_docs():
    rng = np.random.default_rng(1234)
    vocab = [f"t{i}" for i in range(120)]
    probs = 1.0 / np.arange(1, 121) ** 1.05
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(120, size=rng.integers(5, 40),
                                          p=probs)]
            for _ in range(320)]
    return vocab, docs


def _modes(word_level):
    base = ["conjunctive", "ranked_tfidf", "bm25"]
    if word_level:
        base += ["phrase", "proximity", "bm25_prox"]
    return base


def _assert_byte_identical(se, oracle, terms, mode, k=10):
    kw = dict(window=5) if mode == "proximity" else {}
    r = se.execute(Query(terms=terms, mode=mode, k=k, **kw))
    e = oracle.execute(Query(terms=terms, mode=mode, k=k, backend="host",
                             **kw))
    assert r.docids.tolist() == e.docids.tolist(), (mode, terms)
    if e.scores is not None:
        # byte-identical: same doubles, same canonical tie order — the
        # global-statistics exchange leaves no shard-local approximation
        assert np.array_equal(r.scores, e.scores), (mode, terms)


# --------------------------------------------------------------------------
# the acceptance differential: sharded ≡ single-engine oracle, all modes,
# with background freezes completing mid-stream under the coordinator
# --------------------------------------------------------------------------


@pytest.mark.parametrize("word_level", [False, True],
                         ids=["doc_level", "word_level"])
def test_sharded_byte_identical_to_oracle_during_freezes(
        stream_docs, word_level):
    vocab, docs = stream_docs
    se = ShardedEngine(
        num_shards=4, B=64, growth="const", word_level=word_level,
        tier_policy=FreezePolicy(every_docs=20, background=True),
        max_in_flight=1)
    oracle = Engine(B=64, growth="const", word_level=word_level)
    rng = np.random.default_rng(5 + word_level)

    def check(n=2):
        for _ in range(n):
            nt = int(rng.integers(1, 4))
            terms = tuple(vocab[i] for i in
                          rng.choice(60, size=nt, replace=False))
            for mode in _modes(word_level):
                _assert_byte_identical(se, oracle, terms, mode)

    for i, d in enumerate(docs):
        g = se.add_document(d)
        assert g == oracle.add_document(d)   # same global docid stream
        if i % 9 == 4:
            check()
    assert se.coordinator.peak_in_flight <= 1
    se.drain_freezes()
    assert all(e.lifecycle.freezes >= 1 for e in se.engines)
    assert se.coordinator.epoch == sum(e.lifecycle.epoch
                                       for e in se.engines) > 0
    check(6)                                 # after every tier swap settled


def test_sharded_device_batches_match_oracle(stream_docs):
    """Batched fan-out routes each shard to its device image (planner
    default); the rebased (N, f_t, avgdl) make device scores match the
    global oracle to f32 tolerance."""
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=2, B=64, growth="const")
    oracle = Engine(B=64, growth="const")
    for d in docs[:200]:
        se.add_document(d)
        oracle.add_document(d)
    se.collate_now()
    for d in docs[200:260]:
        se.add_document(d)
        oracle.add_document(d)
    rng = np.random.default_rng(17)
    for mode in ("ranked_tfidf", "bm25"):
        batch = [Query(terms=tuple(vocab[i] for i in
                                   rng.choice(40, size=2, replace=False)),
                       mode=mode, k=10) for _ in range(6)]
        res = se.execute_many(batch)
        assert all(r.backend == "device" for r in res)
        for r, q in zip(res, batch):
            e = oracle.execute(Query(terms=q.terms, mode=mode, k=10,
                                     backend="host"))
            assert np.allclose(np.sort(r.scores), np.sort(e.scores),
                               rtol=1e-4), (mode, q.terms)


# --------------------------------------------------------------------------
# round-robin docid arithmetic (no per-document maps)
# --------------------------------------------------------------------------


def test_round_robin_arithmetic(stream_docs):
    vocab, docs = stream_docs
    S = 3
    se = ShardedEngine(num_shards=S, B=64, growth="const")
    for g, d in enumerate(docs[:50], start=1):
        assert se.add_document(d) == g
    assert se.num_docs == 50
    # global g lives on shard (g-1) % S as local (g-1) // S + 1, and the
    # affine inverse globalizes exactly
    for s in range(S):
        locals_ = np.arange(1, se.engines[s].index.num_docs + 1)
        gids = se._globalize(s, locals_)
        assert ((gids - 1) % S == s).all()
        assert (((gids - 1) // S + 1) == locals_).all()
    # O(1) routing state: no per-document structures
    assert not hasattr(se, "_owner") and not hasattr(se, "_to_global")


def test_parallel_and_serial_fanout_agree(stream_docs):
    vocab, docs = stream_docs
    par = ShardedEngine(num_shards=3, B=64, growth="const", parallel=True)
    ser = ShardedEngine(num_shards=3, B=64, growth="const", parallel=False)
    assert par._pool is not None and ser._pool is None
    for d in docs[:90]:
        par.add_document(d)
        ser.add_document(d)
    rng = np.random.default_rng(23)
    for _ in range(5):
        terms = tuple(vocab[i] for i in rng.choice(40, size=2,
                                                   replace=False))
        for mode in ("conjunctive", "bm25"):
            a = par.execute(Query(terms=terms, mode=mode, k=10))
            b = ser.execute(Query(terms=terms, mode=mode, k=10))
            assert a.docids.tolist() == b.docids.tolist()
            if a.scores is not None:
                assert np.array_equal(a.scores, b.scores)


# --------------------------------------------------------------------------
# backend-set reporting (ISSUE-5 satellite: no more shard_res[0].backend)
# --------------------------------------------------------------------------


def test_fused_result_reports_backend_set(stream_docs):
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=2, B=64, growth="const",
                       tier_policy=FreezePolicy())
    for d in docs[:80]:
        se.add_document(d)
    # freeze ONLY shard 0: its planner now routes small queries to the
    # tiered backend while shard 1 stays on the host
    se.engines[0].lifecycle.freeze(blocking=True)
    r = se.execute(Query(terms=(vocab[40],), mode="conjunctive"))
    assert r.backend == "host+tiered", r.backend
    assert "sharded fan-out x2" in r.reason
    # homogeneous shards report the single backend, not a list of copies
    r2 = se.execute(Query(terms=(vocab[40],), mode="conjunctive",
                          backend="host"))
    assert r2.backend == "host"


# --------------------------------------------------------------------------
# FreezeCoordinator: the fleet encode budget
# --------------------------------------------------------------------------


class _FakeEngine:
    """Minimal engine for coordinator unit tests."""

    def __init__(self):
        from repro.core.index import DynamicIndex
        self.index = DynamicIndex(B=64, growth="const")

    def collate_now(self):
        pass


def test_coordinator_fifo_and_budget_unit():
    coord = FreezeCoordinator(max_in_flight=1)
    a = FreezeManager(_FakeEngine(), FreezePolicy())
    b = FreezeManager(_FakeEngine(), FreezePolicy())
    coord.register(a)
    coord.register(b)
    assert a.coordinator is coord and b.coordinator is coord
    assert coord.try_acquire(a)          # slot free -> granted
    assert not coord.try_acquire(b)      # budget exhausted -> queued
    assert coord.pending == 1
    assert not coord.try_acquire(b)      # still queued, not re-queued
    assert coord.pending == 1
    coord.release(a)
    assert coord.try_acquire(b)          # front of queue, slot free
    assert coord.pending == 0
    # FIFO fairness: a refused earlier manager may not be overtaken
    assert not coord.try_acquire(a)      # b holds the slot
    coord.release(b)
    assert not coord.try_acquire(b)      # a is ahead in the queue
    assert coord.try_acquire(a)
    coord.release(a)
    assert coord.peak_in_flight == 1
    assert coord.deferrals >= 3
    with pytest.raises(ValueError):
        FreezeCoordinator(max_in_flight=0)


@pytest.mark.parametrize("max_in_flight", [1, 2])
def test_coordinator_caps_concurrent_encodes(stream_docs, max_in_flight,
                                             monkeypatch):
    """The acceptance criterion: with num_shards=4 and an aggressive
    policy, concurrent background encodes never exceed ``max_in_flight``
    (measured INSIDE StaticIndex.freeze, not self-reported) while every
    document stays continuously queryable — differential-tested
    mid-freeze."""
    vocab, docs = stream_docs
    lock = threading.Lock()
    active = [0]
    peak = [0]
    real_freeze = static_index_mod.StaticIndex.freeze
    # handshake instead of a timing window: encodes HOLD their slot until
    # the ingest loop has observed the contention it asserts on, then the
    # gate opens for all later freezes (timeout only as a deadlock bound)
    gate = threading.Event()

    def slow_freeze(index, codec="bp128"):
        with lock:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        try:
            gate.wait(timeout=30)
            return real_freeze(index, codec)
        finally:
            with lock:
                active[0] -= 1

    monkeypatch.setattr(static_index_mod.StaticIndex, "freeze", slow_freeze)
    se = ShardedEngine(
        num_shards=4, B=64, growth="const",
        tier_policy=FreezePolicy(every_docs=12, background=True),
        max_in_flight=max_in_flight)
    oracle = Engine(B=64, growth="const")
    rng = np.random.default_rng(31)
    saw_in_flight = False
    for i, d in enumerate(docs[:240]):
        se.add_document(d)
        oracle.add_document(d)
        saw_in_flight |= any(e.lifecycle.in_flight for e in se.engines)
        if not gate.is_set() and (
                peak[0] >= max_in_flight
                if max_in_flight > 1 else se.coordinator.deferrals > 0):
            gate.set()          # contention observed: release the encodes
        if i % 6 == 2:
            terms = tuple(vocab[j] for j in
                          rng.choice(40, size=2, replace=False))
            _assert_byte_identical(se, oracle, terms, "bm25")
            _assert_byte_identical(se, oracle, terms, "conjunctive")
    gate.set()                  # unblock any straggling encode
    se.drain_freezes()
    assert saw_in_flight, "no background freeze ever overlapped the stream"
    assert peak[0] <= max_in_flight, \
        f"{peak[0]} concurrent encodes exceeded the budget {max_in_flight}"
    assert se.coordinator.peak_in_flight <= max_in_flight
    assert all(e.lifecycle.freezes >= 1 for e in se.engines), \
        "a shard starved: staggering must still freeze every shard"
    if max_in_flight == 1:
        assert se.coordinator.deferrals > 0, \
            "aggressive policy on 4 shards should have contended for slots"


def test_deferred_freeze_pumped_by_any_shard_ingest(stream_docs,
                                                    monkeypatch):
    """Liveness: the fleet shares one writer thread, so a shard whose slot
    request was refused retries on ANY fleet ingest — a queue-head shard
    that happens to receive no documents cannot wedge the FIFO."""
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=2, B=64, growth="const",
                       tier_policy=FreezePolicy(every_docs=10 ** 9,
                                                background=True),
                       max_in_flight=1)
    for d in docs[:41]:
        se.add_document(d)
    # shard 1's encode holds the slot until WE release it — the refusal
    # below is deterministic, not a race against a timed window
    real_freeze = static_index_mod.StaticIndex.freeze
    hold = threading.Event()

    def slow_freeze(index, codec="bp128"):
        hold.wait(timeout=30)
        return real_freeze(index, codec)

    monkeypatch.setattr(static_index_mod.StaticIndex, "freeze", slow_freeze)
    assert se.engines[1].lifecycle.freeze(blocking=False)
    # make shard 0 due and refused -> queued behind the busy slot
    mgr0 = se.engines[0].lifecycle
    monkeypatch.setattr(mgr0, "policy", FreezePolicy(every_docs=1,
                                                     background=True))
    assert not mgr0.maybe_freeze()            # slot busy -> deferred
    assert se.coordinator.pending == 1
    hold.set()                                # let the encode finish
    se.engines[1].lifecycle.wait()            # slot frees
    # the next ingest routes to shard 1 (num_docs=41 is odd -> global 42
    # lands on shard (42-1) % 2 = 1), NOT to queued shard 0 — only the
    # fleet-level pump can start shard 0's deferred freeze here
    assert se.num_docs % 2 == 1
    se.add_document(docs[41])
    assert mgr0.in_flight or mgr0.epoch == 1, \
        "queued freeze was not pumped by another shard's ingest"
    se.drain_freezes()
    assert mgr0.epoch >= 1
    se.close()


def test_failed_snapshot_releases_encode_slot(stream_docs, monkeypatch):
    """A collate/clone failure after the slot grant must release the slot —
    a leak would silently disable every later freeze in the fleet."""
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=2, B=64, growth="const",
                       tier_policy=FreezePolicy(), max_in_flight=1)
    for d in docs[:30]:
        se.add_document(d)
    eng = se.engines[0]

    def boom():
        raise MemoryError("collation failed")

    monkeypatch.setattr(eng, "collate_now", boom)
    with pytest.raises(MemoryError):
        eng.lifecycle.freeze(blocking=False)
    monkeypatch.undo()
    assert se.coordinator.in_flight == 0, "encode slot leaked"
    # the budget is intact: both shards can still freeze
    assert se.engines[1].lifecycle.freeze(blocking=True)
    assert se.engines[0].lifecycle.freeze(blocking=True)
    se.close()


def test_close_releases_pool(stream_docs):
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=3, B=64, growth="const")
    for d in docs[:30]:
        se.add_document(d)
    assert se._pool is not None
    se.close()
    assert se._pool is None
    se.close()                                # idempotent
    # still serves, just serially
    r = se.execute(Query(terms=(vocab[0],), mode="conjunctive"))
    assert len(r.docids) > 0
    with ShardedEngine(num_shards=2, B=64, growth="const") as ctx:
        ctx.add_document(docs[0])
        assert ctx._pool is not None
    assert ctx._pool is None


def test_blocking_freeze_waits_for_budget(stream_docs):
    """A synchronous freeze under a coordinator still respects the encode
    budget: it waits for the in-flight background encode, never runs
    beside it."""
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=2, B=64, growth="const",
                       tier_policy=FreezePolicy(), max_in_flight=1)
    for d in docs[:60]:
        se.add_document(d)
    assert se.engines[0].lifecycle.freeze(blocking=False)   # takes the slot
    se.engines[1].lifecycle.freeze(blocking=True)           # must wait
    se.drain_freezes()
    assert se.coordinator.peak_in_flight == 1
    assert se.engines[0].lifecycle.epoch == 1
    assert se.engines[1].lifecycle.epoch == 1


# --------------------------------------------------------------------------
# serving-cache integration (ISSUE-5 satellite: no silent cache bypass)
# --------------------------------------------------------------------------


def test_sharded_results_are_cached_and_invalidated(stream_docs):
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=3, B=64, growth="const",
                       tier_policy=FreezePolicy())
    svc = QueryService(se, max_batch=4, cache_size=32)
    for d in docs[:60]:
        svc.ingest(d)
    q = Query(terms=(vocab[0], vocab[3]), mode="bm25", k=10)
    r1 = svc.query(q)
    assert svc.cache_misses == 1 and svc.cache_hits == 0
    r2 = svc.query(q)                     # version+epoch unchanged -> HIT
    assert svc.cache_hits == 1
    assert r2.docids.tolist() == r1.docids.tolist()
    assert np.array_equal(r2.scores, r1.scores)
    # ingest bumps the composite version -> old entries unreachable
    svc.ingest(docs[60])
    svc.query(q)
    assert svc.cache_misses == 2
    # ANY shard's tier swap bumps the composite epoch -> invalidated too
    svc.query(q)
    assert svc.cache_hits == 2
    se.engines[1].lifecycle.freeze(blocking=True)
    r3 = svc.query(q)
    assert svc.cache_misses == 3, \
        "a shard tier swap must invalidate the sharded result cache"
    # and the post-swap result is still the oracle's
    oracle = Engine(B=64, growth="const")
    for d in docs[:61]:
        oracle.add_document(d)
    e = oracle.execute(Query(terms=q.terms, mode="bm25", k=10,
                             backend="host"))
    assert r3.docids.tolist() == e.docids.tolist()
    assert np.array_equal(r3.scores, e.scores)


# --------------------------------------------------------------------------
# composite observability
# --------------------------------------------------------------------------


def test_incremental_gft_cache_matches_naive_walk(stream_docs):
    """The per-shard aligned global-f_t arrays (value-updated at ingest,
    suffix-extended at read) must always equal the naive dict walk over
    the shard vocabulary — including terms a shard interns late and device
    refreshes interleaved with ingest."""
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=3, B=64, growth="const")
    rng = np.random.default_rng(41)
    for i, d in enumerate(docs[:150]):
        se.add_document(d)
        if i % 25 == 7:
            # materialize + refresh the cached arrays mid-stream (the
            # device path is what reads them)
            se.execute_many([Query(terms=(vocab[0], vocab[1]), mode="bm25",
                                   k=5)] * 4)
        if i % 10 == 3:
            for e in se.engines:
                got = e.global_fts()
                naive = np.asarray([se._ft.get(tb, 0) for tb in e.vocab],
                                   dtype=np.int64)
                assert np.array_equal(got, naive)
    se.close()


def test_composite_stats(stream_docs):
    vocab, docs = stream_docs
    se = ShardedEngine(num_shards=3, B=64, growth="const",
                       tier_policy=FreezePolicy(every_docs=30,
                                                background=False))
    for d in docs[:100]:
        se.add_document(d)
    se.execute(Query(terms=(vocab[0],), mode="conjunctive"))
    s = se.stats()
    assert s.num_docs == 100 == se.num_docs
    assert s.num_shards == 3
    assert s.num_postings == sum(e.index.num_postings for e in se.engines)
    assert s.num_postings == se.num_postings
    assert s.freezes == sum(e.lifecycle.freezes for e in se.engines) > 0
    assert s.tier_epoch == se.coordinator.epoch > 0
    assert s.queries == 3                 # one per shard fan-out
    assert sum(s.by_backend.values()) == 3
    assert s.vocab_size == len({t for d in docs[:100] for t in d})
