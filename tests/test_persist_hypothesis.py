"""Hypothesis properties for engine snapshots: random document streams x
{bp128, interp} x {doc-level, word-level} -> snapshot -> restore -> every
query mode answers byte-identically; manifest round-trip is idempotent.

Own module so the importorskip cannot take the deterministic persist tests
with it (same split as test_static_hypothesis.py)."""

import json
import os

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core import persist  # noqa: E402
from repro.core.lifecycle import FreezePolicy  # noqa: E402
from repro.engine import Engine, Query  # noqa: E402

TERMS = [f"t{i}" for i in range(40)]

# a document is 1..25 term ids; a stream is 0..60 documents — enough for
# several freeze horizons at every_docs=16 while staying fast per example
doc_stream = hst.lists(
    hst.lists(hst.integers(0, len(TERMS) - 1), min_size=1, max_size=25),
    min_size=0, max_size=60)


def _probes(word_level):
    qs = []
    for t in ("t0", "t1"):
        qs.append(Query(terms=(t,), mode="conjunctive"))
    qs += [Query(terms=("t0", "t1"), mode="conjunctive"),
           Query(terms=("t0", "t2"), mode="ranked_tfidf", k=8),
           Query(terms=("t1", "t2"), mode="bm25", k=8)]
    if word_level:
        qs += [Query(terms=("t0", "t1"), mode="phrase"),
               Query(terms=("t0", "t2"), mode="proximity", window=4),
               Query(terms=("t0", "t1"), mode="bm25_prox", k=8)]
    return qs


def _fingerprint(eng, word_level):
    out = []
    for q in _probes(word_level):
        r = eng.execute(q)
        out.append((r.docids.tobytes(),
                    None if r.scores is None else r.scores.tobytes()))
    return out


@pytest.mark.parametrize("word_level", [False, True])
@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(deadline=None)
@given(stream=doc_stream)
def test_snapshot_restore_byte_identical(tmp_path_factory, word_level,
                                         codec, stream):
    """Any ingest stream, any codec, either granularity: the restored
    engine's answers are indistinguishable at the byte level — docids,
    score doubles, tie order — across every supported query mode."""
    root = str(tmp_path_factory.mktemp("snap"))
    eng = Engine(word_level=word_level,
                 tier_policy=FreezePolicy(codec=codec, every_docs=16,
                                          background=False))
    for doc in stream:
        eng.add_document([TERMS[i] for i in doc])
    eng.snapshot(root)
    restored = Engine.restore(root)
    assert restored.index.num_docs == eng.index.num_docs
    assert restored.lifecycle.epoch == eng.lifecycle.epoch
    assert _fingerprint(eng, word_level) == _fingerprint(restored, word_level)


@settings(deadline=None, max_examples=25)
@given(stream=doc_stream)
def test_manifest_round_trip_idempotent(tmp_path_factory, stream):
    """snapshot(restore(snapshot(E))) writes a byte-identical manifest and
    identical artifact CRCs: persistence is a fixed point, so repeated
    backup/restore cycles cannot drift."""
    root_a = str(tmp_path_factory.mktemp("a"))
    root_b = str(tmp_path_factory.mktemp("b"))
    eng = Engine(tier_policy=FreezePolicy(every_docs=16, background=False))
    for doc in stream:
        eng.add_document([TERMS[i] for i in doc])
    snap_a = eng.snapshot(root_a)
    restored = Engine.restore(root_a)
    snap_b = restored.snapshot(root_b)
    raw_a = open(os.path.join(snap_a, persist.MANIFEST), "rb").read()
    raw_b = open(os.path.join(snap_b, persist.MANIFEST), "rb").read()
    assert raw_a == raw_b
    # ... and the artifacts themselves, via their recorded checksums
    man = json.loads(raw_a)
    assert man["files"] == json.loads(raw_b)["files"]
