"""StaticIndex as a serving tier: empty/singleton guards, bp128 skip-table
seek, cursor protocol differentials, and hypothesis round-trip properties
for both codecs (empty, singleton, dense-range, large-gap lists)."""

import numpy as np
import pytest

from repro.core.index import DynamicIndex
from repro.core.query import ChainedCursor, PostingsCursor, \
    conjunctive_from_cursors
from repro.core.static_index import BP_BLOCK, StaticIndex


def _roundtrip(codec, docids, fs):
    st = StaticIndex(codec)
    st.add_list(b"t", np.asarray(docids, np.int64), np.asarray(fs, np.int64))
    d, f = st.postings(b"t")
    assert d.tolist() == list(docids)
    assert f.tolist() == list(fs)
    return st


# --------------------------------------------------------------------------
# deterministic edge cases (run everywhere, no hypothesis needed)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["bp128", "interp"])
class TestEdgeLists:
    def test_empty_list_does_not_crash(self, codec):
        """Regression: docids[-1] raised IndexError on empty lists."""
        st = _roundtrip(codec, [], [])
        assert st.postings_iter(b"t") is None
        assert st.ft(b"t") == 0
        assert st.num_postings == 0
        assert st.total_bytes() > 0  # vocabulary entry still accounted

    def test_singleton(self, codec):
        st = _roundtrip(codec, [7], [3])
        c = st.postings_iter(b"t")
        assert (c.docid, c.payload) == (7, 3)
        assert not c.next() and c.exhausted

    def test_singleton_docid_one(self, codec):
        # fully-dense degenerate range: interp codes zero bits for docids
        _roundtrip(codec, [1], [1])

    def test_dense_range(self, codec):
        n = 3 * BP_BLOCK + 17
        _roundtrip(codec, list(range(1, n + 1)), [1] * n)

    def test_large_gaps(self, codec):
        rng = np.random.default_rng(8)
        docids = np.cumsum(rng.integers(1, 1 << 24, 400))
        fs = rng.integers(1, 100, 400)
        _roundtrip(codec, docids.tolist(), fs.tolist())

    def test_freeze_includes_every_term(self, codec, zipf_docs):
        vocab, docs = zipf_docs
        idx = DynamicIndex(B=64, growth="const")
        for d in docs[:120]:
            idx.add_document(d)
        st = StaticIndex.freeze(idx, codec)
        assert st.num_docs == 120
        assert st.num_postings == idx.num_postings
        for t in vocab[:100]:
            d1, f1 = idx.postings(t)
            assert st.ft(t) == len(d1)


# --------------------------------------------------------------------------
# cursor protocol: next / seek_geq differential against the decoded arrays
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_cursor_full_iteration_matches_decode(codec):
    rng = np.random.default_rng(21)
    docids = np.cumsum(rng.integers(1, 50, 5 * BP_BLOCK + 3))
    fs = rng.integers(1, 30, len(docids))
    st = _roundtrip(codec, docids.tolist(), fs.tolist())
    c = st.postings_iter(b"t")
    got = []
    while True:
        got.append((c.docid, c.payload))
        if not c.next():
            break
    assert got == list(zip(docids.tolist(), fs.tolist()))


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_cursor_seek_geq_differential(codec):
    rng = np.random.default_rng(13)
    docids = np.cumsum(rng.integers(1, 40, 4 * BP_BLOCK))
    fs = rng.integers(1, 9, len(docids))
    st = _roundtrip(codec, docids.tolist(), fs.tolist())
    for _ in range(150):
        c = st.postings_iter(b"t")
        for target in np.sort(rng.integers(0, int(docids[-1]) + 20, 4)):
            ok = c.seek_geq(int(target))
            k = int(np.searchsorted(docids, target, side="left"))
            if k >= len(docids):
                assert not ok and c.exhausted
                break
            assert ok and c.docid == docids[k] and c.payload == fs[k]


def test_bp128_seek_decodes_single_block():
    """The skip table must land seeks on one block, not scan the list."""
    rng = np.random.default_rng(5)
    docids = np.cumsum(rng.integers(1, 20, 8 * BP_BLOCK))
    fs = np.ones(len(docids), np.int64)
    st = _roundtrip("bp128", docids.tolist(), fs.tolist())
    c = st.postings_iter(b"t")
    target = int(docids[6 * BP_BLOCK + 5])
    assert c.seek_geq(target) and c.docid == target
    assert c._blk == 6  # jumped straight to the containing block


def test_block_cache_shared_across_cursors(monkeypatch):
    """A fresh cursor per query must not re-decode blocks an earlier cursor
    already decoded: decoded blocks are cached on the shared TermList."""
    import repro.core.static_index as si

    rng = np.random.default_rng(7)
    docids = np.cumsum(rng.integers(1, 20, 4 * BP_BLOCK))
    fs = np.ones(len(docids), np.int64)
    st = _roundtrip("bp128", docids.tolist(), fs.tolist())

    calls = []
    real = si.bp_decode
    monkeypatch.setattr(si, "bp_decode", lambda n, r: calls.append(n) or real(n, r))

    target = int(docids[2 * BP_BLOCK + 3])
    c1 = st.postings_iter(b"t")
    assert c1.seek_geq(target) and c1.docid == target
    first = len(calls)
    assert first > 0
    # a second cursor over the same term hits the cache for block 0 (eager
    # load) and the seek target block — zero new decode work
    c2 = st.postings_iter(b"t")
    assert c2.seek_geq(target) and c2.docid == target
    assert len(calls) == first
    # within-block re-seek on the same cursor is also free
    assert c1.seek_geq(target + 1)
    assert len(calls) == first


def test_chained_cursor_spans_tiers(zipf_docs):
    """ChainedCursor(static prefix, dynamic suffix) behaves like one cursor
    over the whole collection."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64, growth="const")
    for d in docs[:80]:
        idx.add_document(d)
    st = StaticIndex.freeze(idx, "bp128")
    horizon = idx.num_docs
    for d in docs[80:120]:
        idx.add_document(d)
    for t in vocab[:40]:
        full_d, full_f = idx.postings(t)
        parts = [st.postings_iter(t)]
        h = idx.lookup(t)
        if h is not None:
            c = PostingsCursor(idx.store, h)
            if c.seek_geq(horizon + 1):
                parts.append(c)
        chained = ChainedCursor(parts)
        if len(full_d) == 0:
            assert chained.exhausted
            continue
        got = []
        while True:
            got.append((chained.docid, chained.payload))
            if not chained.next():
                break
        assert got == list(zip(full_d.tolist(), full_f.tolist()))


def test_conjunctive_from_cursors_handles_missing():
    assert conjunctive_from_cursors([]).tolist() == []
    assert conjunctive_from_cursors([None]).tolist() == []
    st = StaticIndex("bp128")
    st.add_list(b"a", np.array([1, 2, 3]), np.array([1, 1, 1]))
    st.add_list(b"b", np.array([2, 3, 9]), np.array([1, 1, 1]))
    out = conjunctive_from_cursors([st.postings_iter(b"a"),
                                    st.postings_iter(b"b")])
    assert out.tolist() == [2, 3]


# --------------------------------------------------------------------------
# word-level ⟨d,w⟩ lists: deterministic edge cases + cursor differentials
# (ISSUE 3; the randomized properties live in test_static_hypothesis.py)
# --------------------------------------------------------------------------


def _word_roundtrip(codec, occ_docids, wgaps):
    """Encode an occurrence stream, decode it back bit-exactly."""
    st = StaticIndex(codec, word_level=True)
    st.add_list(b"t", np.asarray(occ_docids, np.int64),
                np.asarray(wgaps, np.int64))
    d, w = st.postings(b"t")
    assert d.tolist() == list(occ_docids)
    assert w.tolist() == list(wgaps)
    return st


@pytest.mark.parametrize("codec", ["bp128", "interp"])
class TestWordEdgeLists:
    def test_empty(self, codec):
        st = _word_roundtrip(codec, [], [])
        assert st.postings_iter(b"t") is None
        assert st.ft(b"t") == 0 and st.num_postings == 0

    def test_singleton_occurrence(self, codec):
        st = _word_roundtrip(codec, [3], [7])
        c = st.postings_iter(b"t")
        assert (c.docid, c.payload) == (3, 1)
        assert c.positions().tolist() == [7]
        assert not c.next() and c.exhausted

    def test_repeated_term_single_doc(self, codec):
        # one doc, five occurrences: "a x a a y a a"-style w-gaps
        st = _word_roundtrip(codec, [1] * 5, [1, 2, 1, 2, 1])
        c = st.postings_iter(b"t")
        assert (c.docid, c.payload) == (1, 5)
        assert c.positions().tolist() == [1, 3, 4, 6, 7]
        assert st.ft(b"t") == 5  # word-level f_t counts occurrences

    def test_max_gap_positions(self, codec):
        # docid and position gaps near the dynamic codec's practical range
        occ = [1, 1, 1 << 22, 1 << 22]
        wg = [1 << 20, 1 << 19, 5, 1 << 21]
        st = _word_roundtrip(codec, occ, wg)
        c = st.postings_iter(b"t")
        assert c.positions().tolist() == [1 << 20, (1 << 20) + (1 << 19)]
        assert c.seek_geq(2) and c.docid == 1 << 22
        assert c.positions().tolist() == [5, 5 + (1 << 21)]

    def test_word_freeze_matches_dynamic(self, codec, zipf_docs):
        vocab, docs = zipf_docs
        idx = DynamicIndex(B=64, word_level=True)
        for d in docs[:60]:
            idx.add_document(d)
        st = StaticIndex.freeze(idx, codec)
        assert st.word_level and st.num_postings == idx.num_postings
        for t in vocab[:60]:
            d1, w1 = idx.postings(t)
            d2, w2 = st.postings(t)
            assert d1.tolist() == d2.tolist()
            assert w1.tolist() == w2.tolist()
            assert st.ft(t) == idx.ft(t)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_word_cursor_matches_grouped_decode(codec):
    """Cursor iteration (unique docs, counts, lazy positions) must agree
    with the one-shot grouped decode, across many 128-blocks."""
    rng = np.random.default_rng(33)
    n_docs = 3 * BP_BLOCK + 40
    occ, wg = [], []
    for d in np.cumsum(rng.integers(1, 6, n_docs)):
        k = int(rng.integers(1, 5))
        occ += [int(d)] * k
        wg += rng.integers(1, 50, k).tolist()
    st = _word_roundtrip(codec, occ, wg)
    udocs, counts, wgaps = st.word_postings(b"t")
    starts = np.cumsum(counts) - counts
    c = st.postings_iter(b"t")
    i = 0
    while True:
        assert (c.docid, c.payload) == (udocs[i], counts[i])
        lo = int(starts[i])
        exp = np.cumsum(wgaps[lo:lo + int(counts[i])])
        assert c.positions().tolist() == exp.tolist()
        i += 1
        if not c.next():
            break
    assert i == len(udocs)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_word_cursor_seek_geq_differential(codec):
    rng = np.random.default_rng(29)
    n_docs = 4 * BP_BLOCK
    occ, wg = [], []
    for d in np.cumsum(rng.integers(1, 9, n_docs)):
        k = int(rng.integers(1, 4))
        occ += [int(d)] * k
        wg += rng.integers(1, 30, k).tolist()
    st = _word_roundtrip(codec, occ, wg)
    udocs, counts, wgaps = st.word_postings(b"t")
    starts = np.cumsum(counts) - counts
    for _ in range(120):
        c = st.postings_iter(b"t")
        for target in np.sort(rng.integers(0, int(udocs[-1]) + 15, 4)):
            ok = c.seek_geq(int(target))
            k = int(np.searchsorted(udocs, target, side="left"))
            if k >= len(udocs):
                assert not ok and c.exhausted
                break
            assert ok and c.docid == udocs[k] and c.payload == counts[k]
            lo = int(starts[k])
            exp = np.cumsum(wgaps[lo:lo + int(counts[k])])
            assert c.positions().tolist() == exp.tolist()


def test_word_chained_cursor_positions_span_tiers(zipf_docs):
    """ChainedCursor(static word cursor, dynamic WordPostingsCursor) serves
    docids, counts, AND positions identically to a pure dynamic walk."""
    from repro.core.query import WordPostingsCursor, word_cursor
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64, word_level=True)
    for d in docs[:50]:
        idx.add_document(d)
    st = StaticIndex.freeze(idx, "bp128")
    horizon = idx.num_docs
    for d in docs[50:80]:
        idx.add_document(d)
    for t in vocab[:40]:
        parts = [st.postings_iter(t)]
        h = idx.lookup(t)
        if h is not None:
            c = PostingsCursor(idx.store, h)
            if c.seek_geq(horizon + 1):
                parts.append(WordPostingsCursor(c))
        chained = ChainedCursor(parts)
        ref = word_cursor(idx, t)
        if ref is None:
            assert chained.exhausted
            continue
        while True:
            assert (chained.docid, chained.payload) == (ref.docid, ref.payload)
            assert chained.positions().tolist() == ref.positions().tolist()
            a, b = chained.next(), ref.next()
            assert a == b
            if not a:
                break
