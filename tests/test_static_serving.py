"""StaticIndex as a serving tier: empty/singleton guards, bp128 skip-table
seek, cursor protocol differentials, and hypothesis round-trip properties
for both codecs (empty, singleton, dense-range, large-gap lists)."""

import numpy as np
import pytest

from repro.core.index import DynamicIndex
from repro.core.query import ChainedCursor, PostingsCursor, \
    conjunctive_from_cursors
from repro.core.static_index import BP_BLOCK, StaticIndex


def _roundtrip(codec, docids, fs):
    st = StaticIndex(codec)
    st.add_list(b"t", np.asarray(docids, np.int64), np.asarray(fs, np.int64))
    d, f = st.postings(b"t")
    assert d.tolist() == list(docids)
    assert f.tolist() == list(fs)
    return st


# --------------------------------------------------------------------------
# deterministic edge cases (run everywhere, no hypothesis needed)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["bp128", "interp"])
class TestEdgeLists:
    def test_empty_list_does_not_crash(self, codec):
        """Regression: docids[-1] raised IndexError on empty lists."""
        st = _roundtrip(codec, [], [])
        assert st.postings_iter(b"t") is None
        assert st.ft(b"t") == 0
        assert st.num_postings == 0
        assert st.total_bytes() > 0  # vocabulary entry still accounted

    def test_singleton(self, codec):
        st = _roundtrip(codec, [7], [3])
        c = st.postings_iter(b"t")
        assert (c.docid, c.payload) == (7, 3)
        assert not c.next() and c.exhausted

    def test_singleton_docid_one(self, codec):
        # fully-dense degenerate range: interp codes zero bits for docids
        _roundtrip(codec, [1], [1])

    def test_dense_range(self, codec):
        n = 3 * BP_BLOCK + 17
        _roundtrip(codec, list(range(1, n + 1)), [1] * n)

    def test_large_gaps(self, codec):
        rng = np.random.default_rng(8)
        docids = np.cumsum(rng.integers(1, 1 << 24, 400))
        fs = rng.integers(1, 100, 400)
        _roundtrip(codec, docids.tolist(), fs.tolist())

    def test_freeze_includes_every_term(self, codec, zipf_docs):
        vocab, docs = zipf_docs
        idx = DynamicIndex(B=64, growth="const")
        for d in docs[:120]:
            idx.add_document(d)
        st = StaticIndex.freeze(idx, codec)
        assert st.num_docs == 120
        assert st.num_postings == idx.num_postings
        for t in vocab[:100]:
            d1, f1 = idx.postings(t)
            assert st.ft(t) == len(d1)


# --------------------------------------------------------------------------
# cursor protocol: next / seek_geq differential against the decoded arrays
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_cursor_full_iteration_matches_decode(codec):
    rng = np.random.default_rng(21)
    docids = np.cumsum(rng.integers(1, 50, 5 * BP_BLOCK + 3))
    fs = rng.integers(1, 30, len(docids))
    st = _roundtrip(codec, docids.tolist(), fs.tolist())
    c = st.postings_iter(b"t")
    got = []
    while True:
        got.append((c.docid, c.payload))
        if not c.next():
            break
    assert got == list(zip(docids.tolist(), fs.tolist()))


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_cursor_seek_geq_differential(codec):
    rng = np.random.default_rng(13)
    docids = np.cumsum(rng.integers(1, 40, 4 * BP_BLOCK))
    fs = rng.integers(1, 9, len(docids))
    st = _roundtrip(codec, docids.tolist(), fs.tolist())
    for _ in range(150):
        c = st.postings_iter(b"t")
        for target in np.sort(rng.integers(0, int(docids[-1]) + 20, 4)):
            ok = c.seek_geq(int(target))
            k = int(np.searchsorted(docids, target, side="left"))
            if k >= len(docids):
                assert not ok and c.exhausted
                break
            assert ok and c.docid == docids[k] and c.payload == fs[k]


def test_bp128_seek_decodes_single_block():
    """The skip table must land seeks on one block, not scan the list."""
    rng = np.random.default_rng(5)
    docids = np.cumsum(rng.integers(1, 20, 8 * BP_BLOCK))
    fs = np.ones(len(docids), np.int64)
    st = _roundtrip("bp128", docids.tolist(), fs.tolist())
    c = st.postings_iter(b"t")
    target = int(docids[6 * BP_BLOCK + 5])
    assert c.seek_geq(target) and c.docid == target
    assert c._blk == 6  # jumped straight to the containing block


def test_chained_cursor_spans_tiers(zipf_docs):
    """ChainedCursor(static prefix, dynamic suffix) behaves like one cursor
    over the whole collection."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64, growth="const")
    for d in docs[:80]:
        idx.add_document(d)
    st = StaticIndex.freeze(idx, "bp128")
    horizon = idx.num_docs
    for d in docs[80:120]:
        idx.add_document(d)
    for t in vocab[:40]:
        full_d, full_f = idx.postings(t)
        parts = [st.postings_iter(t)]
        h = idx.lookup(t)
        if h is not None:
            c = PostingsCursor(idx.store, h)
            if c.seek_geq(horizon + 1):
                parts.append(c)
        chained = ChainedCursor(parts)
        if len(full_d) == 0:
            assert chained.exhausted
            continue
        got = []
        while True:
            got.append((chained.docid, chained.payload))
            if not chained.next():
                break
        assert got == list(zip(full_d.tolist(), full_f.tolist()))


def test_conjunctive_from_cursors_handles_missing():
    assert conjunctive_from_cursors([]).tolist() == []
    assert conjunctive_from_cursors([None]).tolist() == []
    st = StaticIndex("bp128")
    st.add_list(b"a", np.array([1, 2, 3]), np.array([1, 1, 1]))
    st.add_list(b"b", np.array([2, 3, 9]), np.array([1, 1, 1]))
    out = conjunctive_from_cursors([st.postings_iter(b"a"),
                                    st.postings_iter(b"b")])
    assert out.tolist() == [2, 3]


# hypothesis round-trip property tests live in test_static_hypothesis.py —
# a module-level importorskip would skip this whole file with them.
