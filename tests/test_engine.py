"""Unified query engine tests: differential matrix (modes × backends ×
growth policies), incremental device-image refresh (immediate access on the
device path without collate()), planner routing, shard fan-out, serving."""

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, PlannerConfig, Query, UnsupportedQueryError
from repro.serve import QueryService


@pytest.fixture(scope="module")
def small_docs():
    rng = np.random.default_rng(42)
    vocab = [f"t{i}" for i in range(120)]
    probs = 1.0 / np.arange(1, 121) ** 1.05
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(120, size=rng.integers(5, 45),
                                          p=probs)]
            for _ in range(260)]
    return vocab, docs


@pytest.fixture(scope="module")
def engine_const(small_docs):
    """Const-mode engine frozen mid-stream: 180 docs collated, 80 in the
    delta — every device query below must see both halves."""
    vocab, docs = small_docs
    eng = Engine(B=64, growth="const")
    for d in docs[:180]:
        eng.add_document(d)
    eng.collate_now()
    for d in docs[180:]:
        eng.add_document(d)
    return vocab, eng


def _host_expected(eng, query):
    if query.mode == "conjunctive":
        return Q.brute_conjunctive(eng.index, query.terms), None
    if query.mode == "ranked_tfidf":
        return Q.ranked_disjunctive_taat(eng.index, list(query.terms),
                                         k=query.k)
    return Q.ranked_bm25(eng.index, list(query.terms), eng.doclens_array(),
                         k=query.k)


# --------------------------------------------------------------------------
# differential matrix: every backend must agree with the host oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["host", "device", "pallas"])
@pytest.mark.parametrize("mode", ["conjunctive", "ranked_tfidf", "bm25"])
def test_backend_matrix_const(engine_const, backend, mode):
    vocab, eng = engine_const
    rng = np.random.default_rng(hash((backend, mode)) % 2**32)
    for _ in range(6):
        nt = int(rng.integers(1, 4))
        terms = tuple(vocab[i] for i in
                      rng.choice(60, size=nt, replace=False))
        res = eng.execute(Query(terms=terms, mode=mode, k=10,
                                backend=backend))
        exp_d, exp_s = _host_expected(eng, Query(terms=terms, mode=mode,
                                                 k=10))
        assert res.backend == backend
        if mode == "conjunctive":
            assert res.docids.tolist() == exp_d.tolist()
        else:
            assert len(res.scores) == len(exp_s)
            assert np.allclose(np.sort(res.scores), np.sort(exp_s),
                               rtol=1e-5)


@pytest.mark.parametrize("growth", ["triangle", "expon"])
def test_variable_growth_host_routing(small_docs, growth):
    """Non-Const layouts execute on the host backend (planner fallback) and
    still answer every mode correctly."""
    vocab, docs = small_docs
    eng = Engine(B=64, growth=growth)
    for d in docs[:120]:
        eng.add_document(d)
    res = eng.execute(Query(terms=(vocab[1], vocab[4]), mode="conjunctive"))
    assert res.backend == "host"
    exp = Q.brute_conjunctive(eng.index, [vocab[1], vocab[4]])
    assert res.docids.tolist() == exp.tolist()
    d, s = Q.ranked_disjunctive_taat(eng.index, [vocab[2]], k=5)
    r2 = eng.execute(Query(terms=(vocab[2],), mode="ranked_tfidf", k=5))
    assert np.allclose(np.sort(r2.scores), np.sort(s), rtol=1e-6)
    with pytest.raises(ValueError):
        eng.execute(Query(terms=(vocab[0],), backend="device"))
    # Pallas decodes postings host-side, so variable-block layouts work
    r3 = eng.execute(Query(terms=(vocab[1], vocab[4]), mode="conjunctive",
                           backend="pallas"))
    assert r3.docids.tolist() == exp.tolist()


# --------------------------------------------------------------------------
# incremental device-image refresh (the immediate-access TPU path)
# --------------------------------------------------------------------------


def test_device_answers_post_freeze_docs_without_collate(engine_const):
    vocab, eng = engine_const
    assert eng.stats().collations == 1  # the fixture's single freeze
    # docs 181..260 exist only in the delta; conjunctive must return them
    res = eng.execute(Query(terms=(vocab[0],), mode="conjunctive",
                            backend="device"))
    assert res.docids.max() > 180, "device path missed post-freeze documents"
    assert eng.stats().collations == 1, "device query triggered a collation"
    assert eng.stats().delta_refreshes >= 1


def test_k_below_one_rejected():
    """k=0 slices diverge across backends — Query must reject it."""
    with pytest.raises(ValueError):
        Query(terms=("a",), mode="ranked_tfidf", k=0)
    with pytest.raises(ValueError):
        Query(terms=("a",), mode="bm25", k=-3)


def test_device_large_k_clamped(engine_const):
    """k beyond the accumulator width must clamp, not crash top_k
    (both the dense ranked path and the sort-based bm25 path)."""
    vocab, eng = engine_const
    for mode in ("ranked_tfidf", "bm25"):
        r = eng.execute(Query(terms=(vocab[0], vocab[2]), mode=mode,
                              k=5000, backend="device"))
        exp_d, exp_s = _host_expected(eng, Query(terms=(vocab[0], vocab[2]),
                                                 mode=mode, k=5000))
        assert len(r.scores) == len(exp_s)
        # the full tail is compared here (not just top-10), so f32-vs-f64
        # accumulation differences on tiny scores need a looser tolerance
        assert np.allclose(np.sort(r.scores), np.sort(exp_s),
                           rtol=1e-3, atol=1e-6)


def test_device_works_before_any_collation(small_docs):
    """Empty frozen image + all-delta: the device path needs no collate at
    all (the delta covers the whole index)."""
    vocab, docs = small_docs
    eng = Engine(B=64, growth="const")
    for d in docs[:60]:
        eng.add_document(d)
    res = eng.execute(Query(terms=(vocab[1], vocab[3]), mode="conjunctive",
                            backend="device"))
    exp = Q.brute_conjunctive(eng.index, [vocab[1], vocab[3]])
    assert res.docids.tolist() == exp.tolist()
    assert eng.stats().collations == 0


def test_refresh_cycles_and_new_terms(small_docs):
    """Interleave ingest and device queries over several refresh cycles,
    including a term that did not exist at freeze time."""
    vocab, docs = small_docs
    eng = Engine(B=64, growth="const")
    for d in docs[:100]:
        eng.add_document(d)
    eng.collate_now()
    rng = np.random.default_rng(5)
    for cycle in range(3):
        for d in docs[100 + 40 * cycle:100 + 40 * (cycle + 1)]:
            eng.add_document(list(d) + ["postfreeze"])
        terms = ("postfreeze", vocab[int(rng.integers(0, 40))])
        got = eng.execute(Query(terms=terms, mode="conjunctive",
                                backend="device"))
        exp = Q.brute_conjunctive(eng.index, list(terms))
        assert got.docids.tolist() == exp.tolist()
        r = eng.execute(Query(terms=terms, mode="ranked_tfidf", k=8,
                              backend="device"))
        _, s = Q.ranked_disjunctive_taat(eng.index, list(terms), k=8)
        assert np.allclose(np.sort(r.scores), np.sort(s), rtol=1e-5)
    assert eng.stats().collations == 1
    assert eng.stats().delta_refreshes >= 3


def test_auto_collate_bounds_delta(small_docs):
    vocab, docs = small_docs
    eng = Engine(B=64, growth="const", auto_collate_delta_frac=0.25)
    for d in docs[:80]:
        eng.add_document(d)
    eng.collate_now()
    base = eng.stats().collations
    for i, d in enumerate(docs[80:170]):
        eng.add_document(d)
        if i % 40 == 39:
            eng.execute(Query(terms=(vocab[0],), mode="conjunctive",
                              backend="device"))
    assert eng.stats().collations > base, "delta grew unbounded"


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------


def test_planner_batches_route_to_device(engine_const):
    vocab, eng = engine_const
    batch = [Query(terms=(vocab[i], vocab[i + 2]), mode="ranked_tfidf")
             for i in range(5)]
    res = eng.execute_many(batch)
    assert all(r.backend == "device" for r in res)
    single = eng.execute(Query(terms=(vocab[40],), mode="ranked_tfidf"))
    assert single.backend in ("host", "pallas")  # small batch never device


def test_planner_volume_threshold(engine_const):
    vocab, eng = engine_const
    cfg = PlannerConfig(pallas_min_postings=1)
    from repro.engine import Planner
    eng2 = Engine(B=64, growth="const", planner=cfg)
    assert isinstance(eng2.planner, Planner)
    eng2.add_document([vocab[0], vocab[1]])
    r = eng2.execute(Query(terms=(vocab[0],), mode="ranked_tfidf"))
    assert r.backend == "pallas"


def test_force_backend_knob(small_docs):
    vocab, docs = small_docs
    eng = Engine(B=64, growth="const", force_backend="host")
    for d in docs[:30]:
        eng.add_document(d)
    batch = [Query(terms=(vocab[0],), mode="ranked_tfidf")] * 6
    assert all(r.backend == "host" for r in eng.execute_many(batch))


def test_phrase_requires_word_level_host():
    eng = Engine(B=64, growth="const", word_level=True)
    eng.add_document(["to", "be", "or", "not", "to", "be"])
    eng.add_document(["be", "or", "to"])
    res = eng.execute(Query(terms=("to", "be"), mode="phrase"))
    assert res.backend == "host"
    assert res.docids.tolist() == [1]
    with pytest.raises(ValueError):
        eng.execute(Query(terms=("to", "be"), mode="phrase",
                          backend="pallas"))
    doc_eng = Engine(B=64, growth="const")
    doc_eng.add_document(["a", "b"])
    with pytest.raises(UnsupportedQueryError):
        doc_eng.execute(Query(terms=("a", "b"), mode="phrase"))


# --------------------------------------------------------------------------
# shard fan-out + serving
# --------------------------------------------------------------------------


def test_sharded_engine_conjunctive_exact(small_docs):
    vocab, docs = small_docs
    se = ShardedEngine(num_shards=3, B=64, growth="const")
    for d in docs[:90]:
        se.add_document(d)
    se.collate_now()
    for d in docs[90:130]:
        se.add_document(d)
    rng = np.random.default_rng(11)
    for _ in range(10):
        terms = [vocab[i] for i in rng.choice(40, size=2, replace=False)]
        got = se.execute(Query(terms=tuple(terms), mode="conjunctive"))
        exp = [g for g, d in enumerate(docs[:130], start=1)
               if all(t in d for t in terms)]
        assert got.docids.tolist() == exp
    ranked = se.execute(Query(terms=(vocab[0], vocab[2]),
                              mode="ranked_tfidf", k=7))
    assert len(ranked.docids) <= 7
    assert (np.diff(ranked.scores) <= 1e-9).all()  # descending


def test_query_service_immediate_access(small_docs):
    vocab, docs = small_docs
    eng = Engine(B=64, growth="const")
    svc = QueryService(eng, max_batch=4)
    for d in docs[:20]:
        svc.ingest(d)
    t1 = svc.submit(Query(terms=(vocab[0],), mode="conjunctive"))
    svc.ingest(docs[20])
    tickets = svc.flush()
    assert t1.done and t1 in tickets
    exp = Q.brute_conjunctive(eng.index, [vocab[0]])
    assert t1.result.docids.tolist() == exp.tolist()
    summary = svc.latency_summary()
    assert summary["query"]["n"] == 1 and summary["ingest"]["n"] == 21


def test_engine_adopts_existing_index(small_docs):
    vocab, docs = small_docs
    from repro.core.index import DynamicIndex
    idx = DynamicIndex(B=64, growth="const")
    for d in docs[:50]:
        idx.add_document(d)
    eng = Engine(index=idx)
    r = eng.execute(Query(terms=(vocab[1],), mode="bm25", k=5,
                          backend="host"))
    exp_d, exp_s = Q.ranked_bm25(idx, [vocab[1]], eng.doclens_array(), k=5)
    assert np.allclose(r.scores, exp_s)
