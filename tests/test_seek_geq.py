"""Differential tests for PostingsCursor.seek_geq (the paper's §3.2/§3.6
block-skip seek) against decoded postings / brute_conjunctive, across growth
policies, word-level mode, and adversarial gap patterns."""

import numpy as np
import pytest

from repro.analysis.contracts import wrap
from repro.core import query as Q
from repro.core.index import DynamicIndex
from repro.core.query import PostingsCursor

GROWTHS = ["const", "triangle", "expon"]


def _sweep_cursor(idx, term, targets):
    """Drive one cursor through non-decreasing ``targets`` and check every
    landing position against the decoded postings list.  The contract
    wrapper asserts the protocol postconditions (monotone docid, seek_geq
    lands >= target or exhausts) on every call, independent of the oracle."""
    docids, _ = idx.postings(term)
    cur = wrap(PostingsCursor(idx.store, idx.lookup(term)), label=term)
    floor = 0  # cursors only move forward
    for t in targets:
        ok = cur.seek_geq(t)
        eff = max(t, floor)
        j = np.searchsorted(docids, eff)
        if j >= len(docids):
            assert not ok
            return
        assert ok, (term, t)
        assert cur.docid == docids[j], (term, t, cur.docid, docids[j])
        floor = cur.docid


@pytest.mark.parametrize("growth", GROWTHS)
@pytest.mark.parametrize("word_level", [False, True])
def test_seek_geq_random_targets(zipf_docs, growth, word_level):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=48, growth=growth, word_level=word_level)
    for doc in docs[:250]:
        idx.add_document(doc)
    rng = np.random.default_rng(7)
    for ti in rng.choice(150, size=25, replace=False):
        term = vocab[ti]
        docids, _ = idx.postings(term)
        if len(docids) == 0:
            continue
        lo, hi = int(docids[0]), int(docids[-1])
        targets = np.sort(rng.integers(max(0, lo - 2), hi + 3, size=12))
        _sweep_cursor(idx, term, targets.tolist())


@pytest.mark.parametrize("growth", GROWTHS)
def test_seek_geq_adversarial_gaps(growth):
    """Huge d-gaps (block-leading b-gaps spanning thousands of docs),
    singleton chains, and dense runs right after a gap."""
    pattern = ([1, 2, 3] + list(range(40, 60)) + [1500]
               + list(range(2995, 3001)))
    idx = DynamicIndex(B=40, growth=growth)
    hit = set(pattern)
    for d in range(1, 3001):
        terms = ["filler", f"mod{d % 7}"]
        if d in hit:
            terms.append("rare")
        if d == 1700:
            terms.append("singleton")
        idx.add_document(terms)
    docids, _ = idx.postings("rare")
    assert docids.tolist() == sorted(hit)
    # jump straight across the 1440-doc gap, then probe the dense tail
    _sweep_cursor(idx, "rare", [0, 3, 55, 61, 1499, 1500, 1501, 2995, 3000])
    # target beyond the last posting exhausts
    _sweep_cursor(idx, "rare", [3001])
    # singleton chain: land exactly, then exhaust
    _sweep_cursor(idx, "singleton", [5, 1700])
    _sweep_cursor(idx, "singleton", [1701])
    # long filler chain (3000 postings, many blocks): every-block boundaries
    filler_ids, _ = idx.postings("filler")
    _sweep_cursor(idx, "filler", filler_ids[::97].tolist())


@pytest.mark.parametrize("growth", GROWTHS)
def test_seek_geq_drives_conjunctive_vs_brute(zipf_docs, growth):
    """conjunctive_query is built on seek_geq; differential against the
    set-intersection oracle doubles as an end-to-end seek check."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=40, growth=growth)
    for doc in docs[:300]:
        idx.add_document(doc)
    rng = np.random.default_rng(13)
    for _ in range(40):
        terms = [vocab[i] for i in
                 rng.choice(100, size=rng.integers(2, 5), replace=False)]
        got = Q.conjunctive_query(idx, terms)
        exp = Q.brute_conjunctive(idx, terms)
        assert got.tolist() == exp.tolist()


def test_seek_geq_word_level_adversarial():
    """Word-level postings repeat docids (one posting per occurrence);
    seek_geq must land on the FIRST occurrence of the target document."""
    idx = DynamicIndex(B=48, growth="const", word_level=True)
    for d in range(1, 400):
        if d % 50 == 0:
            idx.add_document(["echo"] * 5 + ["pad"])  # 5 occurrences
        else:
            idx.add_document(["pad"])
    docids, _ = idx.postings("echo")
    cur = wrap(PostingsCursor(idx.store, idx.lookup("echo")), label="echo")
    assert cur.seek_geq(120)
    assert cur.docid == 150
    # advancing within the 5 duplicate postings stays on the same document
    assert cur.next() and cur.docid == 150
    assert cur.seek_geq(200) and cur.docid == 200
    assert not cur.seek_geq(351)  # beyond the last posting: exhausts
