"""IngestPipeline: per-shard writer queues, the fan-out barrier, error
propagation, and a mixed ingest/query/delete stress run.

This module (and the pipeline it exercises) runs under the concurrency
sanitizer in CI (``pytest --sanitize``): the stress test drives every lock
in the module — queue internals, per-writer condition variables, freeze
coordination — from both the front-door thread and the writer threads, so
a lock-order inversion or an unlocked shared write surfaces here.
"""

import threading

import numpy as np
import pytest

from repro.core.lifecycle import FreezePolicy
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, Query
from repro.serve import QueryService
from repro.serve.ingest_pipeline import IngestPipeline, IngestTicket


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(2024)
    vocab = [f"t{i}" for i in range(100)]
    probs = 1.0 / np.arange(1, 101) ** 1.05
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(100, size=rng.integers(5, 30),
                                          p=probs)]
            for _ in range(240)]
    return vocab, docs


# --------------------------------------------------------------------------
# barrier mechanics
# --------------------------------------------------------------------------


def test_ticket_and_wait(corpus):
    _, docs = corpus
    with IngestPipeline(Engine(B=64)) as pipe:
        t0 = pipe.ticket()
        assert t0 == IngestTicket((0,))
        pipe.wait(t0)                       # nothing submitted: no block
        ids = pipe.submit(docs[:10])
        assert ids == list(range(1, 11))
        t1 = pipe.ticket()
        assert t1.marks == (10,)
        pipe.wait(t1)
        assert not pipe.in_flight()
        pipe.wait(t0)                       # old tickets stay satisfied
        # docids keep flowing from the pipeline-side counter
        assert pipe.submit(docs[10:13]) == [11, 12, 13]
        pipe.drain()
        assert pipe.engine.index.num_docs == 13


def test_sharded_marks_advance_by_full_batch(corpus):
    _, docs = corpus
    se = ShardedEngine(num_shards=3, B=64)
    with IngestPipeline(se) as pipe:
        pipe.submit(docs[:7])
        # every shard's mark advances by the WHOLE batch (own sub-batch +
        # version bumps for the documents it does not own)
        assert pipe.ticket().marks == (7, 7, 7)
        pipe.drain()
        assert [e.version for e in se.engines] == [7, 7, 7]
        assert se.num_docs == 7
    se.close()


def test_bounded_queue_backpressure(corpus):
    """A tiny queue forces submit() to block on slow writers — the run
    still completes with every document applied."""
    _, docs = corpus
    with IngestPipeline(Engine(B=64), max_queue=1) as pipe:
        for i in range(0, 200, 5):
            pipe.submit(docs[i % len(docs):(i % len(docs)) + 5])
        pipe.drain()
        assert pipe.engine.index.num_docs == 200


def test_writer_error_propagates():
    eng = Engine(B=64)

    def boom(docs):
        raise ValueError("writer exploded")

    eng.add_documents = boom
    pipe = IngestPipeline(eng)
    pipe.submit([["a", "b"]])
    with pytest.raises(RuntimeError, match="ingest writer"):
        pipe.drain()
    # close() after a writer death must not hang or mask the error
    with pytest.raises(RuntimeError, match="ingest writer"):
        pipe.close()


def test_close_is_idempotent(corpus):
    _, docs = corpus
    pipe = IngestPipeline(Engine(B=64))
    pipe.submit(docs[:5])
    pipe.close()
    pipe.close()
    assert pipe.engine.index.num_docs == 5


# --------------------------------------------------------------------------
# stress: mixed ingest/query/delete under background freezes (sanitized)
# --------------------------------------------------------------------------


def test_pipelined_stress_with_freezes(corpus):
    """The whole serving stack at once: pipelined ingest into a 4-shard
    fleet with background freezes, queries and deletes hitting the front
    door between batches, and a synchronous oracle asserting exactness at
    the end.  Under ``--sanitize`` this is the lock-discipline workout for
    the writer-queue module."""
    vocab, docs = corpus
    policy = FreezePolicy(every_docs=25, background=True)

    def mk():
        return ShardedEngine(num_shards=4, B=64, tier_policy=policy)

    oracle = QueryService(mk())
    svc = QueryService(mk(), pipelined=True, pipeline_queue=2)
    rng = np.random.default_rng(99)
    pos = 0
    deleted = []
    for step in range(24):
        n = int(rng.integers(1, 14))
        batch = docs[pos:pos + n]
        pos += len(batch)
        if not batch:
            break
        a = oracle.ingest_batch(batch)
        b = svc.ingest_batch(batch)
        assert a == b
        if step % 3 == 2:
            terms = tuple(vocab[i] for i in
                          rng.choice(50, size=2, replace=False))
            q = Query(terms=terms, mode="bm25", k=10)
            ra, rb = oracle.query(q), svc.query(q)
            assert ra.docids.tolist() == rb.docids.tolist()
            assert np.array_equal(ra.scores, rb.scores)
        if step % 5 == 4 and a:
            victim = int(rng.choice(a))
            oracle.delete(victim)
            svc.delete(victim)
            deleted.append(victim)
    svc.engine.drain_freezes()
    oracle.engine.drain_freezes()
    assert svc.engine.num_docs == oracle.engine.num_docs == pos
    assert svc.engine.stats().deleted_docs == len(deleted)
    for mode in ("conjunctive", "ranked_tfidf", "bm25"):
        for _ in range(6):
            terms = tuple(vocab[i] for i in
                          rng.choice(60, size=int(rng.integers(1, 4)),
                                     replace=False))
            q = Query(terms=terms, mode=mode, k=10)
            ra, rb = oracle.query(q), svc.query(q)
            assert ra.docids.tolist() == rb.docids.tolist(), (mode, terms)
            if ra.scores is not None:
                assert np.array_equal(ra.scores, rb.scores)
    svc.close()
    svc.engine.close()
    oracle.engine.close()


def test_front_door_thread_handoff(corpus):
    """The front door may move between threads as long as calls never
    overlap (the documented single-front-door contract): submits from a
    second thread, then a drain + query from the main thread."""
    _, docs = corpus
    eng = Engine(B=64)
    with IngestPipeline(eng) as pipe:
        done = threading.Event()

        def front():
            for i in range(0, 60, 6):
                pipe.submit(docs[i:i + 6])
            done.set()

        th = threading.Thread(target=front)
        th.start()
        th.join()
        assert done.is_set()
        pipe.drain()
        assert eng.index.num_docs == 60
        r = eng.execute(Query(terms=(docs[0][0],), mode="conjunctive"))
        assert len(r.docids) > 0
