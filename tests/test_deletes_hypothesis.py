"""Hypothesis properties for deletion & update (ISSUE 9): random
add/delete/re-add/update interleavings x {bp128, interp} x {doc-level,
word-level} with freezes mid-stream -> every query mode byte-identical to
the rebuild-without oracle, on every serving path, surviving
snapshot/restore, single engine and 4-shard fleet.

Own module so the importorskip cannot take the deterministic delete tests
(and the sanitized concurrency stress) with it — same split as
test_persist / test_persist_hypothesis.  Replay, oracle, and comparison
helpers are shared with test_deletes.py: the seeded smoke and the
property suite exercise the identical code path."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core.sharded_index import ShardedEngine  # noqa: E402
from repro.engine import Engine  # noqa: E402

from test_deletes import (  # noqa: E402
    TERMS,
    assert_matches_oracle,
    replay,
    replay_fleet,
)

_doc = hst.lists(hst.integers(0, len(TERMS) - 1), min_size=1, max_size=20)

#: one lifecycle op.  Victim indices for delete/update are drawn over a
#: huge range and reduced mod the live count at replay time, so every
#: drawn op is valid against whatever state the prefix produced.
_op = hst.one_of(
    hst.tuples(hst.just("add"), _doc),
    hst.tuples(hst.just("delete"), hst.integers(0, 10 ** 6)),
    hst.tuples(hst.just("readd"), hst.integers(0, 10 ** 6)),
    hst.tuples(hst.just("update"), hst.integers(0, 10 ** 6), _doc),
)
ops_stream = hst.lists(_op, min_size=1, max_size=40)


@pytest.mark.parametrize("word_level", [False, True])
@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(deadline=None, max_examples=30)
@given(ops=ops_stream)
def test_delete_rebuild_differential(word_level, codec, ops):
    """Any interleaving, freezes mid-stream, both codecs, both
    granularities: host and tiered serving are indistinguishable from an
    index that never contained the dead documents."""
    eng, live = replay(ops, word_level=word_level, codec=codec)
    assert_matches_oracle(eng.execute, live, word_level,
                          backends=("host", "tiered"))
    assert eng.stats().deleted_docs == eng.index.num_docs - len(live)


@settings(deadline=None, max_examples=8)
@given(ops=ops_stream)
def test_delete_rebuild_differential_device(ops):
    """The fused doc-level modes on the device/pallas path: the in-kernel
    liveness mask must reproduce the oracle exactly (dead documents can
    never occupy — or displace anything from — a top-k slot)."""
    eng, live = replay(ops)
    assert_matches_oracle(eng.execute, live, False,
                          backends=("device", "pallas"), same_backend=True)


@settings(deadline=None, max_examples=10)
@given(ops=ops_stream)
def test_delete_survives_snapshot_restore(tmp_path_factory, ops):
    """Tombstones are persisted state of record: a restored engine answers
    byte-identically to the never-restarted one AND stays fully live —
    deletes and ingests after restore still track the oracle."""
    root = str(tmp_path_factory.mktemp("snap"))
    eng, live = replay(ops)
    eng.snapshot(root)
    restored = Engine.restore(root)
    assert restored.stats().deleted_docs == eng.stats().deleted_docs
    assert_matches_oracle(restored.execute, live, False,
                          backends=("host", "tiered"))
    # the restored engine is not a read-only artifact: keep mutating
    if live:
        docid, _ = live.pop(0)
        restored.delete_document(docid)
    live.append((restored.add_document(["t0", "t1", "t2"]),
                 ["t0", "t1", "t2"]))
    assert_matches_oracle(restored.execute, live, False,
                          backends=("host", "tiered"))


@settings(deadline=None, max_examples=10)
@given(ops=ops_stream)
def test_sharded_delete_differential(ops):
    """4-shard fleet: delete fan-out (round-robin docid arithmetic + fleet
    counter decrements) keeps every shard-merged answer byte-identical to
    the single-engine rebuild-without oracle — global ranking statistics
    must shed deleted documents exactly."""
    fleet = ShardedEngine(num_shards=4, B=64, growth="const")
    try:
        live = replay_fleet(fleet, ops)
        assert fleet.deleted_docs == fleet.num_docs - len(live)
        assert_matches_oracle(lambda q: fleet.execute_many([q])[0], live,
                              False, backends=(None,))
    finally:
        fleet.close()
