"""Growth-policy tests (paper §2.5, §5.3, §5.4)."""

import numpy as np
import pytest

from repro.core.extensible import Const, Expon, Triangle, overhead_model


def test_expon_matches_paper_sequence():
    # §5.3: B=16, h=4, k=1.5 -> <16,16,16,32,48,64,96,144,208,...>
    assert Expon(B=16, k=1.5).schedule(4)[:9] == \
        (16, 16, 16, 32, 48, 64, 96, 144, 208)


def test_triangle_matches_paper_sequence():
    # §5.4: B=16, h=4 -> <16,16,32,32,32,48,48,48,48,...>
    assert Triangle(B=16).schedule(4)[:9] == \
        (16, 16, 32, 32, 32, 48, 48, 48, 48)


def test_triangle_payload_sequence():
    # §5.4: payload capacities <12,12,28,28,28,44,44,44,44,...>
    sizes = Triangle(B=16).schedule(4)[:9]
    assert tuple(s - 4 for s in sizes) == \
        (12, 12, 28, 28, 28, 44, 44, 44, 44)


def test_const_is_const():
    assert set(Const(B=64).schedule(4)[:50]) == {64}


def test_block_size_capped():
    sizes = Triangle(B=64).schedule(4)
    assert max(sizes) <= 1 << 16  # §5.4: "capped at 2^16 bytes"


@pytest.mark.parametrize("n", [10_000, 100_000, 1_000_000])
def test_triangle_overhead_sublinear(n):
    """The paper's central asymptotic claim (§6): Triangle overhead is
    Θ(sqrt(n)) while Const and Expon are Θ(n)."""
    tri = overhead_model(Triangle(B=64), n, 4)
    con = overhead_model(Const(B=64), n, 4)
    exp = overhead_model(Expon(B=64, k=1.1), n, 4)
    # Triangle beats both at scale
    assert tri["overhead"] < con["overhead"]
    assert tri["overhead"] < exp["overhead"]
    # and is within a constant of 2*sqrt(2*h*n) (links+slack balanced)
    assert tri["overhead"] < 8 * np.sqrt(2 * 4 * n)


def test_triangle_ratio_shrinks():
    r = [overhead_model(Triangle(B=64), n, 4)["ratio"]
         for n in (10**3, 10**4, 10**5, 10**6)]
    assert r[0] > r[1] > r[2] > r[3]
    con = [overhead_model(Const(B=64), n, 4)["ratio"]
           for n in (10**4, 10**6)]
    assert abs(con[0] - con[1]) < 0.02  # Const ratio is ~constant
