"""Batched write path ≡ sequential write path (PR 10 acceptance).

The batched ingest (``add_documents`` at every layer: DynamicIndex, Engine,
ShardedEngine, QueryService) must be indistinguishable from the one-by-one
path to any observer: the same docids come back, and every query mode
answers byte-identically — including while deletes and background freezes
interleave mid-batch.  Block ALLOCATION order inside the store legally
differs (the grouping pass creates heads in first-occurrence order), so the
differential is defined on what the paper defines it on: docids and
answers, not raw array bytes.
"""

import numpy as np
import pytest

from repro.core.index import DynamicIndex
from repro.core.lifecycle import FreezePolicy
from repro.core.prepare import PreparedDoc, prepare_doc
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, Query
from repro.serve import QueryService


@pytest.fixture(scope="module")
def stream_docs():
    rng = np.random.default_rng(777)
    vocab = [f"t{i}" for i in range(140)]
    probs = 1.0 / np.arange(1, 141) ** 1.05
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(140, size=rng.integers(5, 40),
                                          p=probs)]
            for _ in range(180)]
    return vocab, docs


def _modes(word_level):
    base = ["conjunctive", "ranked_tfidf", "bm25"]
    if word_level:
        base += ["phrase", "proximity", "bm25_prox"]
    return base


def _assert_same_answers(a, b, vocab, word_level, seed, n=6):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        nt = int(rng.integers(1, 4))
        terms = tuple(vocab[i] for i in
                      rng.choice(70, size=nt, replace=False))
        for mode in _modes(word_level):
            kw = dict(window=5) if mode == "proximity" else {}
            ra = a.execute(Query(terms=terms, mode=mode, k=10, **kw))
            rb = b.execute(Query(terms=terms, mode=mode, k=10, **kw))
            assert ra.docids.tolist() == rb.docids.tolist(), (mode, terms)
            if ra.scores is not None:
                assert np.array_equal(ra.scores, rb.scores), (mode, terms)


# --------------------------------------------------------------------------
# core: DynamicIndex.add_documents decodes identically to add_document
# --------------------------------------------------------------------------


@pytest.mark.parametrize("growth", ["const", "expon"])
@pytest.mark.parametrize("word_level", [False, True],
                         ids=["doc_level", "word_level"])
def test_core_batch_chains_decode_identically(stream_docs, growth,
                                              word_level):
    _, docs = stream_docs
    seq = DynamicIndex(B=64, growth=growth, word_level=word_level)
    bat = DynamicIndex(B=64, growth=growth, word_level=word_level)
    for d in docs[:50]:
        seq.add_document(d)
    assert bat.add_documents(docs[:50]) == list(range(1, 51))
    # mixed regime: sequential adds on top of a batch, then another batch
    for d in docs[50:70]:
        seq.add_document(d)
        bat.add_document(d)
    for d in docs[70:120]:
        seq.add_document(d)
    bat.add_documents(docs[70:120])
    assert (seq.num_docs, seq.num_postings, seq.num_words) == \
           (bat.num_docs, bat.num_postings, bat.num_words)
    # head POINTERS legally differ (batch allocation order); term sets and
    # decoded chains must not
    seq_terms = [t for t, _ in seq.terms()]
    assert sorted(seq_terms) == sorted(t for t, _ in bat.terms())
    for t in seq_terms:
        sd, sf = seq.postings(t)
        bd, bf = bat.postings(t)
        assert np.array_equal(sd, bd) and np.array_equal(sf, bf), t
    # whole-corpus batch: frequent terms form runs spanning many blocks
    # (repeated mid-run overflow recodes), which must decode identically too
    for d in docs[120:]:
        seq.add_document(d)
    one = DynamicIndex(B=64, growth=growth, word_level=word_level)
    assert one.add_documents(docs) == list(range(1, len(docs) + 1))
    for t in seq_terms:
        sd, sf = seq.postings(t)
        od, of = one.postings(t)
        assert np.array_equal(sd, od) and np.array_equal(sf, of), t


def test_prepared_docs_round_trip(stream_docs):
    """add_documents accepts pre-tokenized PreparedDoc values unchanged —
    the pipeline's writer-thread contract."""
    _, docs = stream_docs
    a = DynamicIndex(B=64)
    b = DynamicIndex(B=64)
    a.add_documents(docs[:30])
    prepared = [prepare_doc(d) for d in docs[:30]]
    assert all(isinstance(p, PreparedDoc) for p in prepared)
    b.add_documents(prepared)
    for t, _ in a.terms():
        ad, af = a.postings(t)
        bd, bf = b.postings(t)
        assert np.array_equal(ad, bd) and np.array_equal(af, bf), t


# --------------------------------------------------------------------------
# the acceptance matrix: six modes x codecs x granularities x 1/4 shards,
# deletes and a background freeze interleaved mid-batch
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@pytest.mark.parametrize("word_level", [False, True],
                         ids=["doc_level", "word_level"])
@pytest.mark.parametrize("shards", [1, 4])
def test_batch_ingest_byte_identical(stream_docs, codec, word_level, shards):
    vocab, docs = stream_docs
    policy = FreezePolicy(codec=codec, every_docs=40, background=True)

    def mk():
        if shards == 1:
            return Engine(B=64, growth="const", word_level=word_level,
                          tier_policy=policy)
        return ShardedEngine(num_shards=shards, B=64, growth="const",
                             word_level=word_level, tier_policy=policy)

    def settle(e):
        if shards == 1:
            e.lifecycle.wait()
        else:
            e.drain_freezes()

    seq, bat = mk(), mk()
    # phase A: seed, then delete while both sides agree on docids
    for d in docs[:60]:
        seq.add_document(d)
    assert bat.add_documents(docs[:60]) == list(range(1, 61))
    for victim in (3, 17, 44):
        seq.delete_document(victim)
        bat.delete_document(victim)
    # phase B: odd-sized batches so the freeze policy fires MID-batch
    # sequence and the background encode overlaps later batches
    for d in docs[60:140]:
        seq.add_document(d)
    out = []
    for i in range(60, 140, 23):
        out.extend(bat.add_documents(docs[i:min(i + 23, 140)]))
    assert out == list(range(61, 141))
    # phase C: delete again (including a doc ingested by a batch), finish
    for victim in (61, 100):
        seq.delete_document(victim)
        bat.delete_document(victim)
    for d in docs[140:]:
        seq.add_document(d)
    bat.add_documents(docs[140:])
    settle(seq)
    settle(bat)
    assert seq.version == bat.version
    assert seq.stats().num_docs == bat.stats().num_docs == len(docs)
    assert seq.stats().deleted_docs == bat.stats().deleted_docs == 5
    _assert_same_answers(seq, bat, vocab, word_level,
                         seed=hash((codec, word_level, shards)) % 2**32)
    for e in (seq, bat):
        if shards > 1:
            e.close()


def test_batch_immediate_visibility(stream_docs):
    """Documents are queryable the moment add_documents returns — no
    collate, no freeze, no refresh (the paper's immediate-access claim,
    batched)."""
    vocab, docs = stream_docs
    for eng in (Engine(B=64), ShardedEngine(num_shards=2, B=64)):
        eng.add_documents(docs[:40])
        dids = eng.add_documents([["qqx", "qqy"], ["qqx"], ["qqz", "qqx"]])
        r = eng.execute(Query(terms=("qqx",), mode="conjunctive"))
        assert r.docids.tolist() == dids
        r = eng.execute(Query(terms=("qqx", "qqz"), mode="conjunctive"))
        assert r.docids.tolist() == [dids[2]]


# --------------------------------------------------------------------------
# stats counters + pipelined service parity
# --------------------------------------------------------------------------


def test_ingest_counters(stream_docs):
    _, docs = stream_docs
    eng = Engine(B=64)
    eng.add_document(docs[0])
    eng.add_documents(docs[1:11])
    s = eng.stats()
    assert s.ingest_docs == 11
    assert s.ingest_batches == 2        # one single + one batch
    assert s.ingest_time_s > 0.0

    se = ShardedEngine(num_shards=3, B=64)
    se.add_documents(docs[:10])
    se.add_document(docs[10])
    cs = se.stats()
    # composite: per-shard counters sum; the single add_document landed on
    # one shard, the batch split into one sub-batch per shard
    assert cs.ingest_docs == 11
    assert cs.ingest_batches == 4
    assert cs.ingest_time_s > 0.0
    se.close()


@pytest.mark.parametrize("shards", [1, 4])
def test_pipelined_service_matches_sync(stream_docs, shards):
    """The pipelined front door (per-shard writer queues, barrier at query
    fan-out) answers exactly like the synchronous service."""
    vocab, docs = stream_docs
    mk = (lambda: Engine(B=64)) if shards == 1 else \
        (lambda: ShardedEngine(num_shards=shards, B=64))
    sync = QueryService(mk())
    pipe = QueryService(mk(), pipelined=True)
    for d in docs[:80]:
        sync.ingest(d)
    ids = []
    for i in range(0, 80, 13):
        ids.extend(pipe.ingest_batch(docs[i:min(i + 13, 80)]))
    assert ids == list(range(1, 81))
    # the immediate-access barrier lives at the SERVICE fan-out (query()
    # drains the pipeline); reading the engine directly needs the flush
    pipe.flush()
    _assert_same_answers(sync.engine, pipe.engine, vocab, False, seed=9)
    # immediate access through the pipeline: no explicit drain before query
    nd = pipe.ingest(["pppx", "pppy"])
    assert pipe.query(Query(terms=("pppx",))).docids.tolist() == [nd]
    # deletes go through the drained front door
    pipe.delete(nd)
    sync_nd = sync.ingest(["pppx", "pppy"])
    sync.delete(sync_nd)
    assert len(pipe.query(Query(terms=("pppx",))).docids) == 0
    pipe.flush()
    _assert_same_answers(sync.engine, pipe.engine, vocab, False, seed=10)
    pipe.close()
    if shards > 1:
        sync.engine.close()
        pipe.engine.close()
