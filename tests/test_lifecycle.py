"""Tiered static-tier lifecycle: background freeze, atomic swap, exact
merge with the dynamic suffix, planner routing, and the serving-layer
query-result cache (epoch/version keyed)."""

import threading

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.lifecycle import FreezeManager, FreezePolicy
from repro.engine import Engine, Query as EQuery, UnsupportedQueryError
from repro.serve import QueryService


@pytest.fixture(scope="module")
def stream_docs():
    rng = np.random.default_rng(77)
    vocab = [f"t{i}" for i in range(150)]
    probs = 1.0 / np.arange(1, 151) ** 1.05
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(150, size=rng.integers(5, 40),
                                          p=probs)]
            for _ in range(300)]
    return vocab, docs


def _assert_identical(eng, terms, mode, k=10):
    rt = eng.execute(EQuery(terms=terms, mode=mode, k=k, backend="tiered"))
    rh = eng.execute(EQuery(terms=terms, mode=mode, k=k, backend="host"))
    assert rt.backend == "tiered" and rh.backend == "host"
    assert rt.docids.tolist() == rh.docids.tolist(), (mode, terms)
    if mode != "conjunctive":
        # byte-identical scores: same arithmetic over the same values
        assert np.array_equal(rt.scores, rh.scores), (mode, terms)


# --------------------------------------------------------------------------
# the acceptance differential: ingest + background freeze + queries, exact
# --------------------------------------------------------------------------


@pytest.mark.parametrize("growth", ["const", "triangle", "expon"])
@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_tiered_identical_to_host_during_background_freeze(
        stream_docs, growth, codec):
    """Every tiered result must be byte-identical to the host backend while
    documents keep arriving and a background freeze completes mid-stream."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth=growth,
                 tier_policy=FreezePolicy(codec=codec, background=True))
    for d in docs[:120]:
        eng.add_document(d)
    rng = np.random.default_rng(3)

    def check(n=4):
        for _ in range(n):
            nt = int(rng.integers(1, 4))
            terms = tuple(vocab[i] for i in
                          rng.choice(70, size=nt, replace=False))
            for mode in ("conjunctive", "ranked_tfidf", "bm25"):
                _assert_identical(eng, terms, mode)

    check()                                   # before any tier exists
    assert eng.lifecycle.freeze(blocking=False)
    # the freeze runs on its own thread; ingest + queries continue against
    # the previous (empty) tier with no availability gap
    saw_in_flight = eng.lifecycle.in_flight
    for d in docs[120:180]:
        eng.add_document(d)
        check(1)
    eng.lifecycle.wait()
    assert saw_in_flight or eng.lifecycle.epoch == 1
    assert eng.lifecycle.tier is not None
    assert eng.lifecycle.tier.epoch == 1
    assert eng.lifecycle.tier.num_docs == 120
    check()                                   # after the swap
    # a second freeze epoch over the grown index
    eng.lifecycle.freeze(blocking=True)
    assert eng.lifecycle.tier.num_docs == eng.index.num_docs
    for d in docs[180:220]:
        eng.add_document(d)
    check()
    assert eng.stats().freezes == 2 and eng.stats().tier_epoch == 2


def test_policy_triggers_freeze_automatically(stream_docs):
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const",
                 tier_policy=FreezePolicy(every_docs=50, background=False))
    for d in docs[:170]:
        eng.add_document(d)
    # 170 docs with a 50-doc trigger: epochs at 50, 100, 150
    assert eng.lifecycle.freezes == 3
    assert eng.lifecycle.tier.num_docs == 150
    _assert_identical(eng, (vocab[0], vocab[5]), "conjunctive")
    _assert_identical(eng, (vocab[2], vocab[9]), "bm25")


def test_background_policy_single_freeze_in_flight(stream_docs):
    """A freeze request while one is running is a no-op, not a pile-up."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const")
    mgr = eng.enable_tiering(FreezePolicy(every_docs=10, background=True))
    for d in docs[:150]:
        eng.add_document(d)
    mgr.wait()
    # at least one freeze happened; never more than one thread at a time
    assert 1 <= mgr.freezes <= 15
    assert threading.active_count() < 10
    _assert_identical(eng, (vocab[1], vocab[4]), "conjunctive")


def test_freeze_empty_engine():
    """Freezing before any document exists must publish an empty tier, not
    crash (the empty-list guard in StaticIndex.add_list)."""
    eng = Engine(B=64, growth="const", tier_policy=FreezePolicy())
    eng.lifecycle.freeze(blocking=True)
    tier = eng.static_tier()
    assert tier is not None and tier.num_docs == 0 and tier.epoch == 1
    eng.add_document(["a", "b"])
    r = eng.execute(EQuery(terms=("a",), mode="conjunctive",
                           backend="tiered"))
    assert r.docids.tolist() == [1]


# --------------------------------------------------------------------------
# word-level tiers: the ⟨d,w⟩ lifecycle, differential vs host (ISSUE 3)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def word_stream_docs():
    rng = np.random.default_rng(55)
    vocab = [f"w{i}" for i in range(80)]
    probs = 1.0 / np.arange(1, 81) ** 1.05
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(80, size=rng.integers(4, 30),
                                          p=probs)]
            for _ in range(260)]
    return vocab, docs


from conftest import naive_phrase as _phrase_oracle  # noqa: E402
from conftest import naive_proximity as _prox_oracle  # noqa: E402
from conftest import naive_ranked as _ranked_oracle  # noqa: E402


@pytest.mark.parametrize("growth", ["const", "triangle", "expon"])
@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_word_level_tiered_identical_to_host_during_freeze(
        word_stream_docs, growth, codec):
    """The acceptance differential at word level: every tiered result —
    conjunctive, ranked (tfidf/bm25/bm25_prox), phrase AND proximity —
    byte-identical to the host backend while ingest continues and a
    background freeze completes mid-stream; phrase/proximity results
    additionally pinned to a naive scan of the raw docs, ranked results to
    the brute-force doc-level oracle (the ISSUE-4 w-gaps-as-frequencies bug
    cannot regress silently)."""
    vocab, docs = word_stream_docs
    eng = Engine(B=64, growth=growth, word_level=True,
                 tier_policy=FreezePolicy(codec=codec, background=True))
    for d in docs[:120]:
        eng.add_document(d)
    rng = np.random.default_rng(9)

    def check(n=3, ingested=120):
        for _ in range(n):
            nt = int(rng.integers(1, 4))
            terms = tuple(vocab[i] for i in
                          rng.choice(40, size=nt, replace=False))
            for mode in ("conjunctive", "ranked_tfidf", "bm25",
                         "bm25_prox"):
                _assert_identical(eng, terms, mode)
            # ranked modes vs the brute-force doc-level oracle (exact)
            for mode, oracle in (("ranked_tfidf", "tfidf"),
                                 ("bm25", "bm25"),
                                 ("bm25_prox", "bm25_prox")):
                r = eng.execute(EQuery(terms=terms, mode=mode, k=10,
                                       backend="tiered"))
                ed, es = _ranked_oracle(docs[:ingested], list(terms), k=10,
                                        mode=oracle)
                assert r.docids.tolist() == ed.tolist(), (mode, terms)
                assert np.allclose(r.scores, es, rtol=1e-12), (mode, terms)
            pt = terms[:2]
            rt = eng.execute(EQuery(terms=pt, mode="phrase",
                                    backend="tiered"))
            rh = eng.execute(EQuery(terms=pt, mode="phrase", backend="host"))
            exp = _phrase_oracle(docs[:ingested], pt)
            assert rt.docids.tolist() == exp, (pt,)
            assert rh.docids.tolist() == exp, (pt,)
            w = int(rng.integers(1, 9))
            qt = eng.execute(EQuery(terms=pt, mode="proximity", window=w,
                                    backend="tiered"))
            qh = eng.execute(EQuery(terms=pt, mode="proximity", window=w,
                                    backend="host"))
            pexp = _prox_oracle(docs[:ingested], pt, w)
            assert qt.docids.tolist() == pexp, (pt, w)
            assert qh.docids.tolist() == pexp, (pt, w)

    check()                                   # before any tier exists
    assert eng.lifecycle.freeze(blocking=False)
    for i, d in enumerate(docs[120:180]):
        eng.add_document(d)
        check(1, ingested=121 + i)
    eng.lifecycle.wait()
    assert eng.lifecycle.tier is not None
    assert eng.lifecycle.tier.num_docs == 120
    assert eng.lifecycle.tier.index.word_level
    check(ingested=180)                       # after the swap
    eng.lifecycle.freeze(blocking=True)       # second epoch, grown index
    assert eng.lifecycle.tier.num_docs == eng.index.num_docs
    for d in docs[180:220]:
        eng.add_document(d)
    check(ingested=220)
    assert eng.stats().freezes == 2 and eng.stats().tier_epoch == 2
    # word-level accounting flows through the stats plumbing
    assert eng.stats().num_words == eng.index.num_words > 0
    assert eng.index.num_words == eng.index.num_postings  # §5.1: 1/occurrence


def test_word_level_policy_and_planner_routing(word_stream_docs):
    """Policy-triggered word-level freezes; once a tier is published the
    planner routes phrase queries to it by default."""
    vocab, docs = word_stream_docs
    eng = Engine(B=64, growth="const", word_level=True,
                 tier_policy=FreezePolicy(every_docs=60, background=False))
    before = eng.execute(EQuery(terms=(vocab[0], vocab[1]), mode="phrase"))
    assert before.backend == "host"           # no tier yet
    for d in docs[:130]:
        eng.add_document(d)
    assert eng.lifecycle.freezes == 2         # epochs at 60, 120
    assert eng.lifecycle.tier.num_docs == 120
    after = eng.execute(EQuery(terms=(vocab[0], vocab[1]), mode="phrase"))
    assert after.backend == "tiered"
    assert after.docids.tolist() == _phrase_oracle(
        docs[:130], (vocab[0], vocab[1]))
    # proximity and bm25_prox follow the same positional routing rule
    prox = eng.execute(EQuery(terms=(vocab[0], vocab[1]), mode="proximity",
                              window=4))
    assert prox.backend == "tiered"
    assert prox.docids.tolist() == _prox_oracle(
        docs[:130], (vocab[0], vocab[1]), 4)
    assert eng.execute(EQuery(terms=(vocab[0], vocab[1]),
                              mode="bm25_prox")).backend == "tiered"
    _assert_identical(eng, (vocab[1], vocab[3]), "conjunctive")
    _assert_identical(eng, (vocab[2], vocab[5]), "bm25")


def test_word_level_static_tier_compression(word_stream_docs):
    """The frozen ⟨d,w⟩ tier must beat the dynamic form on bytes/posting —
    the §5 'small amount more for word-level indexing' claim."""
    vocab, docs = word_stream_docs
    eng = Engine(B=64, growth="const", word_level=True,
                 tier_policy=FreezePolicy())
    for d in docs[:200]:
        eng.add_document(d)
    eng.lifecycle.freeze(blocking=True)
    tier = eng.lifecycle.tier
    assert tier.num_postings == eng.index.num_postings
    assert tier.index.bytes_per_posting() < eng.index.bytes_per_posting()
    assert eng.index.stats()["num_words"] == eng.index.num_postings


def test_forced_phrase_on_doc_level_tiered_raises():
    eng = Engine(B=64, growth="const")       # doc-level
    eng.add_document(["x", "y"])
    with pytest.raises((ValueError, UnsupportedQueryError)):
        eng.execute(EQuery(terms=("x", "y"), mode="phrase",
                           backend="tiered"))
    with pytest.raises((ValueError, UnsupportedQueryError)):
        eng.execute(EQuery(terms=("x", "y"), mode="proximity", window=3,
                           backend="tiered"))
    with pytest.raises((ValueError, UnsupportedQueryError)):
        eng.execute(EQuery(terms=("x", "y"), mode="bm25_prox",
                           backend="tiered"))


def test_forced_device_or_pallas_on_positional_modes_raises():
    """Positional modes never run on the device/Pallas backends — a forced
    override must raise, not silently fall back (same contract as phrase)."""
    eng = Engine(B=64, growth="const", word_level=True)
    eng.add_document(["x", "y", "x"])
    for mode, kw in (("proximity", {"window": 2}), ("bm25_prox", {})):
        for backend in ("device", "pallas"):
            with pytest.raises((ValueError, UnsupportedQueryError)):
                eng.execute(EQuery(terms=("x", "y"), mode=mode,
                                   backend=backend, **kw))


def test_query_window_validation():
    with pytest.raises(ValueError):
        EQuery(terms=("a", "b"), mode="proximity")            # no window
    with pytest.raises(ValueError):
        EQuery(terms=("a", "b"), mode="proximity", window=0)  # degenerate
    with pytest.raises(ValueError):
        EQuery(terms=("a",), mode="conjunctive", window=3)    # misplaced


def test_planner_prefers_tiered_once_published(stream_docs):
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const", tier_policy=FreezePolicy())
    for d in docs[:80]:
        eng.add_document(d)
    before = eng.execute(EQuery(terms=(vocab[120],), mode="conjunctive"))
    assert before.backend == "host"          # no tier yet
    eng.lifecycle.freeze(blocking=True)
    after = eng.execute(EQuery(terms=(vocab[120],), mode="conjunctive"))
    assert after.backend == "tiered"
    # batches still go to the device image, volume still to pallas
    batch = [EQuery(terms=(vocab[i], vocab[i + 1]), mode="ranked_tfidf")
             for i in range(6)]
    assert all(r.backend == "device" for r in eng.execute_many(batch))


def test_suffix_cursor_skips_frozen_prefix(stream_docs):
    """The tiered view reads the dynamic chains only past the horizon."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const", tier_policy=FreezePolicy())
    for d in docs[:100]:
        eng.add_document(d)
    eng.lifecycle.freeze(blocking=True)
    for d in docs[100:140]:
        eng.add_document(d)
    view = eng.backends["tiered"].view()
    assert view.horizon == 100
    for t in vocab[:30]:
        ds, fs = view.suffix_postings(t)
        full_d, full_f = eng.index.postings(t)
        cut = np.searchsorted(full_d, 101, side="left")
        assert ds.tolist() == full_d[cut:].tolist()
        assert fs.tolist() == full_f[cut:].tolist()


# --------------------------------------------------------------------------
# serving-layer query-result cache (epoch/version keyed)
# --------------------------------------------------------------------------


def test_query_cache_hits_and_invalidation(stream_docs):
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const", tier_policy=FreezePolicy())
    svc = QueryService(eng, max_batch=4, cache_size=32)
    for d in docs[:60]:
        svc.ingest(d)
    q = EQuery(terms=(vocab[0], vocab[3]), mode="conjunctive")
    r1 = svc.query(q)
    assert svc.cache_hits == 0 and svc.cache_misses == 1
    r2 = svc.query(q)
    assert svc.cache_hits == 1 and r2.docids.tolist() == r1.docids.tolist()
    # ingest bumps engine.version -> old entries unreachable
    svc.ingest(docs[60])
    r3 = svc.query(q)
    assert svc.cache_misses == 2
    assert r3.docids.tolist() == Q.brute_conjunctive(
        eng.index, list(q.terms)).tolist()
    # a tier swap bumps the epoch -> invalidates even with no ingest
    svc.query(q)
    assert svc.cache_hits == 2
    eng.lifecycle.freeze(blocking=True)
    svc.query(q)
    assert svc.cache_misses == 3
    summary = svc.latency_summary()
    assert summary["cache"]["hits"] == 2 and summary["cache"]["misses"] == 3


def test_query_cache_immune_to_caller_mutation(stream_docs):
    """A caller mutating its result in place must not corrupt later hits."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const")
    svc = QueryService(eng, cache_size=8)
    for d in docs[:40]:
        svc.ingest(d)
    q = EQuery(terms=(vocab[0],), mode="conjunctive")
    r1 = svc.query(q)
    expected = r1.docids.tolist()
    r1.docids[:] = -1          # hostile in-place edit
    r2 = svc.query(q)
    assert svc.cache_hits == 1
    assert r2.docids.tolist() == expected
    r2.docids[:] = -2          # mutating a hit copy is also harmless
    assert svc.query(q).docids.tolist() == expected


def test_query_cache_disabled_and_bounded(stream_docs):
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const")
    svc = QueryService(eng, cache_size=0)
    for d in docs[:20]:
        svc.ingest(d)
    q = EQuery(terms=(vocab[0],), mode="conjunctive")
    svc.query(q)
    svc.query(q)
    assert svc.cache_hits == 0 and svc.cache_misses == 0
    bounded = QueryService(eng, cache_size=2)
    for i in range(5):
        bounded.query(EQuery(terms=(vocab[i],), mode="conjunctive"))
    assert len(bounded._cache) <= 2


def test_query_cache_key_covers_window(word_stream_docs):
    """The same terms at different proximity windows are different cache
    entries — ``window`` is part of the Query value, hence of the key."""
    vocab, docs = word_stream_docs
    eng = Engine(B=64, growth="const", word_level=True)
    svc = QueryService(eng, cache_size=16)
    for d in docs[:40]:
        svc.ingest(d)
    r1 = svc.proximity((vocab[0], vocab[1]), window=1)
    r2 = svc.proximity((vocab[0], vocab[1]), window=20)
    assert svc.cache_misses == 2 and svc.cache_hits == 0
    assert set(r1.docids.tolist()) <= set(r2.docids.tolist())
    assert svc.proximity((vocab[0], vocab[1]),
                         window=1).docids.tolist() == r1.docids.tolist()
    assert svc.cache_hits == 1


def test_flush_cache_key_computed_once_per_ticket(stream_docs):
    """ISSUE-4 satellite: a background freeze bumping ``lifecycle.epoch``
    while ``execute_many`` runs must not file the result under the NEW
    epoch (it was computed against the old tier).  The fix computes the key
    once at lookup and reuses it at store time — so after the bump, the
    next query at the new epoch is a miss, never a stale hit."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const", tier_policy=FreezePolicy())
    svc = QueryService(eng, cache_size=16)
    for d in docs[:60]:
        svc.ingest(d)

    real_execute_many = eng.execute_many

    def racing_execute_many(queries):
        res = real_execute_many(queries)
        eng.lifecycle.freeze(blocking=True)   # epoch bumps mid-flush
        return res

    eng.execute_many = racing_execute_many
    q = EQuery(terms=(vocab[0], vocab[2]), mode="conjunctive")
    r1 = svc.query(q)                          # miss; epoch bumps during it
    eng.execute_many = real_execute_many
    assert svc.cache_misses == 1
    r2 = svc.query(q)                          # new epoch -> must MISS
    assert svc.cache_misses == 2, \
        "result was cached under an epoch it was not computed for"
    assert r2.docids.tolist() == r1.docids.tolist()
    # and the old-epoch entry is simply unreachable, not wrong
    assert svc.query(q).docids.tolist() == r1.docids.tolist()
    assert svc.cache_hits == 1


def test_freeze_manager_standalone(stream_docs):
    """FreezeManager works without the Engine constructor knob."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const")
    mgr = FreezeManager(eng, FreezePolicy(codec="interp"))
    eng.lifecycle = mgr
    for d in docs[:90]:
        eng.add_document(d)
    mgr.freeze(blocking=True)
    tier = mgr.tier
    assert tier.index.codec == "interp"
    assert tier.num_postings == eng.index.num_postings
    assert tier.index.bytes_per_posting() < eng.index.bytes_per_posting()
    _assert_identical(eng, (vocab[0], vocab[2]), "ranked_tfidf")


# --------------------------------------------------------------------------
# pinning tests for the repro.analysis first-run findings (PR 7): freeze
# metadata is published atomically, and suffix_size snapshots the tier once
# --------------------------------------------------------------------------


def test_freeze_metadata_published_atomically(stream_docs):
    """epoch/freezes/last_freeze_s are derived views of the ONE published
    ``tier`` reference.  Under the old three-field publication
    (tier, then epoch, then freezes), a concurrent reader could observe
    ``tier.epoch`` ahead of ``epoch`` ahead of ``freezes``; reading the
    derived views in (tier, epoch, freezes) order must now always satisfy
    freezes >= epoch >= tier.epoch (values only move forward in time)."""
    vocab, docs = stream_docs
    eng = Engine(B=64, growth="const",
                 tier_policy=FreezePolicy(every_docs=12, background=True))
    mgr = eng.lifecycle
    stop = threading.Event()
    bad = []

    def reader():
        while not stop.is_set():
            tier = mgr.tier                   # earliest snapshot...
            epoch = mgr.epoch
            freezes = mgr.freezes             # ...latest snapshot
            t_ep = tier.epoch if tier is not None else 0
            if not freezes >= epoch >= t_ep:
                bad.append((t_ep, epoch, freezes))
            if tier is not None and tier.encode_s is None:
                bad.append(("tier published without encode_s", tier.epoch))

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        for d in docs[:200]:
            eng.add_document(d)
    finally:
        stop.set()
        for t in threads:
            t.join()
    mgr.wait()
    assert not bad, f"inconsistent freeze metadata observed: {bad[:5]}"
    # the derived-view invariant, settled: one freeze == one epoch
    assert mgr.freezes == mgr.epoch == mgr.tier.epoch > 0
    assert mgr.last_freeze_s == mgr.tier.encode_s is not None


def test_suffix_size_snapshots_tier_once():
    """A background swap completing MID-read of suffix_size must not mix
    two horizons.  The fake index publishes a new tier from inside its
    ``num_postings`` property — exactly between the old code's second and
    third loads of ``self.tier`` — which used to yield (50 docs, 0
    postings): a torn read spanning both horizons."""
    from repro.core.lifecycle import StaticTier

    class SwappingIndex:
        mgr = None

        @property
        def num_docs(self):
            return 100

        @property
        def num_postings(self):
            # a freeze thread swaps the tier mid-read
            self.mgr.tier = StaticTier(index=None, num_docs=100,
                                       num_postings=1000, epoch=2)
            return 1000

    class FakeEngine:
        def __init__(self, idx):
            self.index = idx

    idx = SwappingIndex()
    mgr = FreezeManager(FakeEngine(idx), FreezePolicy())
    idx.mgr = mgr
    mgr.tier = StaticTier(index=None, num_docs=50, num_postings=500, epoch=1)
    assert mgr.suffix_size() == (50, 500)   # ONE horizon, the snapshot's
    assert mgr.suffix_size() == (0, 0)      # next call sees the new tier
