"""Substrate tests: sparse ops, optimizers, schedules, gradient compression,
data pipelines, paged KV cache (Triangle transfer), sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.corpus import CorpusSpec, SyntheticCorpus
from repro.data.docstream import tokenize
from repro.data.graph import edges_coo, neighbor_sample, synthetic_power_law
from repro.distributed.compression import (ErrorFeedback, compress_int8,
                                           decompress_int8, ef_compress_tree,
                                           ef_init)
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               row_adagrad_init, row_adagrad_update)
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.serve.kv_cache import PagedKVCache, triangle_page_schedule
from repro.sparse.ops import embedding_bag, segment_softmax, segment_sum


class TestSparse:
    def test_embedding_bag_fixed(self):
        rng = np.random.default_rng(0)
        table = jnp.asarray(rng.standard_normal((50, 8)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, 50, (4, 6)), jnp.int32)
        w = jnp.asarray(rng.random((4, 6)) < 0.7, jnp.float32)
        out = embedding_bag(table, ids, weights=w, mode="sum")
        exp = np.stack([
            (np.asarray(table)[np.asarray(ids)[i]]
             * np.asarray(w)[i][:, None]).sum(0) for i in range(4)])
        assert np.allclose(np.asarray(out), exp, rtol=1e-6)

    def test_embedding_bag_offsets(self):
        table = jnp.asarray(np.eye(6, dtype=np.float32))
        ids = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)
        offs = jnp.asarray([0, 2], jnp.int32)  # bags [0,1] and [2,3,4]
        out = embedding_bag(table, ids, offsets=offs)
        assert np.allclose(np.asarray(out[0]), [1, 1, 0, 0, 0, 0])
        assert np.allclose(np.asarray(out[1]), [0, 0, 1, 1, 1, 0])

    def test_segment_softmax(self):
        logits = jnp.asarray([1.0, 2.0, 3.0, 1.0], jnp.float32)
        seg = jnp.asarray([0, 0, 1, 1], jnp.int32)
        out = np.asarray(segment_softmax(logits, seg, 2))
        assert abs(out[0] + out[1] - 1) < 1e-6
        assert abs(out[2] + out[3] - 1) < 1e-6

    def test_embedding_bag_grad(self):
        table = jnp.ones((10, 4), jnp.float32)
        ids = jnp.asarray([[1, 2]], jnp.int32)
        g = jax.grad(lambda t: embedding_bag(t, ids).sum())(table)
        assert float(g[1].sum()) == 4.0 and float(g[0].sum()) == 0.0


class TestOptim:
    def test_adamw_converges_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        s = adamw_init(p)
        for _ in range(300):
            g = jax.grad(lambda pp: jnp.sum((pp["w"] - 1.0) ** 2))(p)
            p, s, _ = adamw_update(p, g, s, 0.05, weight_decay=0.0)
        assert np.allclose(np.asarray(p["w"]), 1.0, atol=1e-2)

    def test_clipping(self):
        g = {"a": jnp.asarray([3.0, 4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 5.0) < 1e-6
        assert np.allclose(np.asarray(clipped["a"]), [0.6, 0.8])

    def test_bf16_states_still_converge(self):
        p = {"w": jnp.asarray([5.0])}
        s = adamw_init(p, state_dtype=jnp.bfloat16)
        for _ in range(300):
            g = jax.grad(lambda pp: jnp.sum(pp["w"] ** 2))(p)
            p, s, _ = adamw_update(p, g, s, 0.05, weight_decay=0.0)
        assert abs(float(p["w"][0])) < 0.15

    def test_row_adagrad(self):
        t = jnp.ones((4, 3))
        s = row_adagrad_init(t)
        g = jnp.zeros((4, 3)).at[2].set(1.0)
        t2, s2 = row_adagrad_update(t, g, s, lr=0.1)
        assert float(jnp.abs(t2[0] - t[0]).sum()) == 0  # untouched row
        assert float(t2[2][0]) < 1.0
        assert float(s2.accum[2]) > 0

    def test_schedules(self):
        assert float(linear_warmup(0, 1.0, 10)) == pytest.approx(0.1)
        assert float(cosine_schedule(10, 1.0, 10, 110)) == pytest.approx(
            1.0, abs=0.01)
        assert float(cosine_schedule(110, 1.0, 10, 110)) == pytest.approx(
            0.1, abs=0.01)


class TestCompression:
    def test_int8_roundtrip_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        q, s = compress_int8(x)
        err = np.abs(np.asarray(decompress_int8(q, s)) - np.asarray(x))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_error_feedback_accumulates(self):
        """The EF invariant: cumulative transmitted grads never drift more
        than the residual bound (≈ one quantization step) from the truth —
        even for values far below the quantization step."""
        g = {"w": jnp.asarray([1e-4, 2e-4, 1.0], jnp.float32)}
        ef = ef_init(g)
        total_deq = np.zeros(3)
        n = 200
        for _ in range(n):
            q_tree, ef = ef_compress_tree(g, ef)
            deq = decompress_int8(*q_tree["w"])
            total_deq += np.asarray(deq)
        step = 1.0 / 127.0
        drift = np.abs(total_deq - n * np.asarray(g["w"]))
        assert (drift <= step).all(), drift
        # without EF the tiny components would transmit exactly 0 forever
        assert total_deq[0] > 0 and total_deq[1] > 0


class TestData:
    def test_tokenizer_paper_rules(self):
        # §4.1: alpha runs, lowercase, 20-char breaking
        assert tokenize("Hello, WORLD!42foo") == ["hello", "world", "foo"]
        long = "a" * 45
        assert tokenize(long) == ["a" * 20, "a" * 20, "a" * 5]

    def test_corpus_stats(self):
        spec = CorpusSpec(n_docs=300, words_per_doc=100, universe=5000,
                          seed=1)
        docs = list(SyntheticCorpus(spec).doc_term_ids())
        assert len(docs) == 300
        mean_len = np.mean([len(d) for d in docs])
        assert 70 < mean_len < 140  # lognormal around the target
        # Zipf head: the most common term dominates
        flat = np.concatenate(docs)
        counts = np.bincount(flat)
        assert counts.max() > 10 * np.median(counts[counts > 0])

    def test_neighbor_sampler(self):
        g = synthetic_power_law(500, 8, seed=2)
        rng = np.random.default_rng(0)
        seeds = np.arange(16)
        blocks = neighbor_sample(g, seeds, [5, 3], rng)
        assert len(blocks) == 2
        b0 = blocks[0]
        assert b0.mask.shape == (16 * 5,)
        # every sampled edge is a real graph edge
        src_global = b0.nodes[b0.src[b0.mask]]
        dst_global = seeds[b0.dst[b0.mask]]
        for s, d in zip(src_global[:50], dst_global[:50]):
            lo, hi = g.indptr[d], g.indptr[d + 1]
            assert s in g.indices[lo:hi]

    def test_edges_coo(self):
        g = synthetic_power_law(100, 4, seed=3)
        src, dst = edges_coo(g)
        assert len(src) == g.n_edges == len(dst)


class TestPagedKV:
    def test_triangle_schedule_monotone(self):
        sched = triangle_page_schedule(16)
        assert sched[0] == 16
        assert all(b >= a for a, b in zip(sched, sched[1:]))

    def test_allocation_and_release(self):
        pool = PagedKVCache(n_pages=64, page_tokens=16, policy="const")
        pool.add_sequence(0)
        pages = pool.append_tokens(0, 40)  # needs 3 pages
        assert len(pages) == 3
        free_before = len(pool.free)
        pool.release(0)
        assert len(pool.free) == free_before + 3

    def test_triangle_overhead_sublinear_vs_const(self):
        """The paper's §5.4 claim transferred to KV paging: Triangle page-
        table entries grow sub-linearly while Const grows Θ(n)."""
        def entries(policy, n_tokens):
            pool = PagedKVCache(n_pages=100_000, page_tokens=16,
                                policy=policy)
            pool.add_sequence(0)
            pool.append_tokens(0, n_tokens)
            return len(pool.seqs[0].page_capacity)

        assert entries("triangle", 200_000) < entries("const", 200_000) / 4
        # sub-linearity: 4x the tokens -> far less than 4x the entries
        # (const is exactly 4x)
        growth = entries("triangle", 200_000) / entries("triangle", 50_000)
        assert growth < 2.5
        assert entries("const", 200_000) == 4 * entries("const", 50_000)

    def test_pool_exhaustion_raises(self):
        pool = PagedKVCache(n_pages=2, page_tokens=16, policy="const")
        pool.add_sequence(0)
        with pytest.raises(MemoryError):
            pool.append_tokens(0, 1000)


class TestShardingRules:
    def test_lm_rules_cover_all_params(self, host_mesh):
        from repro.configs import get_arch
        from repro.distributed.sharding import lm_param_rules, tree_shardings
        from repro.models.lm import params_shape
        for arch_id in ("granite-3-2b", "llama4-scout-17b-a16e"):
            cfg = get_arch(arch_id).cfg
            ps = params_shape(cfg)
            sh = tree_shardings(ps, host_mesh, lm_param_rules(host_mesh))
            assert jax.tree.structure(sh) == jax.tree.structure(ps)
