"""BlockStore / DynamicIndex ingest+decode tests (Figure 3, Algorithm 1)."""

from collections import Counter, defaultdict

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.index import DynamicIndex


@pytest.mark.parametrize("growth", ["const", "expon", "triangle"])
@pytest.mark.parametrize("B", [40, 64])
def test_doc_level_equals_bruteforce(zipf_docs, growth, B):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=B, growth=growth)
    truth = defaultdict(list)
    for d, doc in enumerate(docs[:300], start=1):
        idx.add_document(doc)
        for t, f in Counter(doc).items():
            truth[t].append((d, f))
    for t, plist in truth.items():
        docids, fs = idx.postings(t)
        assert docids.tolist() == [p[0] for p in plist]
        assert fs.tolist() == [p[1] for p in plist]
        assert idx.ft(t) == len(plist)


@pytest.mark.parametrize("growth", ["const", "triangle"])
def test_word_level_positions(zipf_docs, growth):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64, growth=growth, word_level=True)
    truth = defaultdict(list)
    for d, doc in enumerate(docs[:150], start=1):
        idx.add_document(doc)
        for w, t in enumerate(doc, start=1):
            truth[t].append((d, w))
    for t, plist in truth.items():
        docids, wgaps = idx.postings(t)
        got, last = [], {}
        for dd, wg in zip(docids, wgaps):
            w = last.get(int(dd), 0) + int(wg)
            last[int(dd)] = w
            got.append((int(dd), w))
        assert got == plist


def test_immediate_access(zipf_docs):
    """The defining property: a document is findable the moment add returns."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=48)
    for d, doc in enumerate(docs[:100], start=1):
        idx.add_document(doc)
        t = doc[0]
        docids, _ = idx.postings(t)
        assert docids[-1] == d


def test_breakdown_components_sum(zipf_docs):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64)
    for doc in docs[:200]:
        idx.add_document(doc)
    bd = idx.breakdown()
    parts = sum(v for k, v in bd.items()
                if k.startswith(("head_", "full_", "tail_"))
                and not k.endswith("blocks"))
    assert parts + bd["hash_bytes"] == bd["total_bytes"]
    # Table 7 structure: full-block postings dominate at scale
    assert bd["full_postings"] > 0 and bd["head_vocab"] > 0


def test_bytes_per_posting_in_paper_band(zipf_docs):
    """Table 8: doc-level whole-index cost ~1.9-2.6 B/posting (small
    collections sit at the high end from vocab amortization)."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=48)
    for doc in docs:
        idx.add_document(doc)
    assert 1.5 < idx.bytes_per_posting() < 3.0


def test_hash_probe_equals_cache(zipf_docs):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64)
    for doc in docs[:100]:
        idx.add_document(doc)
    for t in vocab[:200]:
        tb = t.encode()
        via_probe, _ = idx._probe(tb)
        assert via_probe == idx._cache.get(tb)


@given(st.lists(st.lists(st.integers(0, 60), min_size=1, max_size=40),
                min_size=1, max_size=60),
       st.sampled_from(["const", "expon", "triangle"]),
       st.booleans())
@settings(max_examples=25, deadline=None)
def test_random_streams_property(docs_ids, growth, word_level):
    """Hypothesis: arbitrary doc streams roundtrip for every policy."""
    idx = DynamicIndex(B=40, growth=growth, word_level=word_level)
    truth = defaultdict(list)
    for d, doc in enumerate(docs_ids, start=1):
        terms = [f"t{i}" for i in doc]
        idx.add_document(terms)
        if word_level:
            for w, t in enumerate(terms, start=1):
                truth[t].append((d, w))
        else:
            for t, f in Counter(terms).items():
                truth[t].append((d, f))
    for t, plist in truth.items():
        docids, second = idx.postings(t)
        if word_level:
            got, last = [], {}
            for dd, wg in zip(docids, second):
                w = last.get(int(dd), 0) + int(wg)
                last[int(dd)] = w
                got.append((int(dd), w))
            assert got == plist
        else:
            assert list(zip(docids.tolist(), second.tolist())) == plist
