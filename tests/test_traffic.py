"""Traffic harness: seeded determinism, SLO evaluation, availability under
freeze storms, and the QueryService cache hit/miss accounting the harness
reports.  Everything here is smoke-scale (CI runs this module via the
``traffic`` marker) — the full-scale percentiles live in
benchmarks/traffic_bench.py."""

import numpy as np
import pytest

from repro.analysis import purity
from repro.core.lifecycle import FreezePolicy
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, Query
from repro.serve import (FakeClock, QueryService, SLOSpec, TrafficReport,
                         WorkloadSpec, build_query_pool, generate_schedule,
                         run_traffic)

pytestmark = pytest.mark.traffic

VOCAB = [f"v{i}" for i in range(200)]


def make_docs(n, seed=11):
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, len(VOCAB) + 1) ** 1.1
    probs /= probs.sum()
    return [[VOCAB[i] for i in
             rng.choice(len(VOCAB), size=rng.integers(4, 25), p=probs)]
            for _ in range(n)]


SPEC = WorkloadSpec(seed=42, num_events=150, ingest_fraction=0.25,
                    num_distinct_queries=24, max_terms=3)

#: Mirrors the bench's generous-margin philosophy: order-of-magnitude
#: bounds a shared CI box cannot trip, plus the HARD zero-gap invariant.
SMOKE_SLO = SLOSpec(p50_ms=2000.0, p99_ms=30000.0, p999_ms=60000.0,
                    max_availability_gap=0)


# --------------------------------------------------------------------------
# seeded determinism
# --------------------------------------------------------------------------


def test_same_seed_identical_schedule():
    a = generate_schedule(SPEC, VOCAB)
    b = generate_schedule(SPEC, VOCAB)
    assert a == b                       # Event/Query are frozen dataclasses
    assert len(a) == SPEC.num_events


def test_different_seed_distinct_schedule():
    a = generate_schedule(SPEC, VOCAB)
    b = generate_schedule(WorkloadSpec(seed=43, num_events=150,
                                       ingest_fraction=0.25,
                                       num_distinct_queries=24,
                                       max_terms=3), VOCAB)
    assert a != b


def test_schedule_shape():
    sched = generate_schedule(SPEC, VOCAB)
    ts = [e.at_s for e in sched]
    assert ts == sorted(ts) and ts[0] > 0.0
    kinds = {e.kind for e in sched}
    assert kinds <= {"query", "ingest"}
    for e in sched:
        assert (e.query is None) == (e.kind == "ingest")
    # ingest fraction lands near spec (binomial, generous tolerance)
    frac = sum(e.kind == "ingest" for e in sched) / len(sched)
    assert 0.10 < frac < 0.45


def test_query_pool_modes_and_positional_arity():
    rng = np.random.default_rng(0)
    spec = WorkloadSpec(seed=0, num_distinct_queries=30,
                        modes=("conjunctive", "phrase", "proximity",
                               "bm25_prox"))
    pool = build_query_pool(spec, VOCAB, rng)
    assert len(pool) == 30
    assert {q.mode for q in pool} == set(spec.modes)
    for q in pool:
        if q.mode in ("phrase", "proximity"):
            assert len(q.terms) >= 2     # 1-term positional is degenerate
        assert q.window is None or q.mode == "proximity"


def test_same_seed_identical_report():
    """Same seed + FakeClock -> the ENTIRE percentile report is
    bit-reproducible; nothing in the driver leaks wall-clock."""
    docs = make_docs(80)

    def once():
        eng = Engine(force_backend="host",
                     tier_policy=FreezePolicy(every_docs=30,
                                              background=False))
        rep = run_traffic(eng, generate_schedule(SPEC, VOCAB), docs,
                          clock=FakeClock())
        return rep.to_dict()

    a, b = once(), once()
    assert a == b
    assert a["availability_gap"] == 0 and a["num_events"] == 150


def test_fake_clock_is_deterministic():
    a, b = FakeClock(), FakeClock()
    assert [a() for _ in range(5)] == [b() for _ in range(5)]


def test_schedule_purity_lint():
    """The analysis pass rejects time-based nondeterminism in schedule
    generators — and passes the real generator module."""
    bad = "import time\nfrom random import random\nimport numpy as np\n"
    findings = purity.check_schedule_module(bad, "serve/workload.py")
    assert len(findings) == 2
    assert all(f.check == purity.SCHEDULE_CHECK for f in findings)
    import repro.serve.workload as wl
    clean = purity.check_schedule_module(open(wl.__file__).read(),
                                         "serve/workload.py")
    assert clean == []


# --------------------------------------------------------------------------
# SLO evaluation
# --------------------------------------------------------------------------


def test_slo_evaluate_bounds_and_violations():
    rep = TrafficReport(p50_ms=5.0, p99_ms=50.0, p999_ms=100.0,
                        cache_hit_rate=0.5, availability_gap=2)
    ok = SLOSpec(p50_ms=10.0, p99_ms=60.0, p999_ms=200.0,
                 min_cache_hit_rate=0.4, max_availability_gap=2)
    assert ok.evaluate(rep) == {"ok": True, "violations": []}
    strict = SLOSpec(p50_ms=1.0, p999_ms=99.0, min_cache_hit_rate=0.9,
                     max_availability_gap=0)
    ev = strict.evaluate(rep)
    assert not ev["ok"] and len(ev["violations"]) == 4
    # None disables every bound
    assert SLOSpec(max_availability_gap=None).evaluate(rep)["ok"]


def test_traffic_under_freeze_storm_zero_gap():
    """The acceptance invariant at smoke scale: an aggressive background
    freeze storm lands mid-stream and not one query fails or goes
    unanswered."""
    docs = make_docs(120)
    eng = Engine(tier_policy=FreezePolicy(every_docs=15, background=True),
                 force_backend="host")
    rep = run_traffic(eng, generate_schedule(SPEC, VOCAB), docs)
    eng.lifecycle.wait()
    assert rep.availability_gap == 0
    assert rep.num_queries + rep.num_ingests == rep.num_events
    assert eng.lifecycle.freezes >= 1
    ev = SMOKE_SLO.evaluate(rep)
    assert ev["ok"], ev["violations"]


def test_traffic_sharded_zero_gap():
    docs = make_docs(120)
    fleet = ShardedEngine(num_shards=2, force_backend="host",
                          tier_policy=FreezePolicy(every_docs=15,
                                                   background=True))
    try:
        rep = run_traffic(fleet, generate_schedule(SPEC, VOCAB), docs)
        assert rep.availability_gap == 0
        assert SMOKE_SLO.evaluate(rep)["ok"]
    finally:
        fleet.close()


# --------------------------------------------------------------------------
# cache hit/miss accounting (regression-pins the counters the report uses)
# --------------------------------------------------------------------------

Q0 = Query(terms=("v0", "v1"), mode="bm25", k=5)


def test_cache_counters_hit_then_invalidate_on_ingest():
    eng = Engine(force_backend="host")
    for d in make_docs(30):
        eng.add_document(d)
    svc = QueryService(eng, max_batch=4, cache_size=32)
    svc.submit(Q0); svc.flush()
    assert svc.cache_stats() == {"hits": 0, "misses": 1, "hit_rate": 0.0,
                                 "entries": 1}
    svc.submit(Q0); svc.flush()
    assert (svc.cache_hits, svc.cache_misses) == (1, 1)
    assert svc.hit_rate == 0.5
    # ingest bumps engine.version -> the same query misses (immediate
    # access: the cached result would hide the new document)
    svc.ingest(["v0", "v1", "v7"])
    svc.submit(Q0); svc.flush()
    assert (svc.cache_hits, svc.cache_misses) == (1, 2)
    svc.submit(Q0); svc.flush()
    assert (svc.cache_hits, svc.cache_misses) == (2, 2)
    assert svc.hit_rate == 0.5


def test_cache_counters_across_epoch_bumps():
    """A tier swap (epoch bump) invalidates even with NO ingest in
    between: the cache key is (version, epoch, query)."""
    eng = Engine(force_backend="host",
                 tier_policy=FreezePolicy(every_docs=1000,
                                          background=False))
    for d in make_docs(40):
        eng.add_document(d)
    svc = QueryService(eng, max_batch=4, cache_size=32)
    svc.submit(Q0); svc.flush()
    svc.submit(Q0); svc.flush()
    assert (svc.cache_hits, svc.cache_misses) == (1, 1)
    epoch0 = eng.lifecycle.epoch
    eng.lifecycle.freeze(blocking=True)
    assert eng.lifecycle.epoch == epoch0 + 1
    svc.submit(Q0); svc.flush()
    assert (svc.cache_hits, svc.cache_misses) == (1, 2)
    svc.submit(Q0); svc.flush()
    assert (svc.cache_hits, svc.cache_misses) == (2, 2)


def test_cache_counters_sharded_tier_swap():
    """Composite fleet epoch: ANY shard freezing invalidates; hit-rate
    accounting keeps working across the swap."""
    fleet = ShardedEngine(num_shards=2, force_backend="host",
                          tier_policy=FreezePolicy(every_docs=1000,
                                                   background=False))
    try:
        for d in make_docs(40):
            fleet.add_document(d)
        svc = QueryService(fleet, max_batch=4, cache_size=32)
        svc.submit(Q0); svc.flush()
        svc.submit(Q0); svc.flush()
        assert (svc.cache_hits, svc.cache_misses) == (1, 1)
        fleet.engines[0].lifecycle.freeze(blocking=True)  # one shard only
        svc.submit(Q0); svc.flush()
        assert (svc.cache_hits, svc.cache_misses) == (1, 2)
        svc.submit(Q0); svc.flush()
        assert (svc.cache_hits, svc.cache_misses) == (2, 2)
        assert svc.cache_stats()["hit_rate"] == 0.5
    finally:
        fleet.close()


def test_uncacheable_counts_as_neither():
    eng = Engine(force_backend="host")
    for d in make_docs(10):
        eng.add_document(d)
    svc = QueryService(eng, max_batch=4, cache_size=0)   # caching off
    svc.submit(Q0); svc.flush()
    assert svc.cache_stats() == {"hits": 0, "misses": 0, "hit_rate": 0.0,
                                 "entries": 0}
