"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas bodies in Python on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collate import collate
from repro.core.device_index import build_device_image, decode_blocks
from repro.core.index import DynamicIndex
from repro.kernels.dvbyte_decode.ops import dvbyte_decode_blocks
from repro.kernels.intersect.ops import intersect_sorted
from repro.kernels.intersect.ref import PAD, intersect_ref
from repro.kernels.retrieval_dot.ops import candidate_scores
from repro.kernels.retrieval_dot.ref import retrieval_dot_ref
from repro.kernels.topk_score.ops import score_accumulate
from repro.kernels.topk_score.ref import score_ref

from repro.core import dvbyte as dv


class TestDvbyteDecodeKernel:
    @pytest.mark.parametrize("F", [2, 3, 4, 8, 16])
    @pytest.mark.parametrize("tile", [64, 256])
    def test_synthetic_stream_sweep(self, F, tile):
        rng = np.random.default_rng(F * 100 + tile)
        gs = rng.integers(1, 1 << 22, 400).astype(np.int64)
        fs = np.where(rng.random(400) < 0.8,
                      rng.integers(1, max(F, 2), 400),
                      rng.integers(1, 900, 400)).astype(np.int64)
        # pack into 64-byte blocks, codes never split (block-store rule)
        blocks, cur, pos = [], bytearray(64), 4
        for g, f in zip(gs, fs):
            tmp = bytearray(16)
            L = dv.dvbyte_encode_into(tmp, 0, int(g), int(f), F)
            if pos + L > 64:
                blocks.append(bytes(cur))
                cur, pos = bytearray(64), 4
            cur[pos:pos + L] = tmp[:L]
            pos += L
        blocks.append(bytes(cur))
        arr = np.frombuffer(b"".join(blocks), np.uint8).reshape(-1, 64).copy()
        st = jnp.full(len(arr), 4, jnp.int32)
        en = jnp.full(len(arr), 64, jnp.int32)
        g1, f1, v1 = decode_blocks(jnp.asarray(arr), st, en, F)
        g2, f2, v2 = dvbyte_decode_blocks(jnp.asarray(arr), st, en, F=F,
                                          tile=tile)
        assert (np.asarray(v1) == np.asarray(v2)).all()
        assert (np.asarray(g1 * v1) == np.asarray(g2 * v2)).all()
        assert (np.asarray(f1 * v1) == np.asarray(f2 * v2)).all()
        # and the decoded pairs equal the source
        assert np.asarray(g1)[np.asarray(v1)].tolist() == gs.tolist()
        assert np.asarray(f1)[np.asarray(v1)].tolist() == fs.tolist()

    def test_real_index_blocks(self, zipf_docs):
        vocab, docs = zipf_docs
        idx = DynamicIndex(B=64)
        for doc in docs[:300]:
            idx.add_document(doc)
        col = collate(idx)
        img = build_device_image(col, [t.encode() for t in vocab])
        NB = img.blocks.shape[0]
        start = np.full(NB, 4, np.int32)
        end = np.full(NB, 64, np.int32)
        for i in range(len(vocab)):
            s, n = int(img.term_slot[i]), int(img.term_nblk[i])
            if n == 0:
                continue
            start[s] = int(img.term_skip[i])
            end[s + n - 1] = int(img.term_nx[i])
        g1, f1, v1 = decode_blocks(img.blocks, jnp.asarray(start),
                                   jnp.asarray(end), 4)
        g2, f2, v2 = dvbyte_decode_blocks(img.blocks, jnp.asarray(start),
                                          jnp.asarray(end), F=4, tile=128)
        assert (np.asarray(v1) == np.asarray(v2)).all()
        assert (np.asarray(g1 * v1) == np.asarray(g2 * v2)).all()
        assert (np.asarray(f1 * v1) == np.asarray(f2 * v2)).all()


class TestIntersectKernel:
    @pytest.mark.parametrize("na,nb,tile", [(100, 1000, 128), (1000, 77, 64),
                                            (513, 900, 256), (5, 5, 128)])
    def test_sweep(self, na, nb, tile):
        rng = np.random.default_rng(na * nb)
        a = np.unique(rng.integers(1, 8000, na)).astype(np.int32)
        b = np.unique(rng.integers(1, 8000, nb)).astype(np.int32)
        got = intersect_sorted(jnp.asarray(a), jnp.asarray(b),
                               tile_a=tile, tile_b=tile)
        exp = intersect_ref(jnp.asarray(a), jnp.asarray(b))
        assert np.asarray(got).tolist() == np.asarray(exp).tolist()

    def test_disjoint_ranges_skip(self):
        a = jnp.asarray(np.arange(1, 513, dtype=np.int32))
        b = jnp.asarray(np.arange(10_000, 10_512, dtype=np.int32))
        got = intersect_sorted(a, b, tile_a=128, tile_b=128)
        assert not np.asarray(got).any()


class TestScoreKernel:
    @pytest.mark.parametrize("m,n,tm,tn", [(5000, 3000, 512, 512),
                                           (100, 100, 64, 64),
                                           (7000, 1234, 1024, 256)])
    def test_sweep(self, m, n, tm, tn):
        rng = np.random.default_rng(m + n)
        d = rng.integers(0, n, m).astype(np.int32)
        w = rng.random(m).astype(np.float32)
        got = score_accumulate(jnp.asarray(d), jnp.asarray(w), n_docs=n,
                               tile_m=tm, tile_n=tn)
        exp = score_ref(jnp.asarray(d), jnp.asarray(w), n)
        assert np.allclose(np.asarray(got), np.asarray(exp),
                           rtol=1e-5, atol=1e-5)


class TestRetrievalDotKernel:
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    @pytest.mark.parametrize("q,n,d", [(8, 700, 96), (1, 2048, 256),
                                       (17, 333, 64)])
    def test_sweep(self, q, n, d, dtype):
        rng = np.random.default_rng(q * n)
        qv = jnp.asarray(rng.standard_normal((q, d)), dtype)
        cv = jnp.asarray(rng.standard_normal((n, d)), dtype)
        got = candidate_scores(qv, cv, tile_q=8, tile_n=128, tile_d=32)
        exp = retrieval_dot_ref(qv, cv)
        tol = 1e-4 if dtype == np.float32 else 2e-2
        assert np.allclose(np.asarray(got), np.asarray(exp),
                           rtol=tol, atol=tol)
