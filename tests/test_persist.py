"""Crash-recoverable engine snapshots: atomic publish, torn-write fault
injection, retention, and the fresh-process restore differential.

The invariants under test:

* restore answers every query mode byte-identically to the snapshotted
  engine — docids, score doubles, tie order — doc- and word-level, with
  and without a live static tier, including snapshots taken MID freeze
  storm (the persisted tier is whatever was published at snapshot time;
  the tiered merge is exact at any horizon, so it cannot matter);
* a crash at ANY point of the persist path (fault-injected between the
  blockstore flush and the manifest rename) leaves the previous complete
  snapshot as the restore target and never a torn one — the manifest is
  written last and the directory rename is the atomic commit;
* orphaned ``.tmp-`` staging directories from crashed attempts are swept
  by the next snapshot;
* artifact corruption is detected (CRC), not silently restored;
* byte-identity survives a PROCESS boundary (subprocess differential), so
  nothing in the proof leans on same-process state.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import persist
from repro.core.lifecycle import FreezePolicy
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, Query

VOCAB = [f"w{i}" for i in range(120)]


def make_docs(n, seed=5):
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, len(VOCAB) + 1) ** 1.1
    probs /= probs.sum()
    return [[VOCAB[i] for i in
             rng.choice(len(VOCAB), size=rng.integers(4, 30), p=probs)]
            for _ in range(n)]


def build_engine(word_level=False, codec="bp128", n_docs=90, tier=True,
                 **kw):
    policy = FreezePolicy(codec=codec, background=False) if tier else None
    eng = Engine(B=64, word_level=word_level, tier_policy=policy, **kw)
    for d in make_docs(n_docs):
        eng.add_document(d)
    return eng


def probe_queries(word_level):
    qs = [Query(terms=("w0",), mode="conjunctive"),
          Query(terms=("w0", "w2"), mode="conjunctive"),
          Query(terms=("w1", "w3"), mode="ranked_tfidf", k=15),
          Query(terms=("w0", "w4"), mode="bm25", k=15)]
    if word_level:
        qs += [Query(terms=("w0", "w1"), mode="phrase"),
               Query(terms=("w0", "w2"), mode="proximity", window=6),
               Query(terms=("w1", "w2"), mode="bm25_prox", k=15)]
    return qs


def results_of(eng, word_level):
    """Raw bytes of every probe's docids and scores — byte-identity means
    tobytes() equality, which pins dtype, order, AND tie-breaking."""
    out = []
    for q in probe_queries(word_level):
        r = eng.execute(q)
        out.append((r.docids.tobytes(),
                    None if r.scores is None else r.scores.tobytes()))
    return out


def assert_identical(a, b, word_level):
    assert results_of(a, word_level) == results_of(b, word_level)


# --------------------------------------------------------------------------
# round trips
# --------------------------------------------------------------------------


@pytest.mark.parametrize("word_level", [False, True])
@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_round_trip_all_modes(tmp_path, word_level, codec):
    eng = build_engine(word_level=word_level, codec=codec)
    eng.snapshot(str(tmp_path))
    restored = Engine.restore(str(tmp_path))
    assert restored.index.num_docs == eng.index.num_docs
    assert restored.lifecycle.epoch == eng.lifecycle.epoch
    assert_identical(eng, restored, word_level)
    # the restored engine is live, not a read-only replica: ingest + query
    restored.add_document(["w0", "w99", "w0"])
    eng.add_document(["w0", "w99", "w0"])
    assert_identical(eng, restored, word_level)


def test_round_trip_untired_engine(tmp_path):
    eng = build_engine(tier=False)
    eng.snapshot(str(tmp_path))
    restored = Engine.restore(str(tmp_path))
    assert restored.lifecycle is None
    assert_identical(eng, restored, False)


def test_snapshot_mid_freeze_storm(tmp_path):
    """Snapshot while background encodes are landing every few docs; the
    snapshot captures whatever tier was published at its instant, and the
    restore must still answer identically to the ORIGINAL engine (exact
    merge at any horizon)."""
    eng = Engine(B=64, word_level=True,
                 tier_policy=FreezePolicy(every_docs=12, background=True))
    docs = make_docs(140)
    snaps = []
    for i, d in enumerate(docs):
        eng.add_document(d)
        if i in (40, 90, 139):    # mid-storm, encodes likely in flight
            snaps.append(eng.snapshot(str(tmp_path), keep=10))
    eng.lifecycle.wait()
    # the LAST snapshot has all docs; restore and compare to the original
    restored = Engine.restore(snaps[-1])
    assert restored.index.num_docs == eng.index.num_docs
    assert_identical(eng, restored, True)
    # earlier snapshots restore to their own consistent horizons
    early = Engine.restore(snaps[0])
    assert early.index.num_docs == 41


def test_quiesce_snapshot(tmp_path):
    eng = Engine(tier_policy=FreezePolicy(every_docs=20, background=True))
    for d in make_docs(70):
        eng.add_document(d)
    eng.snapshot(str(tmp_path), quiesce=True)   # joins in-flight encode
    restored = Engine.restore(str(tmp_path))
    assert restored.lifecycle.epoch == eng.lifecycle.epoch
    assert_identical(eng, restored, False)


def test_sharded_round_trip(tmp_path):
    fleet = ShardedEngine(num_shards=3, B=64,
                          tier_policy=FreezePolicy(every_docs=25,
                                                   background=False))
    for d in make_docs(80):
        fleet.add_document(d)
    fleet.snapshot(str(tmp_path))
    restored = ShardedEngine.restore(str(tmp_path))
    try:
        assert restored.num_shards == fleet.num_shards
        assert restored._ft == fleet._ft
        c0, c1 = fleet._counts, restored._counts
        assert (c0.version, c0.num_docs, c0.total_tokens) == \
            (c1.version, c1.num_docs, c1.total_tokens)
        assert_identical(fleet, restored, False)
        # global ranked statistics must keep merging exactly after restore
        restored.add_document(["w0", "w1"])
        fleet.add_document(["w0", "w1"])
        assert_identical(fleet, restored, False)
    finally:
        restored.close()
        fleet.close()


def test_restore_engine_kwargs_forward(tmp_path):
    eng = build_engine()
    eng.snapshot(str(tmp_path))
    restored = Engine.restore(str(tmp_path), force_backend="host")
    r = restored.execute(Query(terms=("w0", "w1"), mode="bm25"))
    assert r.backend == "host"


# --------------------------------------------------------------------------
# crash-point fault injection
# --------------------------------------------------------------------------


def snap_dirs(root):
    return [d for d in os.listdir(root) if d.startswith(persist.SNAP_PREFIX)]


def tmp_dirs(root):
    return [d for d in os.listdir(root) if d.startswith(persist.TMP_PREFIX)]


@pytest.mark.parametrize("label", persist.CRASH_POINTS)
def test_crash_leaves_previous_snapshot_intact(tmp_path, monkeypatch, label):
    """Kill the persist path at each injection point; the root must still
    hold exactly the pre-crash complete snapshot, the torn attempt must
    not be listed or restorable, and the next snapshot must succeed and
    sweep the orphaned staging dir."""
    root = str(tmp_path)
    eng = build_engine(n_docs=40)
    first = eng.snapshot(root)
    eng.add_document(["w7", "w8", "w9"])

    monkeypatch.setattr(persist, "_CRASH_AT", label)
    with pytest.raises(persist.SnapshotCrash):
        eng.snapshot(root)
    monkeypatch.setattr(persist, "_CRASH_AT", None)

    # only the complete snapshot is visible; the torn attempt is not
    assert persist.list_snapshots(root) == [first]
    assert persist.latest_snapshot(root) == first
    assert len(snap_dirs(root)) == 1
    # every crash point fires after the staging dir exists -> one orphan
    assert len(tmp_dirs(root)) == 1

    # restore-from-root falls back to the last complete manifest
    restored = Engine.restore(root)
    assert restored.index.num_docs == 40

    # the next snapshot sweeps the orphan and publishes normally
    second = eng.snapshot(root)
    assert tmp_dirs(root) == []
    assert persist.list_snapshots(root) == [first, second]
    assert Engine.restore(root).index.num_docs == 41


def test_crash_on_first_snapshot_leaves_nothing_restorable(tmp_path,
                                                           monkeypatch):
    root = str(tmp_path)
    eng = build_engine(n_docs=10)
    monkeypatch.setattr(persist, "_CRASH_AT", "manifest")
    with pytest.raises(persist.SnapshotCrash):
        eng.snapshot(root)
    monkeypatch.setattr(persist, "_CRASH_AT", None)
    assert persist.latest_snapshot(root) is None
    with pytest.raises(FileNotFoundError):
        Engine.restore(root)


def test_torn_snapshot_without_manifest_is_invisible(tmp_path):
    """A snap- directory missing its manifest (e.g. crashed rename cleanup)
    is not listable and restoring it explicitly raises."""
    root = str(tmp_path)
    eng = build_engine(n_docs=10)
    good = eng.snapshot(root)
    torn = os.path.join(root, persist.SNAP_PREFIX + "9999999999")
    os.makedirs(torn)
    assert persist.list_snapshots(root) == [good]
    with pytest.raises(FileNotFoundError):
        Engine.restore(torn)


def test_corrupt_artifact_detected(tmp_path):
    root = str(tmp_path)
    eng = build_engine(n_docs=20)
    snap = eng.snapshot(root)
    target = os.path.join(snap, "blockstore.npy")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF
    with open(target, "wb") as f:
        f.write(raw)
    with pytest.raises(persist.SnapshotCorrupt):
        Engine.restore(root)


def test_sweep_tmp_counts_and_removes(tmp_path):
    root = str(tmp_path)
    os.makedirs(os.path.join(root, persist.TMP_PREFIX + "0000000007"))
    os.makedirs(os.path.join(root, persist.TMP_PREFIX + "0000000008"))
    assert persist.sweep_tmp(root) == 2
    assert tmp_dirs(root) == []


def test_retention_keeps_newest(tmp_path):
    root = str(tmp_path)
    eng = build_engine(n_docs=5, tier=False)
    for i in range(5):
        eng.add_document(["w1", f"w{i + 2}"])
        eng.snapshot(root, keep=2)
    snaps = persist.list_snapshots(root)
    assert len(snaps) == 2
    # newest snapshot holds the full stream
    assert Engine.restore(root).index.num_docs == 10
    # sequence numbers keep increasing past gc'd ancestors (no reuse)
    assert os.path.basename(snaps[-1]) == persist.SNAP_PREFIX + "0000000005"


# --------------------------------------------------------------------------
# fresh-process differential
# --------------------------------------------------------------------------

_CHILD = r"""
import json, sys
from repro.engine import Engine, Query
root, word_level = sys.argv[1], sys.argv[2] == "1"
eng = Engine.restore(root)
out = []
qs = [("conjunctive", ("w0",), None), ("conjunctive", ("w0", "w2"), None),
      ("ranked_tfidf", ("w1", "w3"), None), ("bm25", ("w0", "w4"), None)]
if word_level:
    qs += [("phrase", ("w0", "w1"), None),
           ("proximity", ("w0", "w2"), 6), ("bm25_prox", ("w1", "w2"), None)]
for mode, terms, window in qs:
    kw = {"window": window} if window else {}
    r = eng.execute(Query(terms=terms, mode=mode, k=15, **kw))
    out.append([r.docids.tobytes().hex(),
                None if r.scores is None else r.scores.tobytes().hex()])
print(json.dumps(out))
"""


@pytest.mark.parametrize("word_level", [False, True])
def test_fresh_process_restore_differential(tmp_path, word_level):
    """The whole proof, across a process boundary: snapshot here, restore
    in a brand-new interpreter, compare hex-encoded result bytes."""
    eng = Engine(B=64, word_level=word_level,
                 tier_policy=FreezePolicy(every_docs=15, background=True))
    for d in make_docs(60):
        eng.add_document(d)
    eng.snapshot(str(tmp_path))      # mid-storm: no quiesce on purpose
    eng.lifecycle.wait()

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(tmp_path),
         "1" if word_level else "0"],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr
    child = json.loads(proc.stdout)

    # NOTE: compare against the restored horizon — the snapshot was taken
    # before lifecycle.wait(), but ingest had finished, so horizons match.
    expect = []
    qs = probe_queries(word_level)
    for q, _ in zip(qs, child):
        r = eng.execute(q)
        expect.append([r.docids.tobytes().hex(),
                       None if r.scores is None else r.scores.tobytes().hex()])
    assert child == expect
