"""Fault tolerance: checkpoint/restart bit-identical resume, atomic publish,
NaN fuse, straggler accounting."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import adamw_init, adamw_update
from repro.train import Trainer


def quadratic_step(lr=0.1):
    def loss_fn(p, b):
        return jnp.sum((p["w"] - b["target"]) ** 2)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        p, o, gn = adamw_update(params, grads, opt_state, lr,
                                weight_decay=0.0)
        return p, o, loss, gn

    return jax.jit(step)


def make_batch_at(nan_at=None):
    def batch_at(i):
        t = jnp.full((4,), 3.0)
        if nan_at is not None and i == nan_at:
            t = t * jnp.nan
        return {"target": t}
    return batch_at


def init_state():
    params = {"w": jnp.zeros((4,))}
    return params, adamw_init(params)


class TestCheckpointManager:
    def test_atomic_publish_and_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": np.arange(5), "b": {"c": np.ones((2, 3))}}
        mgr.save(7, tree)
        assert mgr.latest_step() == 7
        back = mgr.restore(7, like=tree)
        np.testing.assert_array_equal(back["a"], tree["a"])
        np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": np.asarray([s])})
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": np.arange(10)}, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_tmp_dir_never_published(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, {"x": np.arange(3)})
        assert not any(n.startswith(".tmp") for n in os.listdir(tmp_path))

    def test_async_then_blocking_same_step(self, tmp_path):
        """Regression: a blocking save must join an in-flight async save
        instead of racing it in the staging area (FileExistsError)."""
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": np.arange(20000)}
        for step in range(3, 9):
            mgr.save(step, tree, blocking=False)
            mgr.save(step, {"x": np.arange(20000) + step}, blocking=True)
        mgr.wait()
        assert mgr.latest_step() == 8
        np.testing.assert_array_equal(mgr.restore(8, like=tree)["x"],
                                      np.arange(20000) + 8)
        assert not any(n.startswith(".tmp") for n in os.listdir(tmp_path))

    def test_interleaved_async_blocking_distinct_steps(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        for step in range(1, 7):
            mgr.save(step, {"x": np.asarray([step])},
                     blocking=(step % 2 == 0))
        mgr.wait()
        assert mgr.all_steps() == [4, 5, 6]

    def test_keep_zero_retains_newest(self, tmp_path):
        """keep=0 must never delete the newest complete checkpoint."""
        mgr = CheckpointManager(str(tmp_path), keep=0)
        for s in (1, 2, 3):
            mgr.save(s, {"x": np.asarray([s])})
        assert mgr.all_steps() == [3]
        assert mgr.latest_step() == 3

    def test_negative_keep_rejected(self, tmp_path):
        with pytest.raises(ValueError):
            CheckpointManager(str(tmp_path), keep=-1)

    def test_crashed_staging_dirs_swept_at_next_publish(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": np.arange(3)})
        # simulate a crash mid-save: an orphaned staging dir remains
        (tmp_path / ".tmp-7-3").mkdir()
        (tmp_path / ".tmp-7-3" / "leaf-0.npy").write_bytes(b"partial")
        # restore-only instances must NOT sweep (they could race an active
        # writer's in-flight staging dir)
        reader = CheckpointManager(str(tmp_path))
        assert reader.latest_step() == 1
        assert (tmp_path / ".tmp-7-3").exists()
        # the writer's next publish reclaims the orphan
        mgr.save(2, {"x": np.arange(3)})
        assert not any(n.startswith(".tmp") for n in os.listdir(tmp_path))
        assert mgr.all_steps() == [1, 2]


@pytest.mark.slow
class TestTrainerFaultTolerance:
    def test_resume_is_bit_identical(self, tmp_path):
        step = quadratic_step()
        # uninterrupted run: 10 steps
        p, o = init_state()
        t_full = Trainer(step, p, o, make_batch_at(), log_every=0)
        t_full.run(10)
        # interrupted run: 6 steps (ckpt at 5), "crash", resume to 10
        ck = str(tmp_path / "ck")
        p, o = init_state()
        t1 = Trainer(step, p, o, make_batch_at(), ckpt_dir=ck, ckpt_every=5,
                     log_every=0)
        t1.run(6)
        t1.ckpt.wait()
        # new process would re-init params; Trainer must restore from step 5
        p0, o0 = init_state()
        t2 = Trainer(step, p0, o0, make_batch_at(), ckpt_dir=ck,
                     ckpt_every=5, log_every=0)
        assert t2.step == 6  # resumed after the step-5 checkpoint
        t2.run(4)
        np.testing.assert_array_equal(np.asarray(t_full.params["w"]),
                                      np.asarray(t2.params["w"]))

    def test_nan_guard_skips_update(self):
        step = quadratic_step()
        p, o = init_state()
        t = Trainer(step, p, o, make_batch_at(nan_at=3), log_every=0,
                    nan_fuse=5)
        t.run(6)
        assert all(np.isfinite(np.asarray(t.params["w"])))
        bad = [m for m in t.metrics if not np.isfinite(m["loss"])]
        assert len(bad) == 1

    def test_nan_fuse_aborts(self):
        def bad_step(params, opt_state, batch):
            return params, opt_state, jnp.nan, jnp.float32(0)
        p, o = init_state()
        t = Trainer(bad_step, p, o, make_batch_at(), log_every=0, nan_fuse=3)
        with pytest.raises(FloatingPointError):
            t.run(10)

    def test_deterministic_data_replay(self):
        from repro.data.lm import TokenBatches
        d = TokenBatches(vocab=100, batch=2, seq_len=8, seed=9)
        a = d.batch_at(5)
        b = d.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert not np.array_equal(d.batch_at(5)["tokens"],
                                  d.batch_at(6)["tokens"])
