"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real device; only launch/dryrun.py forces 512 fake devices."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def zipf_docs():
    """A small Zipfian document collection shared across test modules."""
    rng = np.random.default_rng(1234)
    vocab = [f"w{i}" for i in range(400)]
    probs = 1.0 / np.arange(1, 401) ** 1.07
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(400, size=rng.integers(8, 150),
                                          p=probs)]
            for _ in range(500)]
    return vocab, docs


@pytest.fixture(scope="session")
def host_mesh():
    import jax
    return jax.make_mesh((1, 1), ("data", "model"))
