"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real device; only launch/dryrun.py forces 512 fake devices."""

import numpy as np
import pytest

try:
    # Hypothesis profiles (selected with --hypothesis-profile=NAME):
    #   * ci   — deterministic (derandomize=True + a fixed example budget)
    #            so the fast `-m "not slow"` CI job can never flake on a
    #            fresh random draw; tier-1 runs the default randomized
    #            profile (hypothesis's stock 100-example budget).
    #   * dev  — bigger example budget for local property hunting.
    # The property tests deliberately pin only deadline=None, so these
    # profile budgets are the single knob for example counts.  Local runs
    # without hypothesis installed simply skip the property modules (they
    # importorskip), so this must stay optional.
    from hypothesis import settings

    settings.register_profile("ci", max_examples=40, derandomize=True,
                              deadline=None)
    settings.register_profile("dev", max_examples=200, deadline=None)
except ImportError:  # pragma: no cover - hypothesis is optional locally
    pass


@pytest.fixture(scope="session")
def zipf_docs():
    """A small Zipfian document collection shared across test modules."""
    rng = np.random.default_rng(1234)
    vocab = [f"w{i}" for i in range(400)]
    probs = 1.0 / np.arange(1, 401) ** 1.07
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(400, size=rng.integers(8, 150),
                                          p=probs)]
            for _ in range(500)]
    return vocab, docs


@pytest.fixture(scope="session")
def host_mesh():
    import jax
    return jax.make_mesh((1, 1), ("data", "model"))


def naive_phrase(docs, terms):
    """Brute-force phrase oracle: scan raw token lists for the consecutive
    phrase (1-based docids).  Shared by the phrase differential tests in
    test_query.py and test_lifecycle.py so the oracle cannot drift."""
    terms = list(terms)
    return [i + 1 for i, d in enumerate(docs)
            if any(list(d[j:j + len(terms)]) == terms
                   for j in range(len(d) - len(terms) + 1))]
