"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real device; only launch/dryrun.py forces 512 fake devices."""

import os

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="run every test under the repro.analysis concurrency "
             "sanitizer (instrumented locks + race detection); the "
             "REPRO_SANITIZE=1 env flag is equivalent")


@pytest.fixture(autouse=True)
def sanitizer(request):
    """Under ``--sanitize`` / ``REPRO_SANITIZE=1``: instrument every lock
    created by repro/test code for the duration of the test and fail it on
    any lock-order inversion or detected race.  Otherwise yields None at
    zero cost.  Tests that *deliberately* seed violations construct their
    own private :class:`Sanitizer` (never ``enable()``-d), so their
    findings land in the private instance, not here."""
    want = request.config.getoption("--sanitize") \
        or os.environ.get("REPRO_SANITIZE", "") not in ("", "0")
    if not want:
        yield None
        return
    from repro.analysis.sanitizer import Sanitizer
    san = Sanitizer(name=request.node.name)
    san.enable()
    try:
        yield san
    finally:
        san.disable()
        assert not san.findings, \
            f"concurrency sanitizer findings:\n{san.report()}"

try:
    # Hypothesis profiles (selected with --hypothesis-profile=NAME):
    #   * ci   — deterministic (derandomize=True + a fixed example budget)
    #            so the fast `-m "not slow"` CI job can never flake on a
    #            fresh random draw; tier-1 runs the default randomized
    #            profile (hypothesis's stock 100-example budget).
    #   * dev  — bigger example budget for local property hunting.
    # The property tests deliberately pin only deadline=None, so these
    # profile budgets are the single knob for example counts.  Local runs
    # without hypothesis installed simply skip the property modules (they
    # importorskip), so this must stay optional.
    from hypothesis import settings

    settings.register_profile("ci", max_examples=40, derandomize=True,
                              deadline=None)
    settings.register_profile("dev", max_examples=200, deadline=None)
except ImportError:  # pragma: no cover - hypothesis is optional locally
    pass


@pytest.fixture(scope="session")
def zipf_docs():
    """A small Zipfian document collection shared across test modules."""
    rng = np.random.default_rng(1234)
    vocab = [f"w{i}" for i in range(400)]
    probs = 1.0 / np.arange(1, 401) ** 1.07
    probs /= probs.sum()
    docs = [[vocab[i] for i in rng.choice(400, size=rng.integers(8, 150),
                                          p=probs)]
            for _ in range(500)]
    return vocab, docs


@pytest.fixture(scope="session")
def host_mesh():
    import jax
    return jax.make_mesh((1, 1), ("data", "model"))


def naive_phrase(docs, terms):
    """Brute-force phrase oracle: scan raw token lists for the consecutive
    phrase (1-based docids).  Shared by the phrase differential tests in
    test_query.py and test_lifecycle.py so the oracle cannot drift."""
    terms = list(terms)
    return [i + 1 for i, d in enumerate(docs)
            if any(list(d[j:j + len(terms)]) == terms
                   for j in range(len(d) - len(terms) + 1))]


def naive_proximity(docs, terms, window):
    """Brute-force proximity oracle over raw token lists (1-based docids):
    a doc matches iff some window [lo, lo+window] contains at least m_t
    occurrences of each query term t, where m_t is t's multiplicity in the
    query (repeated terms bind DISTINCT positions).  Enumerates every
    occurrence position as a candidate window start — O(n^2) per doc,
    deliberately nothing like the cursor operator's two-pointer sweep."""
    need = {}
    for t in terms:
        need[t] = need.get(t, 0) + 1
    out = []
    for i, d in enumerate(docs):
        pos = {t: [j for j, x in enumerate(d) if x == t] for t in need}
        if any(len(pos[t]) < m for t, m in need.items()):
            continue
        starts = sorted(p for ps in pos.values() for p in ps)
        if any(all(sum(lo <= p <= lo + window for p in pos[t]) >= m
                   for t, m in need.items())
               for lo in starts):
            out.append(i + 1)
    return out


def naive_ranked(docs, terms, k=10, mode="tfidf", k1=0.9, b=0.4, alpha=1.0):
    """Brute-force doc-level ranked oracle computing true f_{t,d} / f_t from
    the raw token lists, with the same float64 operations and per-document
    accumulation order (query-term order) as the index scorers, so scores
    are bitwise-comparable.  Tie order: higher score, then lower docid.
    Returns (docids, scores) — the top-k."""
    N = len(docs)
    doclens = np.asarray([0] + [len(d) for d in docs], dtype=np.float64)
    avg = float(doclens[1:N + 1].mean()) if N else 0.0
    df = {t: sum(t in d for d in docs) for t in set(terms)}
    scores = np.zeros(N + 1, dtype=np.float64)
    for t in terms:  # repeated query terms contribute once per slot
        ft = df[t]
        if ft == 0:
            continue
        for i, d in enumerate(docs, start=1):
            f = d.count(t)
            if not f:
                continue
            if mode == "tfidf":
                scores[i] += np.log1p(np.float64(f)) * np.log1p(N / ft)
            else:
                idf = np.log(1.0 + (N - ft + 0.5) / (ft + 0.5))
                tf = (f * (k1 + 1.0)) / (
                    f + k1 * (1.0 - b + b * doclens[i] / max(avg, 1e-9)))
                scores[i] += idf * tf
    if mode == "bm25_prox":
        for i, d in enumerate(docs, start=1):
            if not scores[i]:
                continue
            pos = [[j for j, x in enumerate(d, start=1) if x == t]
                   for t in dict.fromkeys(terms)]
            dists = [abs(p - q) for a in range(len(pos))
                     for bb in range(a + 1, len(pos))
                     for p in pos[a] for q in pos[bb]]
            delta = min(dists) if dists else None
            scores[i] += np.log(alpha + (np.exp(-float(delta))
                                         if delta is not None else 0.0))
    nz = np.flatnonzero(scores)
    order = np.lexsort((nz, -scores[nz]))[:k]
    top = nz[order]
    return top.astype(np.int64), scores[top]
