"""Distributed index tests: multi-device shard_map query correctness,
run in a subprocess with forced device count (never pollute the test
process's jax device state)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.index import DynamicIndex
    from repro.core.collate import collate
    from repro.core.device_index import build_device_image
    from repro.core.query import ranked_disjunctive_taat
    from repro.core.sharded_index import (make_sharded_query_step,
                                          shard_doc_offsets,
                                          sharded_input_specs, stack_images)

    rng = np.random.default_rng(7)
    VOCAB = [f"w{i}" for i in range(120)]
    vb = [t.encode() for t in VOCAB]
    probs = 1.0 / np.arange(1, 121) ** 1.07
    probs /= probs.sum()
    S = 4  # document shards
    per_shard = 150
    shards = []
    all_docs = []
    for s in range(S):
        idx = DynamicIndex(B=64, growth="const")
        docs = [[VOCAB[i] for i in rng.choice(120, size=rng.integers(8, 80),
                                              p=probs)]
                for _ in range(per_shard)]
        for d in docs:
            idx.add_document(d)
        all_docs.append(docs)
        shards.append(collate(idx))
    images = [build_device_image(sh, vb) for sh in shards]
    # pad metadata vocab-aligned; stack along shard axis
    img = stack_images(images)
    offs = shard_doc_offsets(images)
    # local slots are relative to each shard's own block array: offset them
    mesh = jax.make_mesh((S, 2), ("data", "model"))
    mb = int(max(im.term_nblk.max() for im in images))
    fn, ins, outs = make_sharded_query_step(mesh, k=10, max_blocks=mb,
                                            num_docs=per_shard)
    jf = jax.jit(fn, in_shardings=ins, out_shardings=outs)
    Q, T = 4, 4
    qt = np.zeros((Q, T), np.int32)
    qm = np.zeros((Q, T), bool)
    queries = []
    for qi in range(Q):
        terms = rng.choice(60, size=rng.integers(1, T + 1), replace=False)
        queries.append(terms)
        qt[qi, :len(terms)] = terms
        qm[qi, :len(terms)] = True
    with mesh:
        d, s = jf(img.blocks, img.term_slot, img.term_nblk, img.term_skip,
                  img.term_nx, img.term_ft, offs, jnp.asarray(qt),
                  jnp.asarray(qm))
    d, s = np.asarray(d), np.asarray(s)
    # host oracle: score per shard, globalize ids, merge
    ok = True
    for qi, terms in enumerate(queries):
        cand = []
        for si, sh in enumerate(shards):
            dd, ss = ranked_disjunctive_taat(sh, [VOCAB[i] for i in terms],
                                             k=10)
            for ddi, ssi in zip(dd, ss):
                cand.append((float(ssi), int(ddi) + si * per_shard))
        cand.sort(key=lambda x: -x[0])
        exp = sorted([c[0] for c in cand[:10]], reverse=True)
        got = sorted(s[qi].tolist(), reverse=True)[:len(exp)]
        if not np.allclose(got, exp, rtol=1e-4):
            ok = False
            print("MISMATCH", qi, got[:5], exp[:5])
    print(json.dumps({"ok": ok}))
""")


# the rank-offset globalization pin: shards of DIFFERENT document counts.
# Global docids must decode as offsets[s] + local (exclusive prefix sum of
# the shards' own num_docs) — a uniform `rank * max(num_docs)` stride, which
# stack_images' old num_docs=max(...) invited, misplaces every docid of
# every shard after the first smaller one.
UNEQUAL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.index import DynamicIndex
    from repro.core.collate import collate
    from repro.core.device_index import build_device_image, with_global_stats
    from repro.core.query import CollectionStats, ranked_disjunctive_taat
    from repro.core.sharded_index import (make_sharded_query_step,
                                          shard_doc_offsets, stack_images)

    rng = np.random.default_rng(11)
    VOCAB = [f"w{i}" for i in range(100)]
    vb = [t.encode() for t in VOCAB]
    probs = 1.0 / np.arange(1, 101) ** 1.07
    probs /= probs.sum()
    sizes = [150, 90, 140, 60]          # deliberately unequal
    total = sum(sizes)
    S = len(sizes)
    shards = []
    for n in sizes:
        idx = DynamicIndex(B=64, growth="const")
        for _ in range(n):
            idx.add_document([VOCAB[i] for i in
                              rng.choice(100, size=rng.integers(8, 60),
                                         p=probs)])
        shards.append(collate(idx))
    images = [build_device_image(sh, vb) for sh in shards]
    # exact GLOBAL ranked statistics: rebase every shard's term_ft to the
    # collection-wide document frequency (the with_global_stats seam) and
    # score with N = total — per-shard top-k then merges exactly
    gft = np.stack([np.asarray(im.term_ft) for im in images]).sum(axis=0)
    images = [with_global_stats(im, gft, im.num_docs) for im in images]
    img = stack_images(images)
    offs_host = [0]
    for n in sizes[:-1]:
        offs_host.append(offs_host[-1] + n)
    offs = shard_doc_offsets(images)
    assert offs.tolist() == offs_host
    assert img.num_docs == total        # collection total, not max
    mesh = jax.make_mesh((S, 2), ("data", "model"))
    mb = int(max(im.term_nblk.max() for im in images))
    fn, ins, outs = make_sharded_query_step(mesh, k=10, max_blocks=mb,
                                            num_docs=total)
    jf = jax.jit(fn, in_shardings=ins, out_shardings=outs)
    Q, T = 4, 3
    qt = np.zeros((Q, T), np.int32)
    qm = np.zeros((Q, T), bool)
    queries = []
    for qi in range(Q):
        terms = rng.choice(50, size=rng.integers(1, T + 1), replace=False)
        queries.append(terms)
        qt[qi, :len(terms)] = terms
        qm[qi, :len(terms)] = True
    with mesh:
        d, s = jf(img.blocks, img.term_slot, img.term_nblk, img.term_skip,
                  img.term_nx, img.term_ft, offs, jnp.asarray(qt),
                  jnp.asarray(qm))
    d, s = np.asarray(d), np.asarray(s)
    # GLOBAL-stats host oracle, addressed BY GLOBAL DOCID: every returned
    # (gid, score) must decode to a real document of the owning shard whose
    # oracle score matches — this pins the offset mapping itself,
    # independent of tie order at the k boundary
    gstats = CollectionStats(
        num_docs=total, avg_doclen=0.0,
        ft={vb[i]: int(gft[i]) for i in range(len(vb))})
    ok = True
    for qi, terms in enumerate(queries):
        oracle = {}
        for si, sh in enumerate(shards):
            dd, ss = ranked_disjunctive_taat(sh, [VOCAB[i] for i in terms],
                                             k=sizes[si], stats=gstats)
            for ddi, ssi in zip(dd, ss):
                oracle[offs_host[si] + int(ddi)] = float(ssi)
        merged = sorted(oracle.values(), reverse=True)[:10]
        got_s = sorted(s[qi][s[qi] > 0].tolist(), reverse=True)
        if not np.allclose(got_s, merged[:len(got_s)], rtol=1e-4):
            ok = False
            print("SCORE MISMATCH", qi, got_s[:5], merged[:5])
        for gid, sc in zip(d[qi], s[qi]):
            if sc <= 0:
                continue
            gid = int(gid)
            if gid not in oracle:
                ok = False
                print("BAD GID", qi, gid)
            elif not np.isclose(oracle[gid], float(sc), rtol=1e-4):
                ok = False
                print("GID/SCORE MISMATCH", qi, gid, oracle[gid], float(sc))
    print(json.dumps({"ok": ok}))
""")


def _run(script):
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env=dict(os.environ, PYTHONPATH="src"))
    assert out.returncode == 0, out.stderr[-3000:]
    last = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    assert json.loads(last)["ok"], out.stdout[-2000:]


@pytest.mark.slow
def test_sharded_query_matches_host_merge():
    _run(SCRIPT)


@pytest.mark.slow
def test_sharded_unequal_shard_sizes_globalize_exactly():
    _run(UNEQUAL_SCRIPT)


@pytest.mark.slow
def test_multipod_mesh_compiles():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core.sharded_index import (make_sharded_query_step,
                                              sharded_input_specs)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        fn, ins, outs = make_sharded_query_step(mesh, k=5, max_blocks=8,
                                                num_docs=1 << 10)
        specs = sharded_input_specs(mesh, shard_blocks=512, B=64,
                                    vocab=1 << 10, qbatch=8, qterms=4)
        with mesh:
            c = jax.jit(fn, in_shardings=ins,
                        out_shardings=outs).lower(*specs).compile()
        txt = c.as_text()
        assert "all-gather" in txt
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600,
                         env=dict(os.environ, PYTHONPATH="src"))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
