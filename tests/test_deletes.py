"""Deletion & update differential suite (ISSUE 9), deterministic half.

The tentpole invariant: after ANY interleaving of add / delete / re-add /
update — with freezes landing mid-stream — every query mode answers
**byte-identically** to a rebuild-without oracle: a fresh engine
ingesting only the surviving documents in their original order.  Docids
map through the order-preserving correspondence (survivors keep their
docids in the deleted engine; the oracle numbers them 1..L in the same
order), so docid lists AND score doubles must match bit-for-bit —
deletion is pure masking, never renumbering, and the synthesized live
collection statistics (N, avg doclen, per-term ft) must equal a
from-scratch build's exactly.

This module is hypothesis-free so the seeded differentials, the
EngineStats counter regressions, and the concurrent delete+freeze+query
stress (run under ``pytest --sanitize`` in CI) always execute; the
randomized property versions live in test_deletes_hypothesis.py (same
split as test_persist / test_persist_hypothesis)."""

import threading

import numpy as np
import pytest

from repro.core.lifecycle import FreezePolicy
from repro.core.sharded_index import ShardedEngine
from repro.engine import Engine, Query

TERMS = [f"t{i}" for i in range(30)]


def random_ops(seed: int, n: int = 40):
    """A seeded add/delete/re-add/update stream in the same op shape the
    hypothesis strategy draws — the deterministic smoke and the property
    suite replay through the identical code path."""
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n):
        r = rng.random()
        doc = list(rng.integers(0, len(TERMS), size=int(rng.integers(1, 20))))
        if r < 0.5:
            ops.append(("add", doc))
        elif r < 0.7:
            ops.append(("delete", int(rng.integers(10 ** 6))))
        elif r < 0.8:
            ops.append(("readd", int(rng.integers(10 ** 6))))
        else:
            ops.append(("update", int(rng.integers(10 ** 6)), doc))
    return ops


def replay(ops, *, word_level=False, codec="bp128", every_docs=8):
    """Apply ``ops`` to a fresh engine with a freeze policy aggressive
    enough that static-tier publications land mid-history.  Victim
    indices reduce mod the live count, so any drawn op is valid against
    whatever state the prefix produced; "readd" resurrects a previously
    deleted document's terms as a NEW docid.  Returns ``(engine, live)``
    where ``live`` is the surviving ``(docid, terms)`` list in ingestion
    (hence docid) order."""
    eng = Engine(word_level=word_level,
                 tier_policy=FreezePolicy(codec=codec, every_docs=every_docs,
                                          background=False))
    live: list[tuple[int, list]] = []
    graveyard: list[list] = []
    for op in ops:
        if op[0] == "add":
            terms = [TERMS[i] for i in op[1]]
            live.append((eng.add_document(terms), terms))
        elif op[0] == "delete":
            if not live:
                continue
            docid, terms = live.pop(op[1] % len(live))
            eng.delete_document(docid)
            graveyard.append(terms)
        elif op[0] == "readd":
            if not graveyard:
                continue
            terms = graveyard[op[1] % len(graveyard)]
            live.append((eng.add_document(terms), terms))
        else:  # update: tombstone victim, re-ingest new terms as new docid
            if not live:
                continue
            docid, _ = live.pop(op[1] % len(live))
            terms = [TERMS[i] for i in op[2]]
            live.append((eng.update_document(docid, terms), terms))
    return eng, live


def probes(word_level):
    qs = [Query(terms=("t0",), mode="conjunctive"),
          Query(terms=("t0", "t1"), mode="conjunctive"),
          Query(terms=("t0", "t2"), mode="ranked_tfidf", k=8),
          Query(terms=("t1", "t2"), mode="bm25", k=8),
          Query(terms=("t0", "t1", "t3"), mode="bm25", k=8)]
    if word_level:
        qs += [Query(terms=("t0", "t1"), mode="phrase"),
               Query(terms=("t0", "t2"), mode="proximity", window=4),
               Query(terms=("t0", "t1"), mode="bm25_prox", k=8)]
    return qs


def make_oracle(live, word_level):
    """Rebuild-without oracle: only the survivors, original order.  The
    returned ``mapping`` sends oracle docids to deleted-engine docids;
    it is strictly increasing, so ranked tie order is preserved."""
    oracle = Engine(word_level=word_level)
    mapping = [0]
    for docid, terms in live:
        oracle.add_document(terms)
        mapping.append(docid)
    return oracle, mapping


def assert_matches_oracle(execute, live, word_level, backends,
                          same_backend=False):
    """``execute(query)`` must answer byte-identically (docids through the
    order-preserving map; scores bit-for-bit) to the rebuild-without
    oracle for every probe mode on every backend.  ``same_backend=True``
    forces the oracle onto the backend under test — the device/pallas
    paths score in f32, so their parity contract is against the oracle's
    own device answer, not the host's f64 arithmetic."""
    oracle, mapping = make_oracle(live, word_level)
    for q in probes(word_level):
        for backend in backends:
            exp = oracle.execute(Query(
                terms=q.terms, mode=q.mode, k=q.k, window=q.window,
                backend=backend if same_backend else None))
            exp_ids = [mapping[d] for d in exp.docids.tolist()]
            got = execute(Query(terms=q.terms, mode=q.mode, k=q.k,
                                window=q.window, backend=backend))
            assert got.docids.tolist() == exp_ids, (q.mode, backend)
            if exp.scores is None:
                assert got.scores is None
            else:
                assert np.array_equal(got.scores, exp.scores), \
                    (q.mode, backend)


# --------------------------------------------------------------------------
# seeded differential smoke: the tentpole invariant without hypothesis
# --------------------------------------------------------------------------


@pytest.mark.parametrize("word_level", [False, True])
@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_delete_rebuild_differential_seeded(word_level, codec):
    """Three seeded interleavings per (codec, granularity) cell: host and
    tiered serving are indistinguishable from an index that never
    contained the dead documents."""
    for seed in (0, 1, 2):
        eng, live = replay(random_ops(seed), word_level=word_level,
                           codec=codec)
        assert_matches_oracle(eng.execute, live, word_level,
                              backends=("host", "tiered"))
        assert eng.stats().deleted_docs == eng.index.num_docs - len(live)


def test_delete_rebuild_differential_device_seeded():
    """The fused doc-level modes on the device/pallas path: the in-kernel
    liveness mask must reproduce the oracle exactly (dead documents can
    never occupy — or displace anything from — a top-k slot)."""
    eng, live = replay(random_ops(3))
    assert_matches_oracle(eng.execute, live, False,
                          backends=("device", "pallas"), same_backend=True)


def test_sharded_delete_differential_seeded():
    """4-shard fleet: delete fan-out (round-robin docid arithmetic + fleet
    counter decrements) keeps every shard-merged answer byte-identical to
    the single-engine rebuild-without oracle — global ranking statistics
    must shed deleted documents exactly."""
    fleet = ShardedEngine(num_shards=4, B=64, growth="const")
    try:
        live = replay_fleet(fleet, random_ops(4))
        assert fleet.deleted_docs == fleet.num_docs - len(live)
        assert_matches_oracle(lambda q: fleet.execute_many([q])[0], live,
                              False, backends=(None,))
    finally:
        fleet.close()


def replay_fleet(fleet, ops):
    """Fleet-side replay (no "readd": the graveyard bookkeeping adds
    nothing over update at this layer)."""
    live: list[tuple[int, list]] = []
    for op in ops:
        if op[0] == "add":
            terms = [TERMS[i] for i in op[1]]
            live.append((fleet.add_document(terms), terms))
        elif op[0] == "delete":
            if live:
                docid, _ = live.pop(op[1] % len(live))
                fleet.delete_document(docid)
        elif op[0] == "update":
            if live:
                docid, _ = live.pop(op[1] % len(live))
                terms = [TERMS[i] for i in op[2]]
                live.append((fleet.update_document(docid, terms), terms))
    return live


def test_delete_survives_snapshot_restore_seeded(tmp_path):
    """Tombstones are persisted state of record: a restored engine answers
    byte-identically to the never-restarted one AND stays fully live —
    deletes and ingests after restore still track the oracle."""
    eng, live = replay(random_ops(5))
    eng.snapshot(str(tmp_path))
    restored = Engine.restore(str(tmp_path))
    assert restored.stats().deleted_docs == eng.stats().deleted_docs
    assert_matches_oracle(restored.execute, live, False,
                          backends=("host", "tiered"))
    # the restored engine is not a read-only artifact: keep mutating
    if live:
        docid, _ = live.pop(0)
        restored.delete_document(docid)
    live.append((restored.add_document(["t0", "t1", "t2"]),
                 ["t0", "t1", "t2"]))
    assert_matches_oracle(restored.execute, live, False,
                          backends=("host", "tiered"))


# --------------------------------------------------------------------------
# counters + concurrency (satellite: EngineStats regression, sanitized)
# --------------------------------------------------------------------------


def test_engine_stats_delete_counters():
    """deleted_docs counts live tombstones; tombstones_compacted reports
    what the most recent freeze dropped from the static tier."""
    eng = Engine(B=64, growth="const", tier_policy=FreezePolicy())
    docs = [[f"t{i % 7}", f"t{(i + 1) % 7}"] for i in range(10)]
    ids = [eng.add_document(d) for d in docs]
    assert eng.stats().deleted_docs == 0
    for d in ids[:3]:
        eng.delete_document(d)
    st = eng.stats()
    assert st.deleted_docs == 3
    assert st.tombstones_compacted == 0          # no freeze yet
    eng.lifecycle.freeze(blocking=True)
    assert eng.stats().tombstones_compacted == 3
    assert eng.static_tier().compacted == 3
    # update = tombstone + re-ingest: one more deleted, one more doc
    new = eng.update_document(ids[5], ["t0", "t1"])
    st = eng.stats()
    assert st.deleted_docs == 4 and new == 11
    # double delete is an error; the counter must not double-count
    with pytest.raises(ValueError):
        eng.delete_document(ids[0])
    assert eng.stats().deleted_docs == 4


def test_sharded_stats_delete_counters():
    """The fleet aggregate carries the deletion counters across shards."""
    fleet = ShardedEngine(num_shards=4, B=64, growth="const",
                          tier_policy=FreezePolicy())
    try:
        ids = [fleet.add_document([f"t{i % 5}", f"t{(i + 2) % 5}"])
               for i in range(12)]
        for d in ids[:5]:
            fleet.delete_document(d)
        st = fleet.stats()
        assert st.deleted_docs == 5
        for e in fleet.engines:
            e.lifecycle.freeze(blocking=True)
        assert fleet.stats().tombstones_compacted == 5
    finally:
        fleet.close()


def test_concurrent_delete_freeze_query_stress():
    """Single-writer delete+ingest stream with BACKGROUND freezes landing
    mid-stream (compaction runs concurrently with tombstoning) and reader
    threads watching lifecycle metadata; every query differentially
    checked host-vs-tiered.  Runs under ``pytest --sanitize`` in CI, so
    any lock-order inversion or data race in the delete path fails here."""
    rng = np.random.default_rng(5)
    vocab = [f"t{i}" for i in range(60)]
    docs = [[vocab[i] for i in rng.choice(60, size=rng.integers(4, 25))]
            for _ in range(240)]
    eng = Engine(B=64, growth="const",
                 tier_policy=FreezePolicy(every_docs=25, background=True))
    mgr = eng.lifecycle
    stop = threading.Event()
    bad: list = []

    def reader():
        while not stop.is_set():
            tier = mgr.tier
            if tier is not None and tier.compacted < 0:
                bad.append(tier.compacted)
            _ = mgr.epoch

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    live: list[int] = []
    deleted = 0
    try:
        for i, d in enumerate(docs):
            live.append(eng.add_document(d))
            if i % 3 == 2:
                eng.delete_document(live.pop(int(rng.integers(len(live)))))
                deleted += 1
            if i % 5 == 4:
                q = Query(terms=(vocab[0], vocab[3]), mode="bm25", k=10)
                rt = eng.execute(Query(terms=q.terms, mode=q.mode, k=q.k,
                                       backend="tiered"))
                rh = eng.execute(Query(terms=q.terms, mode=q.mode, k=q.k,
                                       backend="host"))
                assert rt.docids.tolist() == rh.docids.tolist()
                assert np.array_equal(rt.scores, rh.scores)
    finally:
        stop.set()
        for t in threads:
            t.join()
    mgr.wait()
    assert not bad
    st = eng.stats()
    assert st.deleted_docs == deleted
    assert st.freezes >= 1
    # the LAST completed freeze compacted the tombstones it saw; a final
    # blocking freeze must account for every one of them
    eng.lifecycle.freeze(blocking=True)
    assert eng.stats().tombstones_compacted == deleted
