"""Codec unit + property tests (paper §2.2, §3.4, Algorithm 2)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dvbyte as dv


class TestVByte:
    def test_paper_example_12345(self):
        # "the decimal number 12,345 ... spans two seven-bit segments"
        assert dv.vbyte_len(12345) == 2

    def test_null_sentinel_property(self):
        # §2.2: a null byte can only be the code of x == 0
        assert dv.vbyte_encode([0]) == b"\x00"
        for x in [1, 127, 128, 129, 2**14, 2**14 + 1, 2**21, 2**28 - 1]:
            assert 0 not in dv.vbyte_encode([x]), x

    def test_lengths(self):
        for x, n in [(0, 1), (127, 1), (128, 2), (16383, 2), (16384, 3),
                     (2**21 - 1, 3), (2**21, 4), (2**28 - 1, 4), (2**28, 5)]:
            assert dv.vbyte_len(x) == n

    @given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                    max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, values):
        enc = dv.vbyte_encode(values)
        dec = list(dv.vbyte_decode_stream(enc, sentinel=False))
        assert dec == values

    @given(st.lists(st.integers(min_value=0, max_value=2**32 - 1),
                    min_size=1, max_size=200))
    @settings(max_examples=30, deadline=None)
    def test_vectorized_matches_scalar(self, values):
        assert bytes(dv.vbyte_encode_array(np.asarray(values))) == \
            dv.vbyte_encode(values)
        out = dv.vbyte_decode_array(dv.vbyte_encode_array(np.asarray(values)))
        assert out.tolist() == values


class TestDoubleVByte:
    def test_paper_examples(self):
        # §3.4: F=4, g=10, f=3 -> g'=39, one byte
        assert dv.dvbyte_len(10, 3, 4) == 1
        # g=40, f=3 -> g'=159, two bytes
        assert dv.dvbyte_len(40, 3, 4) == 2
        # g=40, f=5 -> escape: 160 (2B) + f-F+1=2 (1B) = 3 bytes
        assert dv.dvbyte_len(40, 5, 4) == 3

    @given(st.integers(1, 2**28), st.integers(1, 10_000),
           st.sampled_from([1, 2, 3, 4, 8, 16]))
    @settings(max_examples=200, deadline=None)
    def test_roundtrip_property(self, g, f, F):
        buf = bytearray(16)
        end = dv.dvbyte_encode_into(buf, 0, g, f, F)
        (g2, f2), pos = dv.dvbyte_decode_from(buf, 0, F)
        assert (g2, f2) == (g, f) and pos == end

    @given(st.lists(st.tuples(st.integers(1, 2**20), st.integers(1, 500)),
                    min_size=1, max_size=300),
           st.sampled_from([2, 3, 4, 8]))
    @settings(max_examples=30, deadline=None)
    def test_stream_roundtrip_and_scalar_identity(self, pairs, F):
        g = np.asarray([p[0] for p in pairs], np.uint64)
        f = np.asarray([p[1] for p in pairs], np.uint64)
        enc = dv.dvbyte_encode_pairs(g, f, F)
        g2, f2 = dv.dvbyte_decode_pairs(enc, F)
        assert (g2 == g).all() and (f2 == f).all()
        buf = bytearray(len(pairs) * 16)
        pos = 0
        for gg, ff in pairs:
            pos = dv.dvbyte_encode_into(buf, pos, gg, ff, F)
        assert bytes(buf[:pos]) == bytes(enc)

    def test_no_null_bytes_when_positive(self):
        # the sentinel survives folding: any (g>=1, f>=1) code is null-free
        rng = np.random.default_rng(0)
        for F in (2, 3, 4, 8):
            g = rng.integers(1, 1 << 20, 2000).astype(np.uint64)
            f = rng.integers(1, 600, 2000).astype(np.uint64)
            assert 0 not in dv.dvbyte_encode_pairs(g, f, F)

    def test_f1_degenerates_to_vbyte(self):
        # Table 3: "When F = 1 the original VByte scheme results"
        g, f = np.asarray([5, 300, 7]), np.asarray([2, 1, 90])
        enc = dv.dvbyte_encode_pairs(g, f, 1)
        # F=1: always escape path -> vbyte(g*1) + vbyte(f - 1 + 1)
        expect = dv.vbyte_encode([5, 2, 300, 1, 7, 90])
        assert bytes(enc) == expect

    def test_compression_wins_on_zipf(self):
        """Table 3's shape: F=4 should beat F=1 by ~1/3 on Zipfian data."""
        rng = np.random.default_rng(42)
        g = rng.zipf(1.3, 50_000).astype(np.uint64)
        f = np.minimum(rng.zipf(1.8, 50_000), 1000).astype(np.uint64)
        sizes = {F: len(dv.dvbyte_encode_pairs(g, f, F))
                 for F in (1, 2, 4, 8)}
        assert sizes[4] < sizes[2] < sizes[1]
        assert sizes[4] / sizes[1] < 0.75
