"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (the FULL
configs are exercised only via launch/dryrun.py)."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.train import reduced_lm
from repro.models import gnn as gnn_mod
from repro.models import lm as lm_mod
from repro.models import recsys as rec_mod
from repro.optim import adamw_init, adamw_update

LM_ARCHS = ["llama4-scout-17b-a16e", "granite-moe-3b-a800m", "granite-3-2b",
            "llama3.2-3b", "mistral-large-123b"]
REC_ARCHS = ["dlrm-mlperf", "sasrec", "din", "two-tower-retrieval"]


def _opt(p, g, s):
    return adamw_update(p, g, s, 1e-3)


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id, host_mesh):
    arch = get_arch(arch_id)
    cfg = reduced_lm(arch.cfg)
    # the reduced config keeps the family traits (MoE-ness, GQA ratio)
    assert (cfg.moe is None) == (arch.cfg.moe is None)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    with host_mesh:
        step = jax.jit(lm_mod.make_train_step(cfg, host_mesh, _opt))
        p2, o2, loss, gnorm = step(params, adamw_init(params), batch)
        assert np.isfinite(float(loss)) and float(loss) > 0
        assert np.isfinite(float(gnorm))
        # serve step: one decode token with a KV cache
        serve = jax.jit(lm_mod.make_serve_step(cfg, host_mesh))
        cache = {k: jnp.zeros(v.shape, v.dtype)
                 for k, v in lm_mod.make_cache_shape(cfg, 2, 16).items()}
        logits, cache2 = serve(params, cache,
                               jnp.asarray([1, 2], jnp.int32), 0)
        assert logits.shape == (2, cfg.vocab_padded)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert cache2["k"].shape == cache["k"].shape


def test_lm_prefill_consistent_with_decode(host_mesh):
    """prefill(tokens) then decode(t+1) == decode-from-scratch invariant."""
    cfg = replace(reduced_lm(get_arch("granite-3-2b").cfg), remat=False)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    S = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, S)), jnp.int32)
    with host_mesh:
        prefill = jax.jit(lm_mod.make_prefill_step(cfg, host_mesh))
        serve = jax.jit(lm_mod.make_serve_step(cfg, host_mesh))
        logits_p, cache = prefill(params, toks)
        # decode the same positions one-by-one from an empty cache
        cache2 = {k: jnp.zeros((cfg.n_layers, 1, S, v.shape[-1]), v.dtype)
                  for k, v in cache.items()}
        for pos in range(S):
            logits_d, cache2 = serve(params, cache2, toks[:, pos], pos)
        np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                                   np.asarray(logits_d, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_gnn_arch_smoke(host_mesh):
    cfg = gnn_mod.SchNetConfig(n_interactions=3, d_hidden=32, n_rbf=24,
                               d_feat=12, n_out=5)
    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    N, E = 80, 200
    batch = {
        "node_feat": jnp.asarray(rng.standard_normal((N, 12)), jnp.float32),
        "src": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "dist": jnp.asarray(rng.random(E) * 10, jnp.float32),
        "edge_mask": jnp.ones(E, bool),
        "node_mask": jnp.ones(N, jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 5, N), jnp.int32)}
    with host_mesh:
        out = gnn_mod.forward(params, batch, cfg, host_mesh)
        assert out.shape == (N, 5)
        assert np.isfinite(np.asarray(out)).all()
        step = jax.jit(gnn_mod.make_train_step(cfg, host_mesh, _opt))
        _, _, loss, _ = step(params, adamw_init(params), batch)
        assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_arch_smoke(arch_id, host_mesh):
    arch = get_arch(arch_id)
    rng = np.random.default_rng(0)
    B = 8
    with host_mesh:
        if arch.kind == "dlrm":
            cfg = rec_mod.DLRMConfig(table_rows=(100, 50, 200, 30),
                                     embed_dim=16, bot_mlp=(32, 16),
                                     top_mlp=(64, 32, 1))
            p = rec_mod.dlrm_init(cfg, jax.random.PRNGKey(0))
            b = {"dense": jnp.asarray(rng.random((B, 13)), jnp.float32),
                 "sparse": jnp.asarray(rng.integers(0, 30, (B, 4)),
                                       jnp.int32),
                 "label": jnp.asarray(rng.random(B) < 0.3, jnp.float32)}
            loss_fn = lambda pp, bb: rec_mod.dlrm_loss(pp, bb, cfg, host_mesh)
            out = rec_mod.dlrm_forward(p, b, cfg, host_mesh)
            assert out.shape == (B,)
        elif arch.kind == "sasrec":
            cfg = rec_mod.SASRecConfig(n_items=200, embed_dim=16, seq_len=10)
            p = rec_mod.sasrec_init(cfg, jax.random.PRNGKey(0))
            b = {"seq": jnp.asarray(rng.integers(0, 200, (B, 10)), jnp.int32),
                 "pos": jnp.asarray(rng.integers(0, 200, (B, 10)), jnp.int32),
                 "neg": jnp.asarray(rng.integers(0, 200, (B, 10)), jnp.int32),
                 "seq_mask": jnp.ones((B, 10), jnp.float32)}
            loss_fn = lambda pp, bb: rec_mod.sasrec_loss(pp, bb, cfg,
                                                         host_mesh)
            out = rec_mod.sasrec_serve(
                p, {"seq": b["seq"],
                    "cands": jnp.asarray(rng.integers(0, 200, (B, 7)),
                                         jnp.int32)}, cfg, host_mesh)
            assert out.shape == (B, 7)
        elif arch.kind == "din":
            cfg = rec_mod.DINConfig(n_items=200, embed_dim=8, seq_len=12,
                                    attn_mlp=(16, 8), mlp=(20, 8))
            p = rec_mod.din_init(cfg, jax.random.PRNGKey(0))
            b = {"history": jnp.asarray(rng.integers(0, 200, (B, 12)),
                                        jnp.int32),
                 "hist_mask": jnp.ones((B, 12), jnp.float32),
                 "target": jnp.asarray(rng.integers(0, 200, B), jnp.int32),
                 "label": jnp.asarray(rng.random(B) < 0.3, jnp.float32)}
            loss_fn = lambda pp, bb: rec_mod.din_loss(pp, bb, cfg, host_mesh)
            out = rec_mod.din_forward(p, b, cfg, host_mesh)
            assert out.shape == (B,)
        else:
            cfg = rec_mod.TwoTowerConfig(n_users_vocab=300, n_items=300,
                                         embed_dim=16, tower_mlp=(32, 16),
                                         n_user_feats=4)
            p = rec_mod.twotower_init(cfg, jax.random.PRNGKey(0))
            b = {"user_feats": jnp.asarray(rng.integers(0, 300, (B, 4)),
                                           jnp.int32),
                 "user_mask": jnp.ones((B, 4), jnp.float32),
                 "item": jnp.asarray(rng.integers(0, 300, B), jnp.int32),
                 "logq": jnp.zeros(B, jnp.float32)}
            loss_fn = lambda pp, bb: rec_mod.twotower_loss(pp, bb, cfg,
                                                           host_mesh)
            out = rec_mod.twotower_retrieve(
                p, {"user_feats": b["user_feats"][:1],
                    "user_mask": b["user_mask"][:1],
                    "cand_ids": jnp.asarray(rng.integers(0, 300, 64),
                                            jnp.int32)}, cfg, host_mesh)
            assert out.shape == (1, 64)
        step = jax.jit(rec_mod.make_train_step(loss_fn, _opt))
        _, _, loss, _ = step(p, adamw_init(p), b)
        assert np.isfinite(float(loss))


def test_registry_covers_all_assigned():
    assigned = {"llama4-scout-17b-a16e", "granite-moe-3b-a800m",
                "granite-3-2b", "llama3.2-3b", "mistral-large-123b",
                "schnet", "dlrm-mlperf", "sasrec", "din",
                "two-tower-retrieval"}
    for a in assigned:
        arch = get_arch(a)
        assert len(arch.shapes) == 4  # every arch pairs with its 4 shapes


def test_losses_decrease_briefly(host_mesh):
    """A few steps of the end-to-end driver reduce training loss."""
    cfg = reduced_lm(get_arch("granite-3-2b").cfg)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, (4, 32)), jnp.int32)}
    with host_mesh:
        step = jax.jit(lm_mod.make_train_step(
            cfg, host_mesh, lambda p, g, s: adamw_update(p, g, s, 5e-3)))
        losses = []
        for _ in range(8):
            params, opt, loss, _ = step(params, opt, batch)
            losses.append(float(loss))
    assert losses[-1] < losses[0]
