"""Query-engine tests (paper §3.6, §4.6)."""

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.index import DynamicIndex


@pytest.fixture(scope="module")
def built(zipf_docs):
    vocab, docs = zipf_docs
    out = {}
    for growth in ("const", "triangle"):
        idx = DynamicIndex(B=48, growth=growth)
        for doc in docs:
            idx.add_document(doc)
        out[growth] = idx
    return vocab, out


@pytest.mark.parametrize("growth", ["const", "triangle"])
def test_conjunctive_vs_bruteforce(built, growth):
    vocab, idxs = built
    idx = idxs[growth]
    rng = np.random.default_rng(0)
    for _ in range(150):
        terms = [vocab[i] for i in
                 rng.choice(120, size=rng.integers(1, 5), replace=False)]
        got = Q.conjunctive_query(idx, terms)
        exp = Q.brute_conjunctive(idx, terms)
        assert got.tolist() == exp.tolist()


def test_conjunctive_missing_term(built):
    vocab, idxs = built
    assert len(Q.conjunctive_query(idxs["const"], ["zzz_not_there"])) == 0
    assert len(Q.conjunctive_query(idxs["const"],
                                   [vocab[0], "zzz_not_there"])) == 0


@pytest.mark.parametrize("growth", ["const", "triangle"])
def test_ranked_daat_equals_taat(built, growth):
    vocab, idxs = built
    idx = idxs[growth]
    rng = np.random.default_rng(1)
    for _ in range(40):
        terms = [vocab[i] for i in
                 rng.choice(200, size=rng.integers(1, 4), replace=False)]
        d1, s1 = Q.ranked_disjunctive(idx, terms, k=10)
        d2, s2 = Q.ranked_disjunctive_taat(idx, terms, k=10)
        assert np.allclose(np.sort(s1), np.sort(s2), rtol=1e-9)


def test_seek_geq_cursor(built):
    vocab, idxs = built
    idx = idxs["const"]
    t = vocab[0]  # most common term: long multi-block chain
    docids, _ = idx.postings(t)
    cur = Q.PostingsCursor(idx.store, idx.lookup(t))
    # seek to every 7th docid and to gaps between docids
    for target in list(docids[::7]) + list(docids[:-1:5] + 1):
        cur2 = Q.PostingsCursor(idx.store, idx.lookup(t))
        found = cur2.seek_geq(int(target))
        expect = docids[docids >= int(target)]
        if len(expect) == 0:
            assert not found
        else:
            assert found and cur2.docid == expect[0]


def test_queries_interleaved_with_ingest(zipf_docs):
    """Immediate access under a mixed operation stream (Figure 1's point)."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64)
    rng = np.random.default_rng(3)
    for i, doc in enumerate(docs[:200]):
        idx.add_document(doc)
        if i % 7 == 0:
            terms = [doc[0], doc[min(1, len(doc) - 1)]]
            got = Q.conjunctive_query(idx, terms)
            assert idx.num_docs in got.tolist()  # the just-added doc matches


# --------------------------------------------------------------------------
# phrase operator vs a brute-force position scan over the raw documents,
# across dynamic-only, static-only, and chained-tier cursors (ISSUE 3)
# --------------------------------------------------------------------------


from conftest import naive_phrase as _naive_phrase  # noqa: E402


@pytest.fixture(scope="module")
def word_corpus():
    rng = np.random.default_rng(17)
    vocab = [f"p{i}" for i in range(25)]
    # small vocabulary + short docs -> dense phrase hits, including repeats
    docs = [[vocab[i] for i in rng.integers(0, 25, rng.integers(3, 35))]
            for _ in range(120)]
    idx = DynamicIndex(B=48, word_level=True)
    for d in docs:
        idx.add_document(d)
    return vocab, docs, idx


def _random_phrases(vocab, rng, n=60):
    out = []
    for _ in range(n):
        k = int(rng.integers(2, 5))
        out.append([vocab[i] for i in rng.integers(0, len(vocab), k)])
    # adversarial shapes: repeated term in the phrase, single term
    out += [[vocab[0], vocab[0]], [vocab[1], vocab[2], vocab[1]], [vocab[3]]]
    return out


def test_phrase_oracle_dynamic_cursors(word_corpus):
    vocab, docs, idx = word_corpus
    rng = np.random.default_rng(4)
    for terms in _random_phrases(vocab, rng):
        got = Q.phrase_from_cursors(
            [Q.word_cursor(idx, t) for t in terms]).tolist()
        assert got == _naive_phrase(docs, terms), terms


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_phrase_oracle_static_cursors(word_corpus, codec):
    from repro.core.static_index import StaticIndex
    vocab, docs, idx = word_corpus
    st = StaticIndex.freeze(idx, codec)
    rng = np.random.default_rng(5)
    for terms in _random_phrases(vocab, rng):
        got = Q.phrase_from_cursors(
            [st.postings_iter(t) for t in terms]).tolist()
        assert got == _naive_phrase(docs, terms), (codec, terms)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_phrase_oracle_chained_tier_cursors(word_corpus, codec):
    """Static prefix + dynamic suffix chained per slot: phrase results must
    equal the naive scan over the WHOLE collection."""
    from repro.core.static_index import StaticIndex
    vocab, docs, idx0 = word_corpus
    horizon = 70
    idx = DynamicIndex(B=48, word_level=True)
    for d in docs[:horizon]:
        idx.add_document(d)
    st = StaticIndex.freeze(idx, codec)
    for d in docs[horizon:]:
        idx.add_document(d)

    def chained(t):
        parts = [st.postings_iter(t)]
        h = idx.lookup(t)
        if h is not None:
            c = Q.PostingsCursor(idx.store, h)
            if c.seek_geq(horizon + 1):
                parts.append(Q.WordPostingsCursor(c))
        c = Q.ChainedCursor(parts)
        return None if c.exhausted else c

    rng = np.random.default_rng(6)
    for terms in _random_phrases(vocab, rng):
        got = Q.phrase_from_cursors([chained(t) for t in terms]).tolist()
        assert got == _naive_phrase(docs, terms), (codec, terms)


def test_word_level_conjunctive_unique_docids(word_corpus):
    """Word-level conjunctive must intersect DOCUMENTS, not occurrences —
    duplicate docids in the occurrence streams never reach the output."""
    vocab, docs, idx = word_corpus
    rng = np.random.default_rng(8)
    for _ in range(40):
        terms = [vocab[i] for i in
                 rng.choice(25, size=rng.integers(1, 4), replace=False)]
        got = Q.conjunctive_query(idx, terms).tolist()
        assert got == Q.brute_conjunctive(idx, terms).tolist(), terms
        assert len(got) == len(set(got))
