"""Query-engine tests (paper §3.6, §4.6)."""

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.index import DynamicIndex


@pytest.fixture(scope="module")
def built(zipf_docs):
    vocab, docs = zipf_docs
    out = {}
    for growth in ("const", "triangle"):
        idx = DynamicIndex(B=48, growth=growth)
        for doc in docs:
            idx.add_document(doc)
        out[growth] = idx
    return vocab, out


@pytest.mark.parametrize("growth", ["const", "triangle"])
def test_conjunctive_vs_bruteforce(built, growth):
    vocab, idxs = built
    idx = idxs[growth]
    rng = np.random.default_rng(0)
    for _ in range(150):
        terms = [vocab[i] for i in
                 rng.choice(120, size=rng.integers(1, 5), replace=False)]
        got = Q.conjunctive_query(idx, terms)
        exp = Q.brute_conjunctive(idx, terms)
        assert got.tolist() == exp.tolist()


def test_conjunctive_missing_term(built):
    vocab, idxs = built
    assert len(Q.conjunctive_query(idxs["const"], ["zzz_not_there"])) == 0
    assert len(Q.conjunctive_query(idxs["const"],
                                   [vocab[0], "zzz_not_there"])) == 0


@pytest.mark.parametrize("growth", ["const", "triangle"])
def test_ranked_daat_equals_taat(built, growth):
    """DAAT and TAAT share the canonical tie order (higher score, then
    lower docid), so the returned DOC SETS must be identical too — not just
    the score multisets."""
    vocab, idxs = built
    idx = idxs[growth]
    rng = np.random.default_rng(1)
    for _ in range(40):
        terms = [vocab[i] for i in
                 rng.choice(200, size=rng.integers(1, 4), replace=False)]
        d1, s1 = Q.ranked_disjunctive(idx, terms, k=10)
        d2, s2 = Q.ranked_disjunctive_taat(idx, terms, k=10)
        assert d1.tolist() == d2.tolist()
        assert np.allclose(s1, s2, rtol=1e-9)


def test_ranked_tie_breaking_at_k_boundary():
    """Scores tying across the k boundary: both paths must keep the LOWER
    docids (the defined tie order), never an argpartition-arbitrary set."""
    idx = DynamicIndex(B=48)
    for _ in range(6):
        idx.add_document(["a", "b"])      # six identically-scored docs
    idx.add_document(["a"])               # lower score, doc 7
    d1, s1 = Q.ranked_disjunctive(idx, ["a", "b"], k=3)
    d2, s2 = Q.ranked_disjunctive_taat(idx, ["a", "b"], k=3)
    assert d1.tolist() == [1, 2, 3]
    assert d2.tolist() == [1, 2, 3]
    assert np.allclose(s1, s2)
    dl = np.asarray([0] + [2] * 6 + [1], dtype=np.float64)
    db, _ = Q.ranked_bm25(idx, ["a", "b"], dl, k=3)
    assert db.tolist() == [1, 2, 3]


def test_seek_geq_cursor(built):
    vocab, idxs = built
    idx = idxs["const"]
    t = vocab[0]  # most common term: long multi-block chain
    docids, _ = idx.postings(t)
    cur = Q.PostingsCursor(idx.store, idx.lookup(t))
    # seek to every 7th docid and to gaps between docids
    for target in list(docids[::7]) + list(docids[:-1:5] + 1):
        cur2 = Q.PostingsCursor(idx.store, idx.lookup(t))
        found = cur2.seek_geq(int(target))
        expect = docids[docids >= int(target)]
        if len(expect) == 0:
            assert not found
        else:
            assert found and cur2.docid == expect[0]


def test_queries_interleaved_with_ingest(zipf_docs):
    """Immediate access under a mixed operation stream (Figure 1's point)."""
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64)
    rng = np.random.default_rng(3)
    for i, doc in enumerate(docs[:200]):
        idx.add_document(doc)
        if i % 7 == 0:
            terms = [doc[0], doc[min(1, len(doc) - 1)]]
            got = Q.conjunctive_query(idx, terms)
            assert idx.num_docs in got.tolist()  # the just-added doc matches


# --------------------------------------------------------------------------
# phrase operator vs a brute-force position scan over the raw documents,
# across dynamic-only, static-only, and chained-tier cursors (ISSUE 3)
# --------------------------------------------------------------------------


from conftest import naive_phrase as _naive_phrase  # noqa: E402


@pytest.fixture(scope="module")
def word_corpus():
    rng = np.random.default_rng(17)
    vocab = [f"p{i}" for i in range(25)]
    # small vocabulary + short docs -> dense phrase hits, including repeats
    docs = [[vocab[i] for i in rng.integers(0, 25, rng.integers(3, 35))]
            for _ in range(120)]
    idx = DynamicIndex(B=48, word_level=True)
    for d in docs:
        idx.add_document(d)
    return vocab, docs, idx


def _random_phrases(vocab, rng, n=60):
    out = []
    for _ in range(n):
        k = int(rng.integers(2, 5))
        out.append([vocab[i] for i in rng.integers(0, len(vocab), k)])
    # adversarial shapes: repeated term in the phrase, single term
    out += [[vocab[0], vocab[0]], [vocab[1], vocab[2], vocab[1]], [vocab[3]]]
    return out


def test_phrase_oracle_dynamic_cursors(word_corpus):
    vocab, docs, idx = word_corpus
    rng = np.random.default_rng(4)
    for terms in _random_phrases(vocab, rng):
        got = Q.phrase_from_cursors(
            [Q.word_cursor(idx, t) for t in terms]).tolist()
        assert got == _naive_phrase(docs, terms), terms


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_phrase_oracle_static_cursors(word_corpus, codec):
    from repro.core.static_index import StaticIndex
    vocab, docs, idx = word_corpus
    st = StaticIndex.freeze(idx, codec)
    rng = np.random.default_rng(5)
    for terms in _random_phrases(vocab, rng):
        got = Q.phrase_from_cursors(
            [st.postings_iter(t) for t in terms]).tolist()
        assert got == _naive_phrase(docs, terms), (codec, terms)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_phrase_oracle_chained_tier_cursors(word_corpus, codec):
    """Static prefix + dynamic suffix chained per slot: phrase results must
    equal the naive scan over the WHOLE collection."""
    from repro.core.static_index import StaticIndex
    vocab, docs, idx0 = word_corpus
    horizon = 70
    idx = DynamicIndex(B=48, word_level=True)
    for d in docs[:horizon]:
        idx.add_document(d)
    st = StaticIndex.freeze(idx, codec)
    for d in docs[horizon:]:
        idx.add_document(d)

    def chained(t):
        parts = [st.postings_iter(t)]
        h = idx.lookup(t)
        if h is not None:
            c = Q.PostingsCursor(idx.store, h)
            if c.seek_geq(horizon + 1):
                parts.append(Q.WordPostingsCursor(c))
        c = Q.ChainedCursor(parts)
        return None if c.exhausted else c

    rng = np.random.default_rng(6)
    for terms in _random_phrases(vocab, rng):
        got = Q.phrase_from_cursors([chained(t) for t in terms]).tolist()
        assert got == _naive_phrase(docs, terms), (codec, terms)


def test_word_level_conjunctive_unique_docids(word_corpus):
    """Word-level conjunctive must intersect DOCUMENTS, not occurrences —
    duplicate docids in the occurrence streams never reach the output."""
    vocab, docs, idx = word_corpus
    rng = np.random.default_rng(8)
    for _ in range(40):
        terms = [vocab[i] for i in
                 rng.choice(25, size=rng.integers(1, 4), replace=False)]
        got = Q.conjunctive_query(idx, terms).tolist()
        assert got == Q.brute_conjunctive(idx, terms).tolist(), terms
        assert len(got) == len(set(got))


# --------------------------------------------------------------------------
# word-level ranked scoring: the ISSUE-4 bug — w-gaps were scored as term
# frequencies and f_t inflated to occurrence counts.  Pin every ranked path
# to the brute-force doc-level oracle over the raw documents.
# --------------------------------------------------------------------------


from conftest import naive_proximity as _naive_prox  # noqa: E402
from conftest import naive_ranked as _naive_ranked  # noqa: E402


def _doclens_of(docs):
    return np.asarray([0] + [len(d) for d in docs], dtype=np.float64)


def test_word_level_ranked_matches_doc_level_oracle(word_corpus):
    """TAAT, DAAT, and BM25 over a word-level index must equal the
    brute-force doc-level oracle exactly — docids AND scores."""
    vocab, docs, idx = word_corpus
    dl = _doclens_of(docs)
    rng = np.random.default_rng(21)
    for _ in range(40):
        terms = [vocab[i] for i in
                 rng.choice(25, size=rng.integers(1, 4), replace=False)]
        exp_d, exp_s = _naive_ranked(docs, terms, k=10, mode="tfidf")
        for got_d, got_s in (Q.ranked_disjunctive_taat(idx, terms, k=10),
                             Q.ranked_disjunctive(idx, terms, k=10)):
            assert got_d.tolist() == exp_d.tolist(), terms
            assert np.allclose(got_s, exp_s, rtol=1e-12), terms
        bd, bs = Q.ranked_bm25(idx, terms, dl, k=10)
        ed, es = _naive_ranked(docs, terms, k=10, mode="bm25")
        assert bd.tolist() == ed.tolist(), terms
        assert np.allclose(bs, es, rtol=1e-12), terms


def test_word_level_ranked_equals_doc_level_index(word_corpus):
    """Regression: a doc-level and a word-level index over the SAME corpus
    must produce identical ranked results (docids and scores)."""
    vocab, docs, widx = word_corpus
    didx = DynamicIndex(B=48)
    for d in docs:
        didx.add_document(d)
    dl = _doclens_of(docs)
    rng = np.random.default_rng(22)
    for _ in range(30):
        terms = [vocab[i] for i in
                 rng.choice(25, size=rng.integers(1, 4), replace=False)]
        for fn in (lambda ix: Q.ranked_disjunctive_taat(ix, terms, k=10),
                   lambda ix: Q.ranked_disjunctive(ix, terms, k=10),
                   lambda ix: Q.ranked_bm25(ix, terms, dl, k=10)):
            wd, ws = fn(widx)
            dd, ds = fn(didx)
            assert wd.tolist() == dd.tolist(), terms
            assert np.array_equal(ws, ds), terms


def test_word_level_doc_ft_is_document_frequency(word_corpus):
    vocab, docs, idx = word_corpus
    for t in vocab[:10]:
        assert Q.doc_ft(idx, t) == sum(t in d for d in docs)


def test_bm25_prox_matches_oracle_and_prefers_near(word_corpus):
    vocab, docs, idx = word_corpus
    dl = _doclens_of(docs)
    rng = np.random.default_rng(23)
    for _ in range(25):
        terms = [vocab[i] for i in
                 rng.choice(25, size=rng.integers(1, 4), replace=False)]
        gd, gs = Q.ranked_bm25_prox(idx, terms, dl, k=10)
        ed, es = _naive_ranked(docs, terms, k=10, mode="bm25_prox")
        assert gd.tolist() == ed.tolist(), terms
        assert np.allclose(gs, es, rtol=1e-12), terms
    # positions matter: adjacent terms out-rank distant ones, ceteris paribus
    idx2 = DynamicIndex(B=48, word_level=True)
    idx2.add_document(["p", "z", "z", "z", "z", "q"])
    idx2.add_document(["p", "q", "z", "z", "z", "z"])
    d, s = Q.ranked_bm25_prox(idx2, ["p", "q"],
                              np.asarray([0, 6, 6], np.float64), k=2)
    assert d[0] == 2 and s[0] > s[1]
    # ...while plain BM25 ties them (identical tf/doclen)
    db, sb = Q.ranked_bm25(idx2, ["p", "q"],
                           np.asarray([0, 6, 6], np.float64), k=2)
    assert db.tolist() == [1, 2] and sb[0] == sb[1]


# --------------------------------------------------------------------------
# proximity via the positional cursor protocol, across all cursor kinds
# --------------------------------------------------------------------------


def _random_prox_queries(vocab, rng, n=40):
    out = []
    for _ in range(n):
        k = int(rng.integers(1, 4))
        terms = [vocab[i] for i in rng.integers(0, len(vocab), k)]
        out.append((terms, int(rng.integers(1, 12))))
    # adversarial: repeated terms at tight and loose windows
    out += [([vocab[0], vocab[0]], 1), ([vocab[0], vocab[0]], 6),
            ([vocab[1], vocab[2], vocab[1]], 4), ([vocab[3]], 3)]
    return out


def test_proximity_oracle_dynamic(word_corpus):
    vocab, docs, idx = word_corpus
    rng = np.random.default_rng(24)
    for terms, w in _random_prox_queries(vocab, rng):
        got = Q.proximity_query(idx, terms, w).tolist()
        assert got == _naive_prox(docs, terms, w), (terms, w)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_proximity_oracle_static_cursors(word_corpus, codec):
    from repro.core.static_index import StaticIndex
    vocab, docs, idx = word_corpus
    st = StaticIndex.freeze(idx, codec)
    rng = np.random.default_rng(25)
    for terms, w in _random_prox_queries(vocab, rng):
        need = {}
        for t in terms:
            need[t] = need.get(t, 0) + 1
        got = Q.proximity_from_cursors(
            [st.postings_iter(t) for t in need], w,
            list(need.values())).tolist()
        assert got == _naive_prox(docs, terms, w), (codec, terms, w)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_proximity_oracle_chained_tier_cursors(word_corpus, codec):
    """Static prefix + dynamic suffix chained per unique term: proximity
    must equal the naive scan over the WHOLE collection."""
    from repro.core.static_index import StaticIndex
    vocab, docs, idx0 = word_corpus
    horizon = 70
    idx = DynamicIndex(B=48, word_level=True)
    for d in docs[:horizon]:
        idx.add_document(d)
    st = StaticIndex.freeze(idx, codec)
    for d in docs[horizon:]:
        idx.add_document(d)

    def chained(t):
        parts = [st.postings_iter(t)]
        h = idx.lookup(t)
        if h is not None:
            c = Q.PostingsCursor(idx.store, h)
            if c.seek_geq(horizon + 1):
                parts.append(Q.WordPostingsCursor(c))
        c = Q.ChainedCursor(parts)
        return None if c.exhausted else c

    rng = np.random.default_rng(26)
    for terms, w in _random_prox_queries(vocab, rng):
        need = {}
        for t in terms:
            need[t] = need.get(t, 0) + 1
        got = Q.proximity_from_cursors(
            [chained(t) for t in need], w, list(need.values())).tolist()
        assert got == _naive_prox(docs, terms, w), (codec, terms, w)


def test_proximity_duplicate_terms_bind_distinct_positions():
    """ISSUE-4 satellite: ["a", "a"] must NOT match a doc with a single
    occurrence of "a" (the old per-label window sweep counted the same
    position twice)."""
    idx = DynamicIndex(B=48, word_level=True)
    idx.add_document(["a", "b", "c"])             # 1: one "a"
    idx.add_document(["a", "b", "a"])             # 2: two "a", 2 apart
    idx.add_document(["a"] + ["b"] * 8 + ["a"])   # 3: two "a", 9 apart
    assert Q.proximity_query(idx, ["a", "a"], 5).tolist() == [2]
    assert Q.proximity_query(idx, ["a", "a"], 9).tolist() == [2, 3]
    # triple binding needs three distinct occurrences
    idx.add_document(["a", "a", "a"])             # 4
    assert Q.proximity_query(idx, ["a", "a", "a"], 9).tolist() == [4]
    # mixed repeat: two "a" and one "b" inside one window
    assert Q.proximity_query(idx, ["a", "b", "a"], 2).tolist() == [2]
    assert Q.proximity_query(idx, ["a", "b", "a"], 9).tolist() == [2, 3]
    # single-term queries: any occurrence suffices at multiplicity 1
    assert Q.proximity_query(idx, ["a"], 1).tolist() == [1, 2, 3, 4]


def test_proximity_requires_word_level():
    idx = DynamicIndex(B=48)
    idx.add_document(["a", "b"])
    with pytest.raises(ValueError):
        Q.proximity_query(idx, ["a", "b"], 2)
