"""Device (JAX) query engine vs host engines; kernel-backed decode path."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import query as Q
from repro.core.collate import collate
from repro.core.device_index import build_device_image, query_step
from repro.core.index import DynamicIndex
from repro.kernels.dvbyte_decode.ops import as_decode_fn


@pytest.fixture(scope="module")
def image(zipf_docs):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64, growth="const")
    for doc in docs[:400]:
        idx.add_document(doc)
    col = collate(idx)
    img = build_device_image(col, [t.encode() for t in vocab])
    return vocab, col, img


def test_requires_collated(zipf_docs):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=64)
    for doc in docs[:50]:
        idx.add_document(doc)
    with pytest.raises(ValueError):
        build_device_image(idx, [t.encode() for t in vocab])


def test_ranked_matches_host(image):
    vocab, col, img = image
    rng = np.random.default_rng(0)
    mb = int(img.term_nblk.max())
    for _ in range(15):
        terms = rng.choice(150, size=rng.integers(1, 5), replace=False)
        qt = jnp.asarray([list(terms) + [0] * (5 - len(terms))], jnp.int32)
        qm = jnp.asarray([[1] * len(terms) + [0] * (5 - len(terms))], bool)
        d_dev, s_dev = query_step(img, qt, qm, k=10, max_blocks=mb)
        d_host, s_host = Q.ranked_disjunctive_taat(
            col, [vocab[i] for i in terms], k=10)
        got = np.sort(np.asarray(s_dev[0]))[::-1][: len(s_host)]
        assert np.allclose(got, s_host, rtol=1e-5)


def test_conjunctive_matches_host(image):
    vocab, col, img = image
    rng = np.random.default_rng(1)
    mb = int(img.term_nblk.max())
    for _ in range(15):
        terms = rng.choice(100, size=rng.integers(1, 4), replace=False)
        qt = jnp.asarray([list(terms) + [0] * (4 - len(terms))], jnp.int32)
        qm = jnp.asarray([[1] * len(terms) + [0] * (4 - len(terms))], bool)
        m, _ = query_step(img, qt, qm, mode="conjunctive", max_blocks=mb)
        got = (np.flatnonzero(np.asarray(m[0])) + 1).tolist()
        exp = Q.conjunctive_query(col, [vocab[i] for i in terms]).tolist()
        assert got == exp


def test_kernel_decode_path(image):
    """query_step with the Pallas decode kernel == pure-jnp decode path."""
    vocab, col, img = image
    mb = int(img.term_nblk.max())
    qt = jnp.asarray([[1, 5, 20, 0]], jnp.int32)
    qm = jnp.asarray([[1, 1, 1, 0]], bool)
    d1, s1 = query_step(img, qt, qm, k=10, max_blocks=mb)
    d2, s2 = query_step(img, qt, qm, k=10, max_blocks=mb,
                        decode_fn=as_decode_fn(F=4, tile=64))
    assert np.allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    assert np.asarray(d1).tolist() == np.asarray(d2).tolist()


def test_batched_queries(image):
    vocab, col, img = image
    mb = int(img.term_nblk.max())
    qt = jnp.asarray([[1, 2, 0], [3, 0, 0], [10, 20, 30]], jnp.int32)
    qm = jnp.asarray([[1, 1, 0], [1, 0, 0], [1, 1, 1]], bool)
    d, s = query_step(img, qt, qm, k=5, max_blocks=mb)
    assert d.shape == (3, 5) and s.shape == (3, 5)
    for qi, terms in enumerate(([1, 2], [3], [10, 20, 30])):
        dh, sh = Q.ranked_disjunctive_taat(col, [vocab[i] for i in terms],
                                           k=5)
        assert np.allclose(np.sort(np.asarray(s[qi]))[::-1][: len(sh)], sh,
                           rtol=1e-5)
