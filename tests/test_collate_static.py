"""Collation (§5.5) and static conversion (§3.1 / Table 9) tests."""

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.collate import collate, is_collated
from repro.core.index import DynamicIndex
from repro.core.static_index import StaticIndex


@pytest.fixture(scope="module")
def idx(zipf_docs):
    vocab, docs = zipf_docs
    idx = DynamicIndex(B=48, growth="const")
    for doc in docs:
        idx.add_document(doc)
    return idx


def test_collation_preserves_everything(idx, zipf_docs):
    vocab, _ = zipf_docs
    col = collate(idx)
    assert is_collated(col)
    assert not is_collated(idx)
    assert col.total_bytes() == idx.total_bytes()
    assert col.num_postings == idx.num_postings
    for t in vocab[:150]:
        d1, f1 = idx.postings(t)
        d2, f2 = col.postings(t)
        assert d1.tolist() == d2.tolist() and f1.tolist() == f2.tolist()


def test_collated_index_remains_extensible(idx, zipf_docs):
    """§5.5: "the index remains both queryable and extensible"."""
    vocab, docs = zipf_docs
    col = collate(idx)
    n0 = col.num_docs
    col.add_document(docs[0])
    docids, _ = col.postings(docs[0][0])
    assert docids[-1] == n0 + 1


def test_collation_query_equivalence(idx, zipf_docs):
    vocab, _ = zipf_docs
    col = collate(idx)
    rng = np.random.default_rng(5)
    for _ in range(30):
        terms = [vocab[i] for i in
                 rng.choice(80, size=rng.integers(1, 4), replace=False)]
        assert Q.conjunctive_query(idx, terms).tolist() == \
            Q.conjunctive_query(col, terms).tolist()


@pytest.mark.parametrize("codec", ["bp128", "interp"])
def test_static_freeze_roundtrip(idx, zipf_docs, codec):
    vocab, _ = zipf_docs
    st = StaticIndex.freeze(idx, codec)
    for t in vocab[:150]:
        d1, f1 = idx.postings(t)
        d2, f2 = st.postings(t)
        assert d1.tolist() == d2.tolist() and f1.tolist() == f2.tolist()


def test_static_smaller_than_dynamic(idx):
    """Table 9 vs Table 8: static < dynamic, interp < bp128."""
    bp = StaticIndex.freeze(idx, "bp128")
    it = StaticIndex.freeze(idx, "interp")
    assert it.bytes_per_posting() < bp.bytes_per_posting()
    assert bp.bytes_per_posting() < idx.bytes_per_posting()
