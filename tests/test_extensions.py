"""Beyond-paper extensions: BM25 (paper §6.2 future work), phrase/proximity
querying over the word-level index (§1.1's motivation), remesh, and the
conjunctive sharded mode."""

import numpy as np
import pytest

from repro.core import query as Q
from repro.core.index import DynamicIndex


@pytest.fixture(scope="module")
def word_index():
    docs = [
        "the quick brown fox jumps over the lazy dog".split(),
        "a quick brown cat sits on the quick mat".split(),
        "brown fox quick brown fox".split(),
        "the dog sleeps all day long every day".split(),
        "quick thinking saves the slow fox".split(),
    ]
    idx = DynamicIndex(B=48, word_level=True)
    for d in docs:
        idx.add_document(d)
    return idx, docs


class TestPhrase:
    def test_phrase_hits(self, word_index):
        idx, docs = word_index
        got = Q.phrase_query(idx, ["quick", "brown"]).tolist()
        exp = [i + 1 for i, d in enumerate(docs)
               if any(d[j:j + 2] == ["quick", "brown"]
                      for j in range(len(d) - 1))]
        assert got == exp

    def test_phrase_three_terms(self, word_index):
        idx, docs = word_index
        got = Q.phrase_query(idx, ["quick", "brown", "fox"]).tolist()
        assert got == [1, 3]

    def test_phrase_no_match(self, word_index):
        idx, _ = word_index
        assert len(Q.phrase_query(idx, ["lazy", "fox"])) == 0

    def test_phrase_needs_word_level(self):
        idx = DynamicIndex(B=48)
        idx.add_document(["a", "b"])
        with pytest.raises(ValueError):
            Q.phrase_query(idx, ["a", "b"])

    def test_proximity(self, word_index):
        idx, docs = word_index
        # "fox" and "dog" within 3 words: doc 1 only ("fox jumps over the
        # lazy dog" — distance 5 > 3? positions: fox=4, dog=9 -> no)
        got = Q.proximity_query(idx, ["fox", "dog"], window=5).tolist()
        exp = []
        for i, d in enumerate(docs):
            pf = [j for j, t in enumerate(d) if t == "fox"]
            pd = [j for j, t in enumerate(d) if t == "dog"]
            if pf and pd and min(abs(a - b) for a in pf for b in pd) <= 5:
                exp.append(i + 1)
        assert got == exp

    def test_phrase_brute_force_random(self):
        rng = np.random.default_rng(0)
        vocab = [f"t{i}" for i in range(30)]
        docs = [[vocab[i] for i in rng.integers(0, 30, rng.integers(5, 40))]
                for _ in range(60)]
        idx = DynamicIndex(B=48, word_level=True)
        for d in docs:
            idx.add_document(d)
        for _ in range(25):
            a, b = vocab[rng.integers(30)], vocab[rng.integers(30)]
            got = Q.phrase_query(idx, [a, b]).tolist()
            exp = [i + 1 for i, d in enumerate(docs)
                   if any(d[j] == a and d[j + 1] == b
                          for j in range(len(d) - 1))]
            assert got == exp, (a, b)


class TestBM25:
    def test_bm25_ranks_sensibly(self, zipf_docs):
        vocab, docs = zipf_docs
        idx = DynamicIndex(B=64)
        doclens = [0]
        for d in docs[:300]:
            idx.add_document(d)
            doclens.append(len(d))
        dl = np.asarray(doclens, dtype=np.float64)
        t = vocab[40]
        top_d, top_s = Q.ranked_bm25(idx, [t], dl, k=10)
        assert len(top_d) > 0
        assert (np.diff(top_s) <= 1e-12).all()  # descending
        # every returned doc actually contains the term
        docs_with_t, _ = idx.postings(t)
        assert set(top_d.tolist()) <= set(docs_with_t.tolist())

    def test_bm25_prefers_higher_tf_same_length(self):
        idx = DynamicIndex(B=48)
        idx.add_document(["x", "x", "x", "pad", "pad", "pad"])
        idx.add_document(["x", "pad", "pad", "pad", "pad", "pad"])
        dl = np.asarray([0, 6, 6], dtype=np.float64)
        top_d, top_s = Q.ranked_bm25(idx, ["x"], dl, k=2)
        assert top_d[0] == 1 and top_s[0] > top_s[1]

    def test_bm25_length_normalization(self):
        idx = DynamicIndex(B=48)
        idx.add_document(["x"] + ["pad"] * 3)       # tf=1, len 4
        idx.add_document(["x"] + ["filler"] * 99)   # tf=1, len 100
        dl = np.asarray([0, 4, 100], dtype=np.float64)
        top_d, top_s = Q.ranked_bm25(idx, ["x"], dl, k=2)
        assert top_d[0] == 1  # shorter doc wins at equal tf


class TestDeviceBM25:
    def test_device_bm25_matches_host(self, zipf_docs):
        import jax.numpy as jnp

        from repro.core.collate import collate
        from repro.core.device_index import build_device_image, query_step
        vocab, docs = zipf_docs
        idx = DynamicIndex(B=64)
        doclens = [0]
        for d in docs[:250]:
            idx.add_document(d)
            doclens.append(len(d))
        img = build_device_image(collate(idx), [t.encode() for t in vocab])
        dl = np.zeros(idx.num_docs + 1, np.float32)
        dl[: len(doclens)] = doclens
        mb = int(img.term_nblk.max())
        rng = np.random.default_rng(2)
        for _ in range(8):
            terms = rng.choice(100, size=rng.integers(1, 4), replace=False)
            qt = jnp.asarray([list(terms) + [0] * (4 - len(terms))],
                             jnp.int32)
            qm = jnp.asarray([[1] * len(terms) + [0] * (4 - len(terms))],
                             bool)
            d_dev, s_dev = query_step(img, qt, qm, k=10, max_blocks=mb,
                                      mode="bm25", doclens=jnp.asarray(dl))
            d_host, s_host = Q.ranked_bm25(
                idx, [vocab[i] for i in terms], dl.astype(np.float64), k=10)
            got = np.sort(np.asarray(s_dev[0]))[::-1][: len(s_host)]
            assert np.allclose(got, s_host, rtol=2e-4)


class TestRemesh:
    def test_remesh_preserves_values(self):
        import jax
        import jax.numpy as jnp

        from repro.distributed.sharding import lm_param_rules, remesh
        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        tree = {"embed": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "layers": {"wq": jnp.ones((2, 8, 8))}}
        out = remesh(tree, mesh1, lm_param_rules(mesh1))
        assert np.allclose(np.asarray(out["embed"]),
                           np.asarray(tree["embed"]))
        assert jax.tree.structure(out) == jax.tree.structure(tree)
