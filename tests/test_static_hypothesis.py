"""Hypothesis round-trip properties for the static codecs (own module so
the importorskip cannot take the deterministic static-serving tests with
it; CI installs hypothesis, local runs without it just skip)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core.static_index import BP_BLOCK, StaticIndex  # noqa: E402

gap_lists = hst.lists(
    hst.tuples(hst.integers(1, 1 << 26), hst.integers(1, 1 << 16)),
    min_size=0, max_size=3 * BP_BLOCK + 5)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(deadline=None)
@given(pairs=gap_lists)
def test_roundtrip_property(codec, pairs):
    """encode∘decode is the identity for any gap/frequency list, including
    empty, singleton, dense (gap=1), and large-gap shapes."""
    docids = np.cumsum([g for g, _ in pairs]).astype(np.int64)
    fs = np.asarray([f for _, f in pairs], np.int64)
    st = StaticIndex(codec)
    st.add_list(b"t", docids, fs)
    d, f = st.postings(b"t")
    assert d.tolist() == docids.tolist()
    assert f.tolist() == fs.tolist()


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(deadline=None)
@given(pairs=gap_lists, targets=hst.lists(hst.integers(0, 1 << 27),
                                          min_size=1, max_size=6))
def test_seek_geq_property(codec, pairs, targets):
    docids = np.cumsum([g for g, _ in pairs]).astype(np.int64)
    fs = np.asarray([f for _, f in pairs], np.int64)
    st = StaticIndex(codec)
    st.add_list(b"t", docids, fs)
    c = st.postings_iter(b"t")
    if c is None:
        assert len(docids) == 0
        return
    for target in sorted(targets):
        ok = c.seek_geq(int(target))
        k = int(np.searchsorted(docids, target, side="left"))
        if k >= len(docids):
            assert not ok
            return
        assert ok and c.docid == int(docids[k]) and c.payload == int(fs[k])


# --------------------------------------------------------------------------
# word-level ⟨d,w⟩ lists (ISSUE 3): a random stream is a list of documents,
# each a (d-gap, [w-gaps...]) pair — covering empty lists, singleton docs,
# repeated terms (several occurrences per doc), and max-gap positions.
# --------------------------------------------------------------------------

word_lists = hst.lists(
    hst.tuples(hst.integers(1, 1 << 24),                       # d-gap >= 1
               hst.lists(hst.integers(1, 1 << 20),             # w-gaps >= 1
                         min_size=1, max_size=6)),
    min_size=0, max_size=2 * BP_BLOCK + 9)


def _occurrence_stream(docs):
    """Flatten [(d-gap, [w-gaps])] into the arrays add_list expects plus the
    grouped reference shape."""
    udocs = np.cumsum([g for g, _ in docs]).astype(np.int64)
    occ, wgaps = [], []
    for d, (_, ws) in zip(udocs, docs):
        occ += [int(d)] * len(ws)
        wgaps += ws
    counts = np.asarray([len(ws) for _, ws in docs], np.int64)
    return (udocs, counts, np.asarray(occ, np.int64),
            np.asarray(wgaps, np.int64))


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(deadline=None)
@given(docs=word_lists)
def test_word_roundtrip_property(codec, docs):
    """encode∘decode is the identity for any ⟨d,w⟩ occurrence stream, both
    at occurrence granularity (postings) and grouped (word_postings)."""
    udocs, counts, occ, wgaps = _occurrence_stream(docs)
    st = StaticIndex(codec, word_level=True)
    st.add_list(b"t", occ, wgaps)
    d, w = st.postings(b"t")
    assert d.tolist() == occ.tolist()
    assert w.tolist() == wgaps.tolist()
    gd, gc, gw = st.word_postings(b"t")
    assert gd.tolist() == udocs.tolist()
    assert gc.tolist() == counts.tolist()
    assert gw.tolist() == wgaps.tolist()


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(deadline=None)
@given(docs=word_lists, targets=hst.lists(hst.integers(0, 1 << 25),
                                          min_size=1, max_size=6))
def test_word_seek_and_positions_property(codec, docs, targets):
    """seek_geq lands on the first doc >= target with the right occurrence
    count, and positions() returns that doc's exact cumulative w-gaps."""
    udocs, counts, occ, wgaps = _occurrence_stream(docs)
    st = StaticIndex(codec, word_level=True)
    st.add_list(b"t", occ, wgaps)
    c = st.postings_iter(b"t")
    if c is None:
        assert len(udocs) == 0
        return
    starts = np.cumsum(counts) - counts
    for target in sorted(targets):
        ok = c.seek_geq(int(target))
        k = int(np.searchsorted(udocs, target, side="left"))
        if k >= len(udocs):
            assert not ok
            return
        assert ok and c.docid == int(udocs[k])
        assert c.payload == int(counts[k])
        lo = int(starts[k])
        exp = np.cumsum(wgaps[lo:lo + int(counts[k])])
        assert c.positions().tolist() == exp.tolist()