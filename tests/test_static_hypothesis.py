"""Hypothesis round-trip properties for the static codecs (own module so
the importorskip cannot take the deterministic static-serving tests with
it; CI installs hypothesis, local runs without it just skip)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core.static_index import BP_BLOCK, StaticIndex  # noqa: E402

gap_lists = hst.lists(
    hst.tuples(hst.integers(1, 1 << 26), hst.integers(1, 1 << 16)),
    min_size=0, max_size=3 * BP_BLOCK + 5)


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(max_examples=60, deadline=None)
@given(pairs=gap_lists)
def test_roundtrip_property(codec, pairs):
    """encode∘decode is the identity for any gap/frequency list, including
    empty, singleton, dense (gap=1), and large-gap shapes."""
    docids = np.cumsum([g for g, _ in pairs]).astype(np.int64)
    fs = np.asarray([f for _, f in pairs], np.int64)
    st = StaticIndex(codec)
    st.add_list(b"t", docids, fs)
    d, f = st.postings(b"t")
    assert d.tolist() == docids.tolist()
    assert f.tolist() == fs.tolist()


@pytest.mark.parametrize("codec", ["bp128", "interp"])
@settings(max_examples=25, deadline=None)
@given(pairs=gap_lists, targets=hst.lists(hst.integers(0, 1 << 27),
                                          min_size=1, max_size=6))
def test_seek_geq_property(codec, pairs, targets):
    docids = np.cumsum([g for g, _ in pairs]).astype(np.int64)
    fs = np.asarray([f for _, f in pairs], np.int64)
    st = StaticIndex(codec)
    st.add_list(b"t", docids, fs)
    c = st.postings_iter(b"t")
    if c is None:
        assert len(docids) == 0
        return
    for target in sorted(targets):
        ok = c.seek_geq(int(target))
        k = int(np.searchsorted(docids, target, side="left"))
        if k >= len(docids):
            assert not ok
            return
        assert ok and c.docid == int(docids[k]) and c.payload == int(fs[k])