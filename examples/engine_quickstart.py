"""Unified query engine quickstart: one API over three backends.

    PYTHONPATH=src python examples/engine_quickstart.py

Demonstrates the engine lifecycle end to end:

  1. ingest through the Engine (doclens/vocab/f_t tracked for you);
  2. query mid-stream on every backend — host cursors, the device oracle,
     and the Pallas kernels — and watch the planner route;
  3. collate once (the freeze), keep ingesting, and query the device
     backend again: the frozen image plus the incremental DeltaImage answer
     for documents the device has never been collated over;
  4. serve an interleaved ingest/query stream through QueryService.
"""

import numpy as np

from repro.core.collate import collation_stats
from repro.data.corpus import CorpusSpec, SyntheticCorpus
from repro.engine import Engine, Query
from repro.serve import QueryService

corpus = SyntheticCorpus(CorpusSpec(n_docs=1200, words_per_doc=120,
                                    universe=2400, seed=4))
docs = list(corpus.doc_terms())

# (pass auto_collate_delta_frac=0.5 to bound the delta by re-freezing
#  automatically; left off here so step 3 shows a single explicit freeze)
eng = Engine(B=64, growth="const")
for d in docs[:700]:
    eng.add_document(d)

sample = [t for t in docs[0][:4]]
print(f"ingested {eng.index.num_docs} docs; probe terms: {sample[:2]}")

# -- 2: same query, every backend -----------------------------------------
q = Query(terms=tuple(sample[:2]), mode="ranked_tfidf", k=5)
for backend in ("host", "device", "pallas"):
    r = eng.execute(Query(terms=q.terms, mode=q.mode, k=q.k,
                          backend=backend))
    print(f"  {backend:7s} top-5 docs {r.docids.tolist()} "
          f"scores {np.round(r.scores, 3).tolist()}")

auto = eng.execute_many([q] * 8)[0]
print(f"planner routed a batch of 8 to: {auto.backend} ({auto.reason})")

# -- 3: freeze once, keep ingesting, device stays current -----------------
eng.collate_now()
print(f"\ncollated (freeze): frag now "
      f"{collation_stats(eng.index)['frag_ratio']:.3f}")
for d in docs[700:]:
    eng.add_document(d)
r = eng.execute(Query(terms=q.terms, mode="conjunctive", backend="device"))
post_freeze = int((r.docids > 700).sum())
print(f"device conjunctive sees {len(r.docids)} docs, {post_freeze} of them "
      f"ingested after the freeze — no re-collation "
      f"(collations={eng.stats().collations}, "
      f"delta_refreshes={eng.stats().delta_refreshes})")

# -- 4: serving loop -------------------------------------------------------
svc = QueryService(eng, max_batch=8)
ops = []
for i, d in enumerate(SyntheticCorpus(CorpusSpec(
        n_docs=200, words_per_doc=120, universe=2400, seed=5)).doc_terms()):
    ops.append(("doc", d))
    if i % 3 == 0:
        ops.append(("query", Query(terms=tuple(sample[:2]),
                                   mode="bm25", k=3)))
tickets = svc.run_stream(ops)
print(f"\nserved {len(tickets)} queries interleaved with 200 ingests: "
      f"{svc.latency_summary()}")
print(f"final stats: {eng.stats()}")
