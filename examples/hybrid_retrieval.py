"""Hybrid retrieval: the paper's index as candidate generator for the
two-tower model (DESIGN.md §Arch-applicability — the direct integration).

    PYTHONPATH=src python examples/hybrid_retrieval.py

Stage 1 (lexical): conjunctive Boolean over the immediate-access dynamic
index produces a candidate set for the query terms.
Stage 2 (dense):  the two-tower model embeds the query profile and scores
the candidates with the retrieval_dot Pallas kernel (interpret mode here).
Documents keep arriving between queries — stage 1 always sees them.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import DynamicIndex
from repro.core.query import conjunctive_query
from repro.data.corpus import CorpusSpec, SyntheticCorpus
from repro.kernels.retrieval_dot.ops import candidate_scores
from repro.models import recsys as rec

mesh = jax.make_mesh((1, 1), ("data", "model"))
rng = np.random.default_rng(0)

# --- corpus + lexical index ------------------------------------------------
corpus = SyntheticCorpus(CorpusSpec(n_docs=1500, words_per_doc=120,
                                    universe=3_000, seed=3))
idx = DynamicIndex(B=64)
docs = []
for doc in corpus.doc_terms():
    idx.add_document(doc)
    docs.append(doc)

# --- dense side: tiny two-tower with per-document item embeddings ----------
cfg = rec.TwoTowerConfig(n_users_vocab=4096, n_items=len(docs) + 1,
                         embed_dim=32, tower_mlp=(64, 32), n_user_feats=4)
params = rec.twotower_init(cfg, jax.random.PRNGKey(0))

with mesh:
    # a user profile (hashed feature ids)
    user = {"user_feats": jnp.asarray([[11, 99, 1033, 7]], jnp.int32),
            "user_mask": jnp.ones((1, 4), jnp.float32)}
    u = rec.user_embedding(params, user, cfg, mesh)          # (1, 32)

    query_terms = [docs[10][0], docs[10][1]]
    for round_ in range(3):
        # stage 1: lexical candidates (immediate access — includes docs
        # ingested since the previous round)
        cand_docs = conjunctive_query(idx, query_terms)
        if len(cand_docs) == 0:
            print("no lexical candidates")
            break
        # stage 2: dense scoring of candidates with the Pallas kernel
        cand_emb = rec.item_embedding(params,
                                      jnp.asarray(cand_docs, jnp.int32),
                                      cfg, mesh)             # (C, 32)
        scores = candidate_scores(u, cand_emb, tile_q=8, tile_n=128,
                                  tile_d=32)[0]
        order = np.argsort(-np.asarray(scores))[:5]
        print(f"[round {round_}] {len(cand_docs)} lexical candidates for "
              f"{query_terms}; top-5 dense: "
              f"{np.asarray(cand_docs)[order].tolist()}")
        # documents keep arriving between queries
        newdoc = [query_terms[0], query_terms[1], "freshdoc"] + docs[round_]
        idx.add_document(newdoc)
        docs.append(newdoc)

print("hybrid retrieval: lexical recall + dense precision, one live index")
