"""Quickstart: the paper's full object lifecycle in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds an immediate-access dynamic index over a synthetic docstream,
queries it while ingesting, collates it (§5.5), freezes it to a static
compressed index (§3.1), and prints the size story (Tables 8/9/13).

This walks the paper's raw structures; for the planner-driven multi-backend
query path (host / device oracle / Pallas kernels, incremental device-image
refresh) see examples/engine_quickstart.py.
"""

import numpy as np

from repro.core.collate import collate
from repro.core.index import DynamicIndex
from repro.core.query import conjunctive_query, ranked_disjunctive_taat
from repro.core.static_index import StaticIndex
from repro.data.corpus import CorpusSpec, SyntheticCorpus

# universe scales with the collection so postings/term matches real corpora
corpus = SyntheticCorpus(CorpusSpec(n_docs=2000, words_per_doc=200,
                                    universe=4_000, seed=1))

idx = DynamicIndex(B=64, growth="const")          # the paper's §3 structure
tri = DynamicIndex(B=64, growth="triangle")       # the paper's §5.4 lists

sample_terms = []
for i, doc in enumerate(corpus.doc_terms()):
    idx.add_document(doc)
    tri.add_document(doc)
    if i < 5:
        sample_terms.extend(doc[:3])
    if i == 999:  # immediate access: query mid-stream
        hits = conjunctive_query(idx, sample_terms[:2])
        print(f"[mid-stream] docs matching {sample_terms[:2]}: {len(hits)}")

print(f"\ningested {idx.num_docs} docs, {idx.num_postings} postings")
print(f"Const    index: {idx.bytes_per_posting():.3f} bytes/posting")
print(f"Triangle index: {tri.bytes_per_posting():.3f} bytes/posting")

top_d, top_s = ranked_disjunctive_taat(idx, sample_terms[:3], k=5)
print(f"top-5 for {sample_terms[:3]}: docs {top_d.tolist()}")

col = collate(idx)                                # §5.5
assert (conjunctive_query(col, sample_terms[:2])
        == conjunctive_query(idx, sample_terms[:2])).all()
print(f"collated: chains now contiguous "
      f"(same {col.bytes_per_posting():.3f} B/posting)")

frozen = StaticIndex.freeze(idx, "interp")        # §3.1 static conversion
print(f"static (interpolative): {frozen.bytes_per_posting():.3f} B/posting")
d1, _ = idx.postings(sample_terms[0])
d2, _ = frozen.postings(sample_terms[0])
assert (d1 == d2).all()
print("static == dynamic postings: verified")
