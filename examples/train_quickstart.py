"""Train a reduced LM end-to-end with the production stack.

    PYTHONPATH=src python examples/train_quickstart.py [--steps 30]

Uses the same config/model/optimizer/trainer path as the full 512-chip
launch (launch/train.py), shrunk to CPU scale: fault-tolerant Trainer
(checkpoint every 10 steps, NaN fuse, straggler log) over the deterministic
token pipeline.  Kill it mid-run and re-run: it resumes from the last
checkpoint and replays the exact interrupted batch.
"""

import argparse
import tempfile

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="repro-ckpt-")
    print(f"checkpoints -> {ckpt}")
    import sys
    sys.argv = ["train", "--arch", args.arch, "--steps", str(args.steps),
                "--batch", "4", "--seq", "64", "--ckpt-dir", ckpt]
    train_mod.main()


if __name__ == "__main__":
    main()
