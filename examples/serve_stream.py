"""End-to-end serving driver (the paper's operational mode, Figure 2).

    PYTHONPATH=src python examples/serve_stream.py [--docs 4000]

A mixed operation stream: documents are ingested continuously; conjunctive
and ranked queries arrive interleaved and must see every previously-ingested
document (immediate access).  When the dynamic shard reaches its memory
budget it is collated, frozen to a static shard, and a fresh dynamic shard
takes over — queries then fan out to both and results fuse, exactly the
lifecycle of §3.1.  Reports ingest/query latency and shard sizes.
"""

import argparse
import time

import numpy as np

from repro.core.collate import collate
from repro.core.index import DynamicIndex
from repro.core.query import conjunctive_query, ranked_disjunctive_taat
from repro.core.static_index import StaticIndex
from repro.data.corpus import CorpusSpec, SyntheticCorpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--shard-budget-mb", type=float, default=1.0)
    args = ap.parse_args()

    corpus = SyntheticCorpus(CorpusSpec(n_docs=args.docs, words_per_doc=150,
                                        universe=max(3000, args.docs), seed=2))
    rng = np.random.default_rng(0)

    static_shards: list[tuple[StaticIndex, int]] = []  # (shard, doc offset)
    dynamic = DynamicIndex(B=64)
    doc_base = 0
    seen_terms: list[str] = []
    i_lat, q_lat = [], []
    n_queries = 0

    def run_query(terms, ranked):
        """Fan out to the dynamic shard + all static shards; fuse."""
        results = []
        t0 = time.perf_counter()
        if ranked:
            d, s = ranked_disjunctive_taat(dynamic, terms, k=10)
            results.extend(zip(s.tolist(), (d + doc_base).tolist()))
            for shard, base in static_shards:
                N = shard.num_postings  # IDF base differs per shard: ok
                acc = {}
                for t in terms:
                    dd, ff = shard.postings(t)
                    for di, fi in zip(dd, ff):
                        w = np.log1p(fi)
                        acc[di + base] = acc.get(di + base, 0.0) + w
                results.extend((v, k) for k, v in acc.items())
            results.sort(reverse=True)
            out = results[:10]
        else:
            hits = list((conjunctive_query(dynamic, terms)
                         + doc_base).tolist())
            for shard, base in static_shards:
                sets = [set((shard.postings(t)[0] + base).tolist())
                        for t in terms]
                if sets:
                    hits.extend(sorted(set.intersection(*sets)))
            out = hits
        q_lat.append(time.perf_counter() - t0)
        return out

    for n, doc in enumerate(corpus.doc_terms(), start=1):
        t0 = time.perf_counter()
        dynamic.add_document(doc)
        i_lat.append(time.perf_counter() - t0)
        if n <= 40:
            seen_terms.extend(doc[:4])
        if n % 9 == 0 and seen_terms:
            terms = list(rng.choice(seen_terms, size=2, replace=False))
            run_query(terms, ranked=(n % 18 == 0))
            n_queries += 1
        # shard rollover at the memory budget (Figure 2's lifecycle)
        if dynamic.total_bytes() > args.shard_budget_mb * 2**20:
            dynamic = collate(dynamic)  # locality for the freeze pass
            frozen = StaticIndex.freeze(dynamic, "bp128")
            static_shards.append((frozen, doc_base))
            doc_base += dynamic.num_docs
            print(f"[rollover] froze shard {len(static_shards)}: "
                  f"{frozen.num_postings} postings at "
                  f"{frozen.bytes_per_posting():.2f} B/p "
                  f"(dynamic was {dynamic.bytes_per_posting():.2f})")
            dynamic = DynamicIndex(B=64)

    print(f"\n{args.docs} docs through {len(static_shards)} static shards + "
          f"1 dynamic shard; {n_queries} queries interleaved")
    print(f"ingest: mean {np.mean(i_lat)*1e6:.1f} us/doc")
    print(f"query : mean {np.mean(q_lat)*1e3:.2f} ms  "
          f"p95 {np.percentile(q_lat, 95)*1e3:.2f} ms")


if __name__ == "__main__":
    main()
