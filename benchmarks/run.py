"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only table14] [--skip-roofline]``
Prints ``name,us_per_call,derived`` CSV rows (paper-table quantities in the
derived column), then the §Roofline report from results/dryrun.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import tables
    from benchmarks.common import BENCH_DOCS, Emitter

    benches = [
        tables.table2_dvbyte_sizes,
        tables.table3_f_sweep,
        tables.table4_codec_speed,
        tables.table7_components,
        tables.table8_block_sweep,
        tables.table9_static,
        tables.table11_wordlevel,
        tables.table13_growth,
        tables.table14_collation,
        tables.fig4_ingest,
        tables.fig5_query_latency,
        tables.device_query_bench,
    ]
    emit = Emitter()
    print(f"# benchmarks over synthetic WSJ1-like corpus "
          f"(BENCH_SCALE={BENCH_DOCS} docs)")
    print("name,us_per_call,derived")
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        t0 = time.time()
        try:
            bench(emit)
        except Exception as e:  # noqa: BLE001
            print(f"{bench.__name__},nan,ERROR {type(e).__name__}: {e}",
                  flush=True)
        print(f"# {bench.__name__} done in {time.time()-t0:.1f}s",
              file=sys.stderr, flush=True)

    if not args.skip_roofline:
        try:
            from benchmarks.roofline import report
            print("# --- roofline (from results/dryrun) ---")
            report()
        except Exception as e:  # noqa: BLE001
            print(f"# roofline report unavailable: {e}")


if __name__ == "__main__":
    main()
