import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""§Perf hillclimb driver: lowers cell VARIANTS and records before/after.

Three hillclimb targets (see EXPERIMENTS.md §Perf for the full log):

  H1 paper-index/query_rank     (most representative of the paper)
     variants: dense-accumulator scorer (paper-faithful TAAT analogue),
               sparse sort-based scorer, collation ablation is host-side.
  H2 recsys/gnn whole-mesh batch sharding (worst useful-compute ratio)
     variants are code-level (before numbers retained in EXPERIMENTS.md).
  H3 mistral-large train_4k     (most collective-bound LM cell)
     variants: act_shard ∈ {seq, dmodel, none} — boundary-activation layout
     trades remat memory vs per-layer collective traffic.

Usage: PYTHONPATH=src python -m benchmarks.perf_iterations [--which h1 h3]
Writes results/perf/<tag>.json with cost/memory/collective numbers.
"""

import argparse
import json
import time

import jax

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "perf")


def measure(tag: str, mesh, fn, in_shardings, args, donate=()):
    from repro.launch.dryrun import collective_bytes
    t0 = time.time()
    with mesh:
        comp = jax.jit(fn, in_shardings=in_shardings,
                       donate_argnums=donate).lower(*args).compile()
        ca = comp.cost_analysis() or {}
        ma = comp.memory_analysis()
        coll = collective_bytes(comp.as_text())
    rec = {"tag": tag,
           "hlo_flops": float(ca.get("flops", 0.0)),
           "hlo_bytes": float(ca.get("bytes accessed", 0.0)),
           "collectives": coll,
           "temp_bytes": int(ma.temp_size_in_bytes),
           "argument_bytes": int(ma.argument_size_in_bytes),
           "compile_s": round(time.time() - t0, 1)}
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[perf] {tag}: flops={rec['hlo_flops']:.3e} "
          f"bytes={rec['hlo_bytes']:.3e} "
          f"link={coll['link_bytes']:.3e} "
          f"temp={rec['temp_bytes']/2**30:.2f}GiB", flush=True)
    return rec


def h1_index_scorer():
    """Dense (paper-faithful) vs sparse sort-based scorer."""
    from repro.configs.paper_index import ARCH
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    out = {}
    for mode in ("ranked", "ranked_sparse"):
        cell = ARCH.build(mesh, "query_rank", mode=mode)
        out[mode] = measure(f"h1_index_{mode}", mesh, cell.fn,
                            cell.in_shardings, cell.args)
    m = out["ranked"]["hlo_bytes"] / max(out["ranked_sparse"]["hlo_bytes"], 1)
    print(f"[perf] H1: sparse scorer reduces bytes accessed {m:.1f}x")
    return out


def h3_lm_act_shard():
    """mistral train: boundary activation sharding variants (probe L=2,
    which exposes per-layer collective volume exactly)."""
    from dataclasses import replace

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()
    arch = get_arch("mistral-large-123b")
    out = {}
    for act in ("seq", "dmodel", "none"):
        arch_v = type(arch)(arch_id=arch.arch_id,
                            cfg=replace(arch.cfg, act_shard=act))
        cell = arch_v.build(mesh, "train_4k", probe_layers=2)
        out[act] = measure(f"h3_mistral_act_{act}", mesh, cell.fn,
                           cell.in_shardings, cell.args)
        # memory evidence needs the production (non-probe) lowering
        cell_m = arch_v.build(mesh, "train_4k")
        out[act + "_mem"] = measure(f"h3_mistral_act_{act}_mem", mesh,
                                    cell_m.fn, cell_m.in_shardings,
                                    cell_m.args, donate=(0, 1))
    return out


def h2_recsys_note():
    print("[perf] H2 (whole-mesh batch sharding for recsys/gnn) is a code-"
          "level change; BEFORE numbers are archived in EXPERIMENTS.md "
          "§Perf from the pre-change dry-run; rerun dryrun.py for AFTER.")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--which", nargs="*", default=["h1", "h3"])
    args = ap.parse_args()
    if "h1" in args.which:
        h1_index_scorer()
    if "h2" in args.which:
        h2_recsys_note()
    if "h3" in args.which:
        h3_lm_act_shard()


if __name__ == "__main__":
    main()
